//! End-to-end integration: tuning loops over the full stack (engines +
//! simulator + history), the paper's qualitative claims at test strength,
//! and failure-injection paths.

use tftune::algorithms::{Algorithm, NelderMead};
use tftune::config::{SurrogateKind, TuneConfig};
use tftune::evaluator::{tune, Evaluator, SimEvaluator};
use tftune::history::History;
use tftune::sim::{ModelId, SimWorkload};
use tftune::space::Config;
use tftune::util::stats;

/// All paper algorithms substantially beat the TF-default configuration
/// on every model within the 50-iteration budget.
#[test]
fn tuning_beats_default_config_everywhere() {
    for model in ModelId::all() {
        let space = model.space();
        // TF-ish default: inter=2, intra=cores, blocktime=200 guide value,
        // omp=cores, smallest batch.
        let default_cfg = space.snap(&vec![2, 48, space.params[2].min, 200, 48]);
        let default_tp = SimWorkload::noiseless(model).true_throughput(&default_cfg);
        for alg in Algorithm::all_paper() {
            let mut tuner = alg.build(&space, 13);
            let mut eval = SimEvaluator::new(model, 13);
            let h = tune(tuner.as_mut(), &mut eval, 50).unwrap();
            let best = h.best().unwrap().value;
            assert!(
                best > default_tp,
                "{} on {}: best {best:.1} <= default {default_tp:.1}",
                alg.name(),
                model.name()
            );
        }
    }
}

/// BO is "the most competitive overall" (paper conclusion): across models
/// and seeds, its median normalised score must be near the per-model
/// winner and at least GA's.
#[test]
fn bo_most_competitive_overall() {
    let mut scores: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for model in ModelId::all() {
        let mut bests: Vec<(&str, f64)> = Vec::new();
        for alg in Algorithm::all_paper() {
            let mut per_seed = Vec::new();
            for seed in [1u64, 2, 3] {
                let cfg = TuneConfig {
                    model,
                    algorithm: alg,
                    iterations: 50,
                    seed,
                    surrogate: SurrogateKind::Native,
                    ..Default::default()
                };
                let h = cfg.run().unwrap();
                per_seed.push(h.best().unwrap().value);
            }
            bests.push((alg.name(), stats::median(&per_seed)));
        }
        let top = bests.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max);
        for (name, v) in bests {
            scores.entry(name).or_default().push(v / top);
        }
    }
    let bo = stats::mean(&scores["bayesian-optimization"]);
    let ga = stats::mean(&scores["genetic-algorithm"]);
    let nms = stats::mean(&scores["nelder-mead"]);
    // BO within 3% of the per-model winner on average, and >= GA.
    assert!(bo > 0.97, "BO mean normalised score {bo:.3}");
    assert!(bo >= ga, "BO {bo:.3} < GA {ga:.3}");
    // nobody should dominate BO by more than noise
    assert!(nms - bo < 0.02, "NMS {nms:.3} dominates BO {bo:.3}");
}

/// Deterministic end-to-end: same spec => identical history.
#[test]
fn runs_are_reproducible() {
    let cfg = TuneConfig {
        model: ModelId::TransformerLtFp32,
        algorithm: Algorithm::Ga,
        iterations: 30,
        seed: 77,
        ..Default::default()
    };
    let h1 = cfg.run().unwrap();
    let h2 = cfg.run().unwrap();
    assert_eq!(h1.values(), h2.values());
    let curves: Vec<Config> = h1.iter().map(|e| e.config.clone()).collect();
    let curves2: Vec<Config> = h2.iter().map(|e| e.config.clone()).collect();
    assert_eq!(curves, curves2);
}

/// Different seeds explore differently.
#[test]
fn seeds_differ() {
    let mk = |seed| TuneConfig {
        model: ModelId::NcfFp32,
        algorithm: Algorithm::Bo,
        iterations: 15,
        seed,
        ..Default::default()
    };
    let h1 = mk(1).run().unwrap();
    let h2 = mk(2).run().unwrap();
    assert_ne!(h1.values(), h2.values());
}

/// Failure injection: an evaluator that errors mid-run aborts cleanly.
struct FlakyEvaluator {
    inner: SimEvaluator,
    fail_at: usize,
    count: usize,
}

impl Evaluator for FlakyEvaluator {
    fn evaluate(&mut self, config: &Config) -> anyhow::Result<f64> {
        self.count += 1;
        if self.count == self.fail_at {
            anyhow::bail!("injected measurement failure");
        }
        self.inner.evaluate(config)
    }
    fn describe(&self) -> String {
        "flaky".into()
    }
}

#[test]
fn evaluator_failure_propagates() {
    let model = ModelId::Resnet50Fp32;
    let mut tuner = Algorithm::Random.build(&model.space(), 5);
    let mut eval = FlakyEvaluator { inner: SimEvaluator::new(model, 5), fail_at: 7, count: 0 };
    let err = tune(tuner.as_mut(), &mut eval, 20).unwrap_err();
    assert!(err.to_string().contains("injected"));
}

/// NMS restart ablation: the modernised (restarting) variant must never be
/// meaningfully worse than the TensorTuner-style one on the real surface.
#[test]
fn nms_restart_ablation() {
    let model = ModelId::Resnet50Int8;
    let space = model.space();
    let mut best_plain = Vec::new();
    let mut best_restart = Vec::new();
    for seed in [3u64, 4, 5, 6] {
        for restarts in [false, true] {
            let mut t = NelderMead::new(space.clone(), seed).with_restarts(restarts);
            let mut eval = SimEvaluator::new(model, seed);
            let h = tune(&mut t, &mut eval, 60).unwrap();
            let best = h.best().unwrap().value;
            if restarts {
                best_restart.push(best);
            } else {
                best_plain.push(best);
            }
        }
    }
    assert!(
        stats::mean(&best_restart) >= stats::mean(&best_plain) * 0.98,
        "restarts should not hurt: {best_restart:?} vs {best_plain:?}"
    );
}

/// History persistence across a full run.
#[test]
fn history_round_trips_through_disk() {
    let dir = std::env::temp_dir().join("tftune_e2e_hist");
    let path = dir.join("run.jsonl");
    let cfg = TuneConfig {
        model: ModelId::BertFp32,
        algorithm: Algorithm::Nms,
        iterations: 20,
        seed: 9,
        history_out: Some(path.clone()),
        ..Default::default()
    };
    let h = cfg.run().unwrap();
    let loaded = History::load(&path, &ModelId::BertFp32.space()).unwrap();
    assert_eq!(h.values(), loaded.values());
    std::fs::remove_dir_all(&dir).ok();
}
