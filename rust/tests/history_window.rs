//! ROADMAP "larger histories", measurement half: posterior quality of the
//! unbounded conditioning window (`with_history_window(None)`) against the
//! default N_PAD=64 AOT-parity window on a *long* run (n ≥ 256 total
//! observations), recording best-so-far regret deltas at checkpoints.
//!
//! Design: both engines are warm-started with the same 200 random
//! observations (long shared history), then run 60 further BO iterations
//! against a deterministic smooth objective — 260 observations by the
//! end. The unbounded engine conditions on all of them; the windowed
//! engine on its best-64 subset. The candidate pool is narrowed to keep
//! the debug-build runtime sane; the comparison is unaffected (both
//! engines score the same pool size).

use tftune::algorithms::{BayesOpt, Tuner};
use tftune::gp::SurrogateHandle;
use tftune::history::Measurement;
use tftune::space::threading_space;
use tftune::util::Rng;

const WARM: usize = 200;
const ITERS: usize = 60;
const OPT: f64 = 10.0;

#[test]
fn unbounded_window_regret_on_long_runs() {
    let space = threading_space(64, 1024, 64);
    let target = space.to_unit(&vec![3, 36, 640, 60, 36]);
    let objective = |cfg: &Vec<i64>| {
        let u = space.to_unit(cfg);
        OPT - OPT * u.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
    };

    // Identical warm-start history for both engines (n = 200 > 3×window).
    let mut rng = Rng::new(91);
    let warm: Vec<(Vec<i64>, f64)> = (0..WARM)
        .map(|_| {
            let c = space.random(&mut rng);
            let v = objective(&c);
            (c, v)
        })
        .collect();

    let mut run = |window: Option<usize>| -> (f64, Vec<f64>) {
        let mut bo = BayesOpt::new(space.clone(), 92)
            .with_history_window(window)
            .with_candidates(32);
        for (c, v) in &warm {
            bo.warm_start(c, *v);
        }
        let mut best = warm.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max);
        let mut regret_curve = Vec::new();
        for i in 0..ITERS {
            let t = bo.ask(1).pop().unwrap();
            let v = objective(&t.config);
            bo.tell(t.id, &Measurement::new(v));
            best = best.max(v);
            if (i + 1) % 15 == 0 {
                regret_curve.push(OPT - best);
            }
        }
        let handle = bo.surrogate_handle();
        let conditioned = handle.lock().conditioning_set().len();
        let expected = if window.is_none() { WARM + ITERS } else { 64 };
        assert_eq!(
            conditioned, expected,
            "window {window:?} conditioned on {conditioned} of {} observations",
            WARM + ITERS
        );
        (OPT - best, regret_curve)
    };

    let (regret_unbounded, curve_unbounded) = run(None);
    let (regret_windowed, curve_windowed) = run(Some(64));

    // Record the deltas (positive = windowed ahead) — the measurement the
    // ROADMAP item asks for, kept visible in the test log.
    println!("window-study checkpoints (iterations 15/30/45/60):");
    for (k, (u, w)) in curve_unbounded.iter().zip(&curve_windowed).enumerate() {
        println!(
            "  iter {:>2}: regret unbounded {u:.4}  windowed {w:.4}  delta {:+.4}",
            (k + 1) * 15,
            u - w
        );
    }
    println!(
        "final regret: unbounded {regret_unbounded:.4}, windowed {regret_windowed:.4}, \
         delta {:+.4}",
        regret_unbounded - regret_windowed
    );

    // Both setups must solve the smooth objective to small regret after
    // 200 random + 60 model-guided evaluations (deterministic: fixed
    // seeds, noiseless objective)…
    assert!(
        regret_unbounded < 2.5,
        "unbounded window failed to converge: regret {regret_unbounded}"
    );
    assert!(
        regret_windowed < 2.5,
        "windowed engine failed to converge: regret {regret_windowed}"
    );
    // …and conditioning on the full history must not be a material
    // regression on this objective (the windowed engine keeps the best
    // quarter of its history, so it is a strong baseline).
    assert!(
        regret_unbounded <= regret_windowed + 1.5,
        "unbounded window regressed: {regret_unbounded} vs windowed {regret_windowed}"
    );
}
