//! The shared concurrent surrogate contract, pinned at integration level:
//!
//! 1. N threads telling into one [`SharedSurrogate`] produce, after the
//!    drain, a posterior within 1e-9 of the serial private-model path
//!    (one `IncrementalGp` fed the same observations on one thread).
//! 2. Tells stream in *while* an ask-side loop scores (drain, sync,
//!    fantasy-extend, blocked scoring) without blocking, losing or
//!    reordering-beyond-enqueue any observation.
//! 3. Attaching a fresh handle to a BO engine changes nothing for a sole
//!    owner: the trajectory is identical to the default private engine.
//! 4. Out-of-order tells on the remote evaluator path: daemon responses
//!    shuffled across two shards condition the shared factor exactly as
//!    a serial run fed the same completion order (and `History` records
//!    that order faithfully).

use tftune::algorithms::{BayesOpt, Tuner};
use tftune::evaluator::{RemoteEvaluator, SimEvaluator};
use tftune::gp::{GpHyper, IncrementalGp, ScoreWorkspace, SharedSurrogate};
use tftune::history::{History, Measurement};
use tftune::server::TargetServer;
use tftune::sim::ModelId;
use tftune::space::threading_space;
use tftune::util::{prop, Rng};

fn toy_obs(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
    (0..n)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin() - 0.5 * x[d - 1];
            (x, y)
        })
        .collect()
}

fn obs_key(x: &[f64], y: f64) -> (Vec<u64>, u64) {
    (x.iter().map(|v| v.to_bits()).collect(), y.to_bits())
}

#[test]
fn concurrent_tells_match_serial_private_model() {
    let hyper = GpHyper::default();
    let mut rng = Rng::new(41);
    let (n, d) = (48usize, 4usize);
    let obs = toy_obs(&mut rng, n, d);
    let cand: Vec<f64> = (0..8 * d).map(|_| rng.f64()).collect();

    // Four evaluator threads tell disjoint chunks concurrently.
    let shared = SharedSurrogate::new(hyper);
    std::thread::scope(|scope| {
        for chunk in obs.chunks(n / 4) {
            let handle = shared.clone();
            scope.spawn(move || {
                for (x, y) in chunk {
                    handle.tell(x.clone(), *y);
                }
            });
        }
    });
    assert_eq!(shared.total_observations(), n);

    let mut g = shared.lock();
    assert_eq!(g.len(), n, "a tell was lost");
    // The drained store is a permutation of the input set, bit-exact.
    let mut got: Vec<_> = (0..n).map(|i| obs_key(g.x(i), g.y(i))).collect();
    let mut want: Vec<_> = obs.iter().map(|(x, y)| obs_key(x, *y)).collect();
    got.sort();
    want.sort();
    assert_eq!(got, want, "drained observations are not the told set");

    // Score through the shared factor (drain order)...
    let idx = g.conditioning_set();
    assert_eq!(idx.len(), n);
    assert!(g.sync(&idx));
    let y_guard: Vec<f64> = (0..n).map(|i| g.y(i)).collect();
    g.set_targets(&y_guard);
    let mut ws = ScoreWorkspace::default();
    g.score_into(&cand, 8, 1.5, 0.3, &mut ws);

    // ...and through the serial private-model path (canonical order).
    let mut private = IncrementalGp::new(hyper);
    for (x, y) in &obs {
        assert!(private.push(x, *y));
    }
    let y_all: Vec<f64> = obs.iter().map(|(_, y)| *y).collect();
    private.set_targets(&y_all);
    let mut ws_ref = ScoreWorkspace::default();
    private.score_into(&cand, 8, 1.5, 0.3, &mut ws_ref);

    // The GP posterior is permutation invariant; thread interleaving may
    // only move it within numerical noise.
    for j in 0..8 {
        assert!(
            (ws.mean[j] - ws_ref.mean[j]).abs() <= 1e-9,
            "mean diverged under concurrency: {} vs {}",
            ws.mean[j],
            ws_ref.mean[j]
        );
        assert!(
            (ws.std[j] - ws_ref.std[j]).abs() <= 1e-9,
            "std diverged under concurrency: {} vs {}",
            ws.std[j],
            ws_ref.std[j]
        );
    }
}

#[test]
fn asks_interleave_with_streaming_tells() {
    let hyper = GpHyper::default();
    let shared = SharedSurrogate::new(hyper);
    let (total, d) = (120usize, 3usize);

    std::thread::scope(|scope| {
        for t in 0..3u64 {
            let handle = shared.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(100 + t);
                for i in 0..total / 3 {
                    let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                    handle.tell(x, (i as f64 * 0.1).sin());
                    if i % 8 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }

        // Ask loop on this thread: every pass drains whatever has queued,
        // rebuilds/extends the factor past the window, fantasy-extends
        // and scores — while tells keep streaming in.
        let mut ws = ScoreWorkspace::default();
        let cand = vec![0.5; d];
        let fantasy = vec![0.25; d];
        let mut seen = 0usize;
        while seen < total {
            let mut g = shared.lock();
            assert!(g.len() >= seen, "observation count went backwards");
            seen = g.len();
            if g.len() >= 2 {
                let idx = g.conditioning_set();
                assert!(idx.len() <= hyper.max_history);
                assert!(g.sync(&idx), "sync failed mid-stream");
                let y: Vec<f64> = idx.iter().map(|&i| g.y(i)).collect();
                g.set_targets(&y);
                assert!(g.extend_fantasy(&fantasy, 0.0));
                g.score_into(&cand, 1, 1.5, 0.0, &mut ws);
                assert!(ws.mean[0].is_finite());
                assert!(ws.std[0] > 0.0);
            }
            drop(g); // retracts the fantasy; releases the model lock
            std::thread::yield_now();
        }
    });
    // Every tell landed exactly once.
    assert_eq!(shared.lock().len(), total);
    assert_eq!(shared.pending(), 0);
}

#[test]
fn attached_handle_preserves_the_sole_owner_trajectory() {
    // A BO engine given an explicit (empty) shared handle must walk the
    // exact trajectory of the default private engine: borrowing the model
    // through the handle is behaviour-neutral for a sole owner.
    let space = threading_space(64, 1024, 64);
    let target = space.to_unit(&vec![2, 36, 704, 120, 44]);
    let objective = |cfg: &Vec<i64>| {
        let u = space.to_unit(cfg);
        8.0 - 8.0 * u.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
    };
    let mut private = BayesOpt::new(space.clone(), 33);
    let handle = SharedSurrogate::new(GpHyper::default());
    let mut attached = BayesOpt::new(space.clone(), 33).with_shared_surrogate(handle.clone());
    for step in 0..20 {
        let a = private.ask(1).pop().unwrap();
        let b = attached.ask(1).pop().unwrap();
        assert_eq!(a.config, b.config, "diverged at step {step}");
        let v = objective(&a.config);
        private.tell(a.id, &Measurement::new(v));
        attached.tell(b.id, &Measurement::new(v));
    }
    assert_eq!(handle.len(), 20);
}

#[test]
fn prop_remote_out_of_order_tells_match_serial_path() {
    // Two daemon shards answer a pipelined batch; the host tells results
    // back in a random completion order. The shared factor must condition
    // exactly as a serial run fed the same order, and History must record
    // that order.
    let model = ModelId::NcfFp32;
    let space = model.space();
    prop::check("remote out-of-order tells", 4, |rng| {
        let mut shards = Vec::new();
        for s in 0..2u64 {
            let server = TargetServer::bind(
                "127.0.0.1:0",
                space.clone(),
                Box::new(SimEvaluator::new(model, 50 + s)),
            )
            .unwrap();
            let (addr, handle) = server.spawn().unwrap();
            let remote = RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
            shards.push((remote, handle));
        }

        let mut engine = BayesOpt::new(space.clone(), rng.next_u64());
        let trials = engine.ask(6);
        assert_eq!(trials.len(), 6);
        // Shard the batch: 3 pipelined trials per daemon connection.
        for (i, t) in trials.iter().enumerate() {
            shards[i % 2].0.submit(t).unwrap();
        }
        let mut done: Vec<(u64, Measurement)> = Vec::new();
        for (shard, _) in shards.iter_mut() {
            for _ in 0..3 {
                let (id, m) = shard.recv_measurement().unwrap();
                done.push((id.expect("daemon echoes trial ids"), m));
            }
        }
        // Random completion order across the shards.
        rng.shuffle(&mut done);

        let mut history = History::new();
        for (id, m) in &done {
            let cfg = trials
                .iter()
                .find(|t| t.id == *id)
                .expect("echoed id was issued")
                .config
                .clone();
            engine.tell(*id, m);
            history.push_trial(*id, cfg, m);
        }

        // History records completion order faithfully.
        for (pos, e) in history.iter().enumerate() {
            assert_eq!(e.iteration, pos);
            assert_eq!(e.trial_id, done[pos].0);
        }
        let mut got_ids: Vec<u64> = history.iter().map(|e| e.trial_id).collect();
        got_ids.sort_unstable();
        let mut want_ids: Vec<u64> = trials.iter().map(|t| t.id).collect();
        want_ids.sort_unstable();
        assert_eq!(got_ids, want_ids, "every trial answered exactly once");

        // Serial replay: telling the same (config, value) sequence into a
        // fresh surrogate must reproduce the engine's shared store and
        // factor bit for bit.
        let serial = SharedSurrogate::new(engine.hyper());
        for e in history.iter() {
            serial.tell(space.to_unit(&e.config), e.value);
        }
        let engine_shared = engine.surrogate_handle();
        let mut ga = engine_shared.lock();
        let mut gb = serial.lock();
        assert_eq!(ga.len(), 6);
        assert_eq!(gb.len(), 6);
        for i in 0..6 {
            assert_eq!(
                obs_key(ga.x(i), ga.y(i)),
                obs_key(gb.x(i), gb.y(i)),
                "shared-factor observation {i} disagrees with the serial path"
            );
        }
        // Identical stores in identical order: the factored posteriors
        // must agree bitwise.
        let cand: Vec<f64> = (0..2 * space.dim()).map(|_| rng.f64()).collect();
        let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
        for (g, ws) in [(&mut ga, &mut wa), (&mut gb, &mut wb)] {
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            let y: Vec<f64> = idx.iter().map(|&i| g.y(i)).collect();
            g.set_targets(&y);
            g.score_into(&cand, 2, 1.5, 0.0, ws);
        }
        for j in 0..2 {
            assert_eq!(wa.mean[j].to_bits(), wb.mean[j].to_bits());
            assert_eq!(wa.std[j].to_bits(), wb.std[j].to_bits());
        }
        drop(ga);
        drop(gb);

        for (remote, handle) in shards {
            remote.shutdown().unwrap();
            let _ = handle.join();
        }
    });
}
