//! Cross-layer integration: the AOT HLO GP artifact (L2 JAX graph with the
//! L1 Pallas RBF kernel inside), executed via PJRT from Rust, must agree
//! with the exact native-Rust GP — including lengthscale selection, which
//! the artifact consumes as a *runtime input* (no recompilation).
//!
//! Skips (with a note) when `artifacts/` has not been built; the
//! lengthscale-selection pin runs the fused-surrogate engine path either
//! way (the scratch reference is artifact-shaped: one `fit_score` call).

use tftune::gp::{GpHyper, NativeSurrogate, Surrogate};
use tftune::runtime::GpSurrogate;
use tftune::util::Rng;

fn load() -> Option<GpSurrogate> {
    match GpSurrogate::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping artifact tests: {e}");
            None
        }
    }
}

fn toy(rng: &mut Rng, n: usize, d: usize, c: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = x.iter().map(|p| (5.0 * p[0]).sin() + p[d - 1] - 0.5).collect();
    let cand: Vec<Vec<f64>> = (0..c).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    (x, y, cand)
}

#[test]
fn artifact_matches_native_gp() {
    let Some(mut hlo) = load() else { return };
    let mut native = NativeSurrogate;
    let hyper = GpHyper::default();
    let mut rng = Rng::new(42);

    for n in [2usize, 7, 23, 64] {
        let (x, y, cand) = toy(&mut rng, n, 5, 64);
        let y_best = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let a = hlo.fit_score(&x, &y, &cand, hyper, 1.5, y_best).unwrap();
        let b = native.fit_score(&x, &y, &cand, hyper, 1.5, y_best).unwrap();
        for i in 0..cand.len() {
            assert!(
                (a.mean[i] - b.mean[i]).abs() < 2e-3,
                "n={n} cand {i}: mu hlo {} vs native {}",
                a.mean[i],
                b.mean[i]
            );
            assert!(
                (a.std[i] - b.std[i]).abs() < 2e-2,
                "n={n} cand {i}: sigma hlo {} vs native {}",
                a.std[i],
                b.std[i]
            );
            assert!(
                (a.gain[i] - b.gain[i]).abs() < 3e-2,
                "n={n} cand {i}: gain hlo {} vs native {}",
                a.gain[i],
                b.gain[i]
            );
        }
    }
}

#[test]
fn artifact_shapes_respected() {
    let Some(mut hlo) = load() else { return };
    let mut rng = Rng::new(1);
    // over-large history must be rejected cleanly
    let (x, y, cand) = toy(&mut rng, 65, 5, 4);
    assert!(hlo
        .fit_score(&x, &y, &cand, GpHyper::default(), 1.0, 0.0)
        .is_err());
    // empty history rejected
    let r = hlo.fit_score(&[], &[], &cand, GpHyper::default(), 1.0, 0.0);
    assert!(r.is_err());
}

#[test]
fn artifact_handles_max_candidates() {
    let Some(mut hlo) = load() else { return };
    let mut rng = Rng::new(2);
    let (x, y, cand) = toy(&mut rng, 10, 5, 512);
    let s = hlo.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 1.0).unwrap();
    assert_eq!(s.mean.len(), 512);
    assert!(s.std.iter().all(|v| v.is_finite() && *v >= 0.0));
}

#[test]
fn lengthscale_selection_drives_the_artifact_path_without_recompilation() {
    // ROADMAP satellite: `select_lengthscale` exists for the native stack;
    // the artifact takes lengthscale as a runtime input, so the same grid
    // search drives it with zero recompilation. Pin: a native incremental
    // engine and a fused-surrogate engine (the artifact-shaped scoring
    // path) walk identical trajectories under --tune-lengthscale and
    // select the *same* grid lengthscale.
    use tftune::algorithms::{BayesOpt, Tuner};
    use tftune::gp::{ExactRefitSurrogate, LENGTHSCALE_GRID};
    use tftune::history::Measurement;
    use tftune::space::threading_space;

    let space = threading_space(64, 1024, 64);
    let target = space.to_unit(&vec![2, 30, 576, 80, 40]);
    let objective = |cfg: &Vec<i64>| {
        let u = space.to_unit(cfg);
        9.0 - 9.0 * u.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
    };

    let mut native = BayesOpt::new(space.clone(), 27).with_lengthscale_selection();
    let mut fused = BayesOpt::with_surrogate(space.clone(), 27, ExactRefitSurrogate)
        .with_lengthscale_selection();
    for step in 0..24 {
        let a = native.ask(1).pop().unwrap();
        let b = fused.ask(1).pop().unwrap();
        assert_eq!(a.config, b.config, "paths diverged under selection at step {step}");
        let v = objective(&a.config);
        native.tell(a.id, &Measurement::new(v));
        fused.tell(b.id, &Measurement::new(v));
    }
    let ls = native.hyper().lengthscale;
    assert!(LENGTHSCALE_GRID.contains(&ls), "selected lengthscale {ls} off grid");
    assert_eq!(
        ls,
        fused.hyper().lengthscale,
        "native and artifact-path selection disagree"
    );
    // The selection must have actually engaged (power-of-two history
    // checkpoints at n=4/8/16 all ran) — with the default 0.2 in the grid
    // this still holds because the quadratic's LML argmax at n>=16 is a
    // longer lengthscale than the near-white candidates.
    assert!(fused.hyper().lengthscale > 0.0);

    // When the compiled artifact is present, it must accept the selected
    // hypers at runtime — same graph, new lengthscale input.
    if let Some(mut hlo) = load() {
        let mut rng = Rng::new(3);
        let (x, y, cand) = toy(&mut rng, 12, 5, 8);
        let hyper = GpHyper { lengthscale: ls, ..GpHyper::default() };
        let s = hlo.fit_score(&x, &y, &cand, hyper, 1.5, 0.0).unwrap();
        assert_eq!(s.mean.len(), 8);
        let native_s = NativeSurrogate.fit_score(&x, &y, &cand, hyper, 1.5, 0.0).unwrap();
        for i in 0..8 {
            assert!(
                (s.mean[i] - native_s.mean[i]).abs() < 2e-3,
                "artifact under selected lengthscale diverged: {} vs {}",
                s.mean[i],
                native_s.mean[i]
            );
        }
    }
}

#[test]
fn bo_runs_on_hlo_surrogate() {
    let Some(hlo) = load() else { return };
    use tftune::algorithms::BayesOpt;
    let space = tftune::sim::ModelId::Resnet50Int8.space();
    let mut bo = BayesOpt::with_surrogate(space.clone(), 3, hlo);
    let mut eval = tftune::evaluator::SimEvaluator::new(tftune::sim::ModelId::Resnet50Int8, 3);
    let h = tftune::evaluator::tune(&mut bo, &mut eval, 20).unwrap();
    assert_eq!(h.len(), 20);
    assert!(h.best().unwrap().value > 0.0);
}
