//! CLI smoke tests: run the actual `tftune` binary end to end.

use std::process::Command;

fn tftune(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_tftune"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("running tftune binary")
}

#[test]
fn no_args_prints_usage() {
    let out = tftune(&[]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("tune"));
}

#[test]
fn unknown_command_fails() {
    let out = tftune(&["frobnicate"]);
    assert!(!out.status.success());
}

#[test]
fn tune_runs_and_reports_best() {
    let out = tftune(&["tune", "--model", "ncf", "--alg", "ga", "--iters", "12", "--seed", "4"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("best throughput"), "{text}");
    assert!(text.contains("OMP_NUM_THREADS"), "{text}");
}

#[test]
fn tune_writes_history_file() {
    let dir = std::env::temp_dir().join("tftune_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("h.jsonl");
    let out = tftune(&[
        "tune",
        "--model",
        "bert",
        "--alg",
        "nms",
        "--iters",
        "8",
        "--out",
        out_path.to_str().unwrap(),
    ]);
    assert!(out.status.success());
    let text = std::fs::read_to_string(&out_path).unwrap();
    assert_eq!(text.lines().count(), 8);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tune_latency_objective() {
    let out = tftune(&[
        "tune", "--model", "resnet50-fp32", "--alg", "bo", "--iters", "15", "--objective",
        "latency",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("inverse-latency"), "{text}");
    assert!(text.contains("batches/s"), "{text}");
}

#[test]
fn profile_prints_schedule() {
    let out = tftune(&["profile", "--model", "ssd-mobilenet", "--inter", "2", "--omp", "16"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("latency"), "{text}");
    assert!(text.contains("backbone_dw_convs"), "{text}");
    assert!(text.contains("nms_postproc"), "{text}");
}

#[test]
fn tune_rejects_bad_model() {
    let out = tftune(&["tune", "--model", "alexnet", "--alg", "bo"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown model"));
}

#[test]
fn space_prints_table1() {
    let out = tftune(&["space"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table 1"));
    assert!(text.contains("KMP_BLOCKTIME"));
    assert!(text.contains("4214784")); // full grid size of resnet50
}

#[test]
fn figures_table1_only() {
    let out = tftune(&["figures", "table1"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Table 1"));
}

#[test]
fn tune_with_config_file() {
    let dir = std::env::temp_dir().join("tftune_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.json");
    std::fs::write(
        &cfg_path,
        r#"{"model":"transformer-lt","algorithm":"random","iterations":6,"seed":2}"#,
    )
    .unwrap();
    let out = tftune(&["tune", "--config", cfg_path.to_str().unwrap()]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Transformer-LT"), "{text}");
    assert!(text.contains("random-search"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn surrogate_serve_and_two_tuner_processes_share_one_factor() {
    // The cross-process quickstart, end to end with real OS processes:
    // one surrogate service, two BO tuner processes conditioning it.
    let port = 17__557;
    let addr = format!("127.0.0.1:{port}");
    let mut server = Command::new(env!("CARGO_BIN_EXE_tftune"))
        .args(["surrogate-serve", "--addr", &addr])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning surrogate service");
    std::thread::sleep(std::time::Duration::from_millis(400));

    for seed in ["3", "4"] {
        let out = tftune(&[
            "tune",
            "--model",
            "ncf",
            "--alg",
            "bo",
            "--iters",
            "10",
            "--seed",
            seed,
            "--surrogate-addr",
            &addr,
        ]);
        assert!(
            out.status.success(),
            "tuner process (seed {seed}) failed, stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(String::from_utf8_lossy(&out.stdout).contains("best throughput"));
    }
    let _ = server.kill();
    let _ = server.wait();
}

#[test]
fn serve_and_remote_tune_over_tcp() {
    // serve on an ephemeral-ish port; pick one unlikely to clash
    let port = 17__435;
    let addr = format!("127.0.0.1:{port}");
    let mut server = Command::new(env!("CARGO_BIN_EXE_tftune"))
        .args(["serve", "--model", "ncf", "--addr", &addr])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning server");
    std::thread::sleep(std::time::Duration::from_millis(400));

    let out = tftune(&[
        "remote-tune",
        "--addr",
        &addr,
        "--model",
        "ncf",
        "--alg",
        "random",
        "--iters",
        "5",
    ]);
    let _ = server.kill();
    let _ = server.wait();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("best throughput"));
}
