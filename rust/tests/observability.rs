//! The observability plane's accounting, chaos and dashboard contracts:
//!
//! 1. Event accounting: a tuning session with `--events-file` emits
//!    exactly one `trial-issued` and one `trial-measured` per
//!    evaluation, with ids matching the returned `History`, and every
//!    per-source sequence is gap-free and monotone.
//! 2. Bitwise replay: the events file alone reconstructs the session's
//!    regret curve and (for a multi-objective session) its Pareto front
//!    and dominated hypervolume bit-identically — and the session's own
//!    `hypervolume` events carry those same bits.
//! 3. Daemon accounting: a fleet daemon run emits space-create / lease /
//!    sync events matching exactly the requests served.
//! 4. Chaos: a stalled TCP subscriber, a mid-stream disconnect and a
//!    reconnect never block tells — the posterior matches a
//!    no-subscriber run within 1e-9 (bitwise, in fact), overflow is
//!    visible through the `dropped` counter, and the reconnecting
//!    subscriber resumes at the advertised sequence.
//! 5. The dashboard renders live frames from both a file and a socket.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use tftune::algorithms::{Algorithm, BayesOpt};
use tftune::config::TuneConfig;
use tftune::evaluator::Evaluator;
use tftune::gp::{GpHyper, SharedSurrogate};
use tftune::history::Measurement;
use tftune::obs::dashboard::{
    follow_file, follow_socket, replay_history, DashOptions, DashboardState, HV_MARGIN,
};
use tftune::obs::{
    decode_event_record, read_events_file, Event, EventBus, EventPublisher, EventRecord,
    FileSink,
};
use tftune::objectives::{ObjectiveSet, Scalarization};
use tftune::server::proto::{
    decode_obs_hello, decode_surrogate_response, encode_obs_subscribe,
    encode_surrogate_request, SurrogateRequest, SurrogateResponse, PROTOCOL_VERSION,
};
use tftune::server::{FleetOptions, TargetServer};
use tftune::session::{Budget, TuningSession};
use tftune::sim::ModelId;
use tftune::space::{threading_space, Config, ParamDef, SearchSpace};
use tftune::util::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tftune_obs_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Per-source sequences must be 0..n with no gap and no reorder — a gap
/// is a dropped record, and none of these runs is allowed to drop.
fn assert_gap_free(records: &[EventRecord]) {
    let mut next: BTreeMap<&str, u64> = BTreeMap::new();
    for r in records {
        let cursor = next.entry(r.source.as_str()).or_insert(0);
        assert_eq!(
            r.seq, *cursor,
            "source {:?} jumped to seq {} (expected {}): a record was dropped or reordered",
            r.source, r.seq, *cursor
        );
        *cursor += 1;
    }
}

/// Like [`assert_gap_free`] but order-insensitive: concurrent emitters
/// (daemon handler threads) can interleave between taking a sequence
/// number and enqueueing, so only completeness is deterministic there.
fn assert_seqs_complete(records: &[EventRecord]) {
    let mut per_source: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
    for r in records {
        per_source.entry(r.source.as_str()).or_default().push(r.seq);
    }
    for (source, mut seqs) in per_source {
        seqs.sort_unstable();
        let want: Vec<u64> = (0..seqs.len() as u64).collect();
        assert_eq!(seqs, want, "source {source:?} has a sequence gap or duplicate");
    }
}

fn events_of<'a>(records: &'a [EventRecord], kind: &str) -> Vec<&'a EventRecord> {
    records.iter().filter(|r| r.event.kind() == kind).collect()
}

// ---------------------------------------------------------------------------
// 1 + 2 (single-objective): session accounting and regret-curve replay.
// ---------------------------------------------------------------------------

#[test]
fn session_events_account_for_every_evaluation_and_replay_bitwise() {
    let dir = tmp_dir("session");
    let path = dir.join("events.jsonl");
    let cfg = TuneConfig {
        model: ModelId::NcfFp32,
        algorithm: Algorithm::Bo,
        iterations: 18,
        seed: 5,
        events_file: Some(path.clone()),
        ..Default::default()
    };
    let history = cfg.run().unwrap();
    assert_eq!(history.len(), 18);

    let records = read_events_file(&path).unwrap();
    assert_gap_free(&records);

    // Exactly one trial-issued and one trial-measured per evaluation,
    // and the id sets match the history's engine-assigned trial ids.
    let issued = events_of(&records, "trial-issued");
    let measured = events_of(&records, "trial-measured");
    assert_eq!(issued.len(), history.len());
    assert_eq!(measured.len(), history.len());
    let ids = |evs: &[&EventRecord]| -> Vec<u64> {
        let mut ids: Vec<u64> = evs
            .iter()
            .map(|r| match &r.event {
                Event::TrialIssued { trial } | Event::TrialMeasured { trial, .. } => *trial,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        ids.sort_unstable();
        ids
    };
    let mut want: Vec<u64> = history.iter().map(|e| e.trial_id).collect();
    want.sort_unstable();
    assert_eq!(ids(&issued), want, "trial-issued ids diverge from the history");
    assert_eq!(ids(&measured), want, "trial-measured ids diverge from the history");

    // The serial loop asks once per evaluation; every ask-start has its
    // ask-end.
    assert_eq!(events_of(&records, "ask-start").len(), events_of(&records, "ask-end").len());

    // Bitwise replay: the events file alone rebuilds the history —
    // configs, values, costs, trial ids, and therefore the regret curve.
    let replayed = replay_history(&records);
    assert_eq!(replayed.len(), history.len());
    for (a, b) in replayed.iter().zip(history.iter()) {
        assert_eq!(a.trial_id, b.trial_id);
        assert_eq!(a.config, b.config);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(a.cost_s.to_bits(), b.cost_s.to_bits());
    }
    let curve_bits =
        |h: &tftune::History| -> Vec<u64> { h.best_curve().iter().map(|v| v.to_bits()).collect() };
    assert_eq!(
        curve_bits(&replayed),
        curve_bits(&history),
        "the replayed regret curve is not bit-identical"
    );

    // Single-objective front tracking: front-advanced fires exactly on
    // the strict improvements of the best-so-far curve.
    let curve = history.best_curve();
    let strict_improvements = 1 + curve.windows(2).filter(|w| w[1] > w[0]).count();
    assert_eq!(events_of(&records, "front-advanced").len(), strict_improvements);

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 2 (multi-objective): Pareto front + hypervolume replay, bit for bit.
// ---------------------------------------------------------------------------

/// The synthetic bi-objective target from `tests/multi_objective.rs`:
/// `u[0]` trades throughput against p99, the other coordinates penalise
/// both objectives.
struct BiObjectiveTarget {
    space: SearchSpace,
}

impl BiObjectiveTarget {
    fn penalty(u: &[f64]) -> f64 {
        u[1..].iter().map(|&v| (v - 0.75) * (v - 0.75)).sum::<f64>()
    }
}

impl Evaluator for BiObjectiveTarget {
    fn evaluate(&mut self, config: &Config) -> anyhow::Result<f64> {
        let u = self.space.to_unit(config);
        Ok(10.0 * u[0] + 5.0 - 4.0 * Self::penalty(&u))
    }

    fn measure(&mut self, config: &Config) -> anyhow::Result<Measurement> {
        let u = self.space.to_unit(config);
        let tp = 10.0 * u[0] + 5.0 - 4.0 * Self::penalty(&u);
        let p99 = 2.0 + 8.0 * u[0] * u[0] + 4.0 * Self::penalty(&u);
        Ok(Measurement::new(tp).with_cost_s(0.001).with_metadata("p99", p99))
    }

    fn describe(&self) -> String {
        "synthetic-bi-objective".into()
    }
}

#[test]
fn multi_objective_events_replay_front_and_hypervolume_bitwise() {
    let dir = tmp_dir("pareto");
    let path = dir.join("events.jsonl");
    let space = threading_space(64, 1024, 64);
    let set = ObjectiveSet::parse("throughput,p99:min").unwrap();
    let bus = EventBus::new();
    bus.attach(Box::new(FileSink::create(&path).unwrap()));
    let tuner = Box::new(
        BayesOpt::new(space.clone(), 23).with_objectives(set.clone(), Scalarization::Smsego),
    );
    let mut session = TuningSession::new(
        tuner,
        vec![Box::new(BiObjectiveTarget { space })],
        Budget::evaluations(25),
    )
    .with_objectives(set)
    .with_events(bus.source("session"));
    let history = session.run().unwrap();
    bus.flush();
    assert_eq!(bus.dropped(), 0, "a local file sink must never drop");

    let records = read_events_file(&path).unwrap();
    assert_gap_free(&records);

    // The replayed history reproduces the live Pareto front exactly.
    let replayed = replay_history(&records);
    let front_ids = |h: &tftune::History| -> Vec<u64> {
        h.pareto_front().iter().map(|e| e.trial_id).collect()
    };
    assert_eq!(front_ids(&replayed), front_ids(&history), "replayed Pareto front diverged");

    // And the dominated hypervolume, bit for bit — from the file alone.
    let hv_live = history.hypervolume_auto(HV_MARGIN).expect("live hv");
    let hv_replay = replayed.hypervolume_auto(HV_MARGIN).expect("replayed hv");
    assert_eq!(hv_live.to_bits(), hv_replay.to_bits(), "replayed hypervolume is not bit-identical");

    // Every measurement restated the hypervolume; the last emission
    // carries the final value's exact bits.
    let hv_events = events_of(&records, "hypervolume");
    assert_eq!(hv_events.len(), history.len());
    let Event::Hypervolume { hv } = hv_events.last().unwrap().event else { unreachable!() };
    assert_eq!(hv.to_bits(), hv_live.to_bits(), "the hypervolume event stream drifted");

    // The last front-advanced event's size matches the live front.
    let fronts = events_of(&records, "front-advanced");
    assert!(!fronts.is_empty(), "a 25-trial Pareto session never advanced its front");
    let Event::FrontAdvanced { front_size, .. } = fronts.last().unwrap().event else {
        unreachable!()
    };
    assert_eq!(front_size, history.pareto_front().len());

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 3: daemon accounting — space lifecycle, leases, served syncs.
// ---------------------------------------------------------------------------

struct Raw {
    s: TcpStream,
    r: BufReader<TcpStream>,
}

impl Raw {
    fn connect(addr: SocketAddr) -> Raw {
        let s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let r = BufReader::new(s.try_clone().unwrap());
        Raw { s, r }
    }

    fn send(&mut self, req: &SurrogateRequest) {
        writeln!(self.s, "{}", encode_surrogate_request(req)).unwrap();
    }

    fn roundtrip(&mut self, req: &SurrogateRequest) -> SurrogateResponse {
        self.send(req);
        let mut line = String::new();
        self.r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "daemon hung up");
        decode_surrogate_response(line.trim_end()).unwrap()
    }

    fn hello(&mut self, space: &SearchSpace) {
        match self.roundtrip(&SurrogateRequest::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: Some(space.fingerprint()),
            dim: Some(space.dim()),
        }) {
            SurrogateResponse::HelloOk { .. } => {}
            other => panic!("hello refused: {other:?}"),
        }
    }

    /// Unbounded sync — the barrier that proves preceding tells landed.
    fn sync(&mut self) -> usize {
        match self.roundtrip(&SurrogateRequest::SyncFactor {
            from_n: 0,
            max_rows: None,
            quantise: false,
        }) {
            SurrogateResponse::FactorDelta { delta, .. } => delta.total_n,
            other => panic!("unexpected sync response: {other:?}"),
        }
    }
}

fn shutdown_daemon(addr: SocketAddr) {
    use tftune::server::proto::{encode_request, Request};
    let space = threading_space(64, 1024, 64);
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = writeln!(s, "{}", encode_request(&Request::Shutdown, &space));
    }
}

/// Poll `read_events_file` until `pred` holds (the daemon's handler
/// threads race the test on connection-close events).
fn wait_for_events(
    bus: &EventBus,
    path: &std::path::Path,
    pred: impl Fn(&[EventRecord]) -> bool,
) -> Vec<EventRecord> {
    for _ in 0..2000 {
        bus.flush();
        let records = read_events_file(path).unwrap();
        if pred(&records) {
            return records;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("daemon events never reached the expected state");
}

#[test]
fn daemon_events_match_served_requests_exactly() {
    let dir = tmp_dir("daemon");
    let path = dir.join("daemon_events.jsonl");
    let bus = EventBus::new();
    bus.attach(Box::new(FileSink::create(&path).unwrap()));

    let (server, _factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let server = server
        .with_fleet_options(FleetOptions::default())
        .unwrap()
        .with_events(bus.source("daemon"));
    let (addr, handle) = server.spawn().unwrap();

    let space_a = SearchSpace::new(vec![ParamDef::new("oa0", 1, 32, 1), ParamDef::new("oa1", 1, 32, 1)]);
    let space_b = SearchSpace::new(vec![
        ParamDef::new("ob0", 1, 32, 1),
        ParamDef::new("ob1", 1, 32, 1),
        ParamDef::new("ob2", 1, 32, 1),
    ]);

    let mut rng = Rng::new(31);
    let mut c1 = Raw::connect(addr);
    c1.hello(&space_a); // lazily creates space A
    let n_a = 5usize;
    for _ in 0..n_a {
        c1.send(&SurrogateRequest::TellObs {
            x: (0..space_a.dim()).map(|_| rng.f64()).collect(),
            y: rng.f64(),
            ys: Vec::new(),
        });
    }
    assert_eq!(c1.sync(), n_a); // barrier + one served sync-factor

    // Two leases on this connection: the first is retracted explicitly,
    // the second expires when the connection dies.
    let lease_points = |k: usize, rng: &mut Rng| -> Vec<(Vec<f64>, f64)> {
        (0..k).map(|_| ((0..2).map(|_| rng.f64()).collect(), 0.0)).collect()
    };
    let id1 = match c1.roundtrip(&SurrogateRequest::AskLease { points: lease_points(2, &mut rng) })
    {
        SurrogateResponse::Lease { id } => id,
        other => panic!("unexpected lease response: {other:?}"),
    };
    match c1.roundtrip(&SurrogateRequest::RetractLease { id: id1 }) {
        SurrogateResponse::LeaseOk { .. } | SurrogateResponse::HyperOk => {}
        other => panic!("unexpected retract response: {other:?}"),
    }
    match c1.roundtrip(&SurrogateRequest::AskLease { points: lease_points(1, &mut rng) }) {
        SurrogateResponse::Lease { .. } => {}
        other => panic!("unexpected lease response: {other:?}"),
    }
    drop(c1); // the unretracted lease expires on close

    let mut c2 = Raw::connect(addr);
    c2.hello(&space_b); // lazily creates space B
    assert_eq!(c2.sync(), 0); // second served sync-factor
    drop(c2);

    // Expect 2 spaces created, 2 leases published, 2 leases expired
    // (one retract, one connection close), 2 served syncs.
    let records = wait_for_events(&bus, &path, |recs| {
        let expired: usize = recs
            .iter()
            .filter_map(|r| match r.event {
                Event::LeaseExpired { leases } => Some(leases),
                _ => None,
            })
            .sum();
        expired >= 2
    });
    shutdown_daemon(addr);
    let _ = handle.join();

    assert_seqs_complete(&records);
    assert!(records.iter().all(|r| r.source == "daemon"));

    let created: Vec<(u64, usize)> = records
        .iter()
        .filter_map(|r| match r.event {
            Event::SpaceCreated { fingerprint, dim } => Some((fingerprint, dim)),
            _ => None,
        })
        .collect();
    assert_eq!(
        created,
        vec![(space_a.fingerprint(), 2), (space_b.fingerprint(), 3)],
        "space-created events diverge from the hellos served"
    );

    let published: Vec<usize> = records
        .iter()
        .filter_map(|r| match r.event {
            Event::LeasePublished { points, .. } => Some(points),
            _ => None,
        })
        .collect();
    assert_eq!(published, vec![2, 1], "lease-published events diverge from the ask-leases");

    let expired: usize = records
        .iter()
        .filter_map(|r| match r.event {
            Event::LeaseExpired { leases } => Some(leases),
            _ => None,
        })
        .sum();
    assert_eq!(expired, 2, "one retract + one connection close must expire 2 leases");

    let syncs: Vec<usize> = records
        .iter()
        .filter_map(|r| match &r.event {
            Event::SyncFactor { rows, bytes, .. } => {
                assert!(*bytes > 0, "a served sync crossed zero wire bytes");
                Some(*rows)
            }
            _ => None,
        })
        .collect();
    assert_eq!(syncs, vec![n_a, 0], "served sync-factor events diverge from the syncs");

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 4: chaos — stalled subscriber, disconnect, overflow, resume.
// ---------------------------------------------------------------------------

/// Connect a subscriber, perform the handshake, return the socket (kept
/// open, unread — the stall) plus the decoded hello.
fn subscribe(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>, u64, Vec<(String, u64)>) {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut w = s.try_clone().unwrap();
    writeln!(w, "{}", encode_obs_subscribe()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut hello = String::new();
    r.read_line(&mut hello).unwrap();
    let (dropped, seqs) = decode_obs_hello(hello.trim_end()).unwrap();
    (s, r, dropped, seqs)
}

#[test]
fn stalled_and_dying_subscribers_never_block_tells_and_reconnects_resume() {
    let bus = EventBus::new();
    // A 1-slot per-subscriber queue: once the stalled socket's send
    // buffer fills, the writer thread blocks and the very next event
    // overflows the queue into the dropped counter.
    let publisher = EventPublisher::bind_with_queue("127.0.0.1:0", &bus, 1).unwrap();

    let observed = SharedSurrogate::new(GpHyper::default());
    observed.set_event_source(bus.source("surrogate"));
    let clean = SharedSurrogate::new(GpHyper::default());

    // Subscriber A handshakes, then never reads again: the stall.
    let (stalled_sock, _stalled_reader, dropped0, _) = subscribe(publisher.addr());
    assert_eq!(dropped0, 0);
    std::thread::sleep(Duration::from_millis(50)); // let the sink attach

    // Ballast: fat records (large config payloads) wedge the stalled
    // subscriber's socket buffer far faster than surrogate-tell lines
    // would, making the overflow deterministic.
    let ballast = bus.source("ballast");
    let fat = Event::TrialMeasured {
        trial: 0,
        config: vec![7; 8192],
        value: 1.0,
        cost_s: 0.0,
        objectives: Vec::new(),
    };

    let mut rng = Rng::new(99);
    let d = 4usize;
    let obs: Vec<(Vec<f64>, f64)> = (0..48)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = (2.0 * x[0]).sin() - x[3];
            (x, y)
        })
        .collect();

    let t0 = Instant::now();
    for (i, (x, y)) in obs.iter().enumerate() {
        observed.tell(x.clone(), *y);
        clean.tell(x.clone(), *y);
        ballast.emit(fat.clone());
        if i % 8 == 7 {
            // Drains must be as unblockable as tells.
            drop(observed.lock());
            drop(clean.lock());
        }
    }
    for _ in 0..256 {
        ballast.emit(fat.clone());
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(20),
        "tells stalled behind a wedged subscriber ({elapsed:?})"
    );

    // Posterior parity with the no-subscriber run: bit-identical (a
    // fortiori within the 1e-9 acceptance bound).
    drop(observed.lock());
    drop(clean.lock());
    let bits = |s: &SharedSurrogate| -> Vec<u64> {
        s.export_delta(0)
            .unwrap()
            .factor
            .expect("factor present")
            .iter()
            .map(|v| v.to_bits())
            .collect()
    };
    assert_eq!(
        bits(&observed),
        bits(&clean),
        "an observed surrogate diverged from the unobserved baseline"
    );

    // The overflow is visible: the stalled subscriber cost drops.
    bus.flush();
    assert!(bus.dropped() > 0, "a 1-slot queue behind a stalled socket must drop");

    // Mid-stream disconnect: kill the stalled socket, keep telling.
    drop(stalled_sock);
    for (x, y) in &obs[..8] {
        observed.tell(x.clone(), *y);
        clean.tell(x.clone(), *y);
    }
    drop(observed.lock());
    drop(clean.lock());
    assert_eq!(bits(&observed), bits(&clean), "a dying subscriber corrupted the stream source");

    // Reconnect: the hello advertises the cumulative drop counter and
    // the current per-source next sequences — the resume point.
    let (sock_b, mut reader_b, dropped_b, seqs_b) = subscribe(publisher.addr());
    assert!(dropped_b > 0, "the reconnect hello must carry the cumulative drop counter");
    let advertised = seqs_b
        .iter()
        .find(|(name, _)| name == "surrogate")
        .map(|(_, next)| *next)
        .expect("the hello must list the surrogate source");
    let current = bus
        .source_seqs()
        .into_iter()
        .find(|(name, _)| name == "surrogate")
        .map(|(_, next)| next)
        .unwrap();
    assert_eq!(advertised, current, "the hello's resume point is stale");

    // The next surrogate record it receives resumes at (or past — the
    // attach can race one emission) the advertised sequence, and a
    // hello-seeded dashboard reads no false gap from the skipped prefix.
    let mut state = DashboardState::new();
    state.seed_seqs(&seqs_b);
    std::thread::sleep(Duration::from_millis(50));
    let mut resumed = None;
    'outer: for (x, y) in obs.iter().cycle().take(50) {
        observed.tell(x.clone(), *y);
        bus.flush();
        loop {
            let mut line = String::new();
            match reader_b.read_line(&mut line) {
                Ok(0) => panic!("publisher hung up on the reconnected subscriber"),
                Ok(_) => {
                    let rec = decode_event_record(line.trim_end()).unwrap();
                    state.apply(&rec);
                    if rec.source == "surrogate" {
                        resumed = Some(rec.seq);
                        break 'outer;
                    }
                }
                Err(_) => break, // timeout this round: emit again
            }
        }
    }
    let resumed = resumed.expect("the reconnected subscriber never received a surrogate record");
    assert!(
        resumed >= advertised,
        "resumed at seq {resumed}, before the advertised {advertised}"
    );
    assert_eq!(
        state.seq_gaps,
        resumed - advertised,
        "hello seeding must suppress the skipped prefix as false gaps"
    );
    drop(sock_b);
    drop(publisher);
}

// ---------------------------------------------------------------------------
// 5: the dashboard renders live from both sources.
// ---------------------------------------------------------------------------

#[test]
fn dashboard_renders_live_from_file_and_socket() {
    // File: one --once frame over a recorded stream.
    let dir = tmp_dir("dash");
    let path = dir.join("events.jsonl");
    let cfg = TuneConfig {
        model: ModelId::NcfFp32,
        algorithm: Algorithm::Random,
        iterations: 6,
        seed: 1,
        events_file: Some(path.clone()),
        ..Default::default()
    };
    let history = cfg.run().unwrap();
    let mut out = Vec::new();
    follow_file(&path, &DashOptions { once: true, ..DashOptions::default() }, &mut out).unwrap();
    let frame = String::from_utf8(out).unwrap();
    assert!(frame.contains("tftune dashboard"), "{frame}");
    assert!(frame.contains("measured"), "{frame}");
    assert!(!frame.contains('\u{1b}'), "--once frames must be plain text");
    let best = history.best().unwrap().value;
    assert!(frame.contains(&format!("{best:.6}")), "best value missing from: {frame}");

    // Socket: a live publisher feeds a bounded follow_socket session.
    let bus = EventBus::new();
    let publisher = EventPublisher::bind("127.0.0.1:0", &bus).unwrap();
    let addr = publisher.addr().to_string();
    let src = bus.source("session");
    let feeder = std::thread::spawn(move || {
        for i in 0..60u64 {
            src.emit(Event::TrialIssued { trial: i });
            src.emit(Event::TrialMeasured {
                trial: i,
                config: vec![1, 2],
                value: i as f64,
                cost_s: 0.0,
                objectives: Vec::new(),
            });
            std::thread::sleep(Duration::from_millis(20));
        }
    });
    let mut out = Vec::new();
    let state = follow_socket(
        &addr,
        &DashOptions { refresh_ms: 50, once: false, max_seconds: Some(1.0) },
        &mut out,
    )
    .unwrap();
    feeder.join().unwrap();
    assert!(state.measured > 0, "the live dashboard saw no measurements over the socket");
    assert_eq!(state.seq_gaps, 0);
    let live = String::from_utf8(out).unwrap();
    assert!(live.contains("tftune dashboard"), "no frame rendered");
    assert!(live.contains('\u{1b}'), "live frames must clear the screen");
    drop(publisher);

    std::fs::remove_dir_all(&dir).ok();
}
