//! Sharded-surrogate pins (ISSUE 9): the contracts the scaling tier
//! rests on, end to end through the public API.
//!
//! - **One shard is the exact engine, bitwise.** With `shard_cap >= n`
//!   the KD tree never splits and every call delegates verbatim to the
//!   single inner `IncrementalGp` — pinned to the bit over a trajectory
//!   that interleaves pushes, constant-liar fantasies, retractions,
//!   target swaps, multi-objective panels and predictions.
//! - **The blended posterior tracks the exact posterior.** Multi-shard
//!   predictions stay close to the full exact GP (documented tolerance
//!   at each assertion), and the blended std never undercuts the exact
//!   std — conditioning on a *subset* of the data can only widen a GP
//!   posterior, and the variance-weighted blend preserves that floor.
//! - **BO quality survives sharding.** At n = 256 on the simulator, BO
//!   driven by the sharded tier lands within 10% of exact BO's best
//!   (mean over 3 seeds).
//! - **Tell cost is bounded.** Far past the cap, per-tell time stays
//!   flat and the ensemble's factor storage is O(n·cap), nowhere near
//!   the flat engine's O(n²) triangle.
//! - **Conversion re-tiers in place.** `convert_to_sharded` keeps the
//!   store, splits it into shards, stays idempotent, and the handle
//!   keeps draining tells afterwards.

use std::time::{Duration, Instant};

use tftune::algorithms::BayesOpt;
use tftune::evaluator::{tune, SimEvaluator};
use tftune::gp::{GpHyper, IncrementalGp, ScoreWorkspace, SharedSurrogate, ShardedGp};
use tftune::server::FactorTier;
use tftune::sim::ModelId;
use tftune::util::linalg::packed_len;
use tftune::util::{stats, Rng};

fn random_row(rng: &mut Rng, d: usize) -> Vec<f64> {
    (0..d).map(|_| rng.f64()).collect()
}

/// A smooth deterministic surface with strong variation over the unit
/// cube — both engines should reconstruct it, so their posteriors are
/// comparable candidate by candidate.
fn surface(x: &[f64]) -> f64 {
    let mut v = 0.0;
    for (k, &xi) in x.iter().enumerate() {
        let c = 0.25 + 0.4 * (k as f64 % 2.0);
        v += (2.0 + k as f64) * (xi - c) * (xi - c);
    }
    3.0 - v
}

/// (a) `shard_cap >= n` keeps one shard, and one shard IS the exact
/// engine: every output bit-identical over a pinned trajectory.
#[test]
fn single_shard_is_bitwise_identical_to_exact() {
    let d = 4;
    let c = 32;
    let mut exact = IncrementalGp::new(GpHyper::default());
    let mut sharded = ShardedGp::new(GpHyper::default(), 10_000, 2);
    assert_eq!(sharded.num_shards(), 1);

    let mut rng = Rng::new(0x5AD1);
    let cand: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();
    let mut ws_e = ScoreWorkspace::default();
    let mut ws_s = ScoreWorkspace::default();

    for step in 0..48 {
        let x = random_row(&mut rng, d);
        let yv = surface(&x) + 0.05 * rng.f64();
        assert_eq!(exact.push(&x, yv), sharded.push(&x, yv), "push diverged at {step}");

        if step % 5 == 3 {
            // Constant-liar fantasies ride the same routed path.
            let fx = random_row(&mut rng, d);
            assert_eq!(exact.extend_fantasy(&fx, 0.25), sharded.extend_fantasy(&fx, 0.25));
        }

        exact.score_into(&cand, c, 1.5, 0.3, &mut ws_e);
        sharded.score_into(&cand, c, 1.5, 0.3, &mut ws_s);
        for j in 0..c {
            assert_eq!(
                ws_e.mean[j].to_bits(),
                ws_s.mean[j].to_bits(),
                "mean diverged at step {step}, candidate {j}"
            );
            assert_eq!(
                ws_e.std[j].to_bits(),
                ws_s.std[j].to_bits(),
                "std diverged at step {step}, candidate {j}"
            );
            assert_eq!(
                ws_e.gain[j].to_bits(),
                ws_s.gain[j].to_bits(),
                "gain diverged at step {step}, candidate {j}"
            );
        }

        exact.retract_fantasies();
        sharded.retract_fantasies();
    }

    // Installed-target swap (the multi-objective ask path), same bits.
    let n = exact.total();
    assert_eq!(sharded.total(), n);
    let alt: Vec<f64> = (0..n).map(|i| 0.01 * i as f64 - 0.2).collect();
    exact.set_targets(&alt);
    sharded.set_targets(&alt);
    exact.score_into(&cand, c, 0.0, 0.0, &mut ws_e);
    sharded.score_into(&cand, c, 0.0, 0.0, &mut ws_s);
    for j in 0..c {
        assert_eq!(ws_e.mean[j].to_bits(), ws_s.mean[j].to_bits(), "post-swap mean {j}");
    }

    // K-objective panel, same bits.
    let t2: Vec<f64> = (0..n).map(|i| (i as f64 * 0.07) - 1.0).collect();
    let refs: Vec<&[f64]> = vec![&alt, &t2];
    exact.score_multi_into(&cand, c, &refs, &mut ws_e);
    sharded.score_multi_into(&cand, c, &refs, &mut ws_s);
    assert_eq!(ws_e.n_obj, ws_s.n_obj);
    for k in 0..2 {
        for j in 0..c {
            assert_eq!(
                ws_e.mean_obj[k * c + j].to_bits(),
                ws_s.mean_obj[k * c + j].to_bits(),
                "objective {k} mean diverged at candidate {j}"
            );
        }
    }

    // Posterior entry point, same bits.
    let pts: Vec<Vec<f64>> = (0..8).map(|_| random_row(&mut rng, d)).collect();
    let pe = exact.predict(&pts);
    let ps = sharded.predict(&pts);
    for j in 0..pts.len() {
        assert_eq!(pe.mean[j].to_bits(), ps.mean[j].to_bits(), "predict mean {j}");
        assert_eq!(pe.std[j].to_bits(), ps.std[j].to_bits(), "predict std {j}");
    }

    assert_eq!(sharded.num_shards(), 1, "cap >= n must never split");
}

/// (b) Multi-shard posterior vs the full exact GP at n = 256.
///
/// Documented tolerances:
/// - means: normalised RMSE <= 0.3 — the blended mean must track the
///   exact posterior to well under a third of that posterior's own
///   cross-candidate spread. A broken router or blend (near-prior or
///   shuffled means) sits at nRMSE ≈ 1 and fails loudly; the gPoE
///   approximation with local shards sits far below the bound.
/// - stds: `blend >= 0.999 × exact` everywhere. Each shard conditions
///   on a subset of the data, so its variance dominates the exact GP's
///   (GP posterior variance is non-increasing under added data), and
///   the variance-weighted blend cannot go below its narrowest member;
///   the 0.1% margin absorbs floating-point noise only. Upward, a
///   generous factor bounds gross mis-blends.
#[test]
fn blended_posterior_tracks_exact_posterior() {
    let (d, n, cap) = (2usize, 256usize, 48usize);
    let mut rng = Rng::new(0xB1E7D);
    let mut exact = IncrementalGp::new(GpHyper::default());
    let mut sharded = ShardedGp::new(GpHyper::default(), cap, 2);
    for _ in 0..n {
        let x = random_row(&mut rng, d);
        let y = surface(&x);
        assert!(exact.push(&x, y));
        assert!(sharded.push(&x, y));
    }
    assert!(sharded.num_shards() >= 4, "{n} rows at cap {cap} must split repeatedly");
    assert!(sharded.max_shard_rows() <= cap, "a split leaf may not exceed the cap");

    let pts: Vec<Vec<f64>> = (0..96)
        .map(|_| (0..d).map(|_| 0.05 + 0.9 * rng.f64()).collect())
        .collect();
    let pe = exact.predict(&pts);
    let ps = sharded.predict(&pts);

    let mean_of = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let centre = mean_of(&pe.mean);
    let spread =
        (mean_of(&pe.mean.iter().map(|m| (m - centre) * (m - centre)).collect::<Vec<_>>()))
            .sqrt();
    assert!(spread > 1e-3, "exact posterior is flat — the property test would be vacuous");

    let mut sq = 0.0;
    for j in 0..pts.len() {
        assert!(ps.mean[j].is_finite() && ps.std[j].is_finite(), "non-finite blend at {j}");
        assert!(ps.std[j] > 0.0, "non-positive blended std at {j}");
        assert!(
            ps.std[j] >= 0.999 * pe.std[j],
            "blended std {} undercut exact {} at candidate {j}",
            ps.std[j],
            pe.std[j]
        );
        assert!(
            ps.std[j] <= 20.0 * pe.std[j] + 1.0,
            "blended std {} implausibly wide vs exact {} at candidate {j}",
            ps.std[j],
            pe.std[j]
        );
        let dm = ps.mean[j] - pe.mean[j];
        sq += dm * dm;
    }
    let nrmse = (sq / pts.len() as f64).sqrt() / spread;
    assert!(nrmse <= 0.3, "blended mean nRMSE {nrmse:.3} exceeds the documented 0.3");
}

/// (c) End-to-end BO on the simulator: at n = 256 the sharded tier's
/// best-found stays within 10% of exact BO's (mean over 3 seeds). The
/// cap of 64 forces real sharding well before the budget ends.
#[test]
fn sharded_bo_regret_within_ten_percent_of_exact() {
    let model = ModelId::NcfFp32;
    let space = model.space();
    let mut exact_best = Vec::new();
    let mut sharded_best = Vec::new();
    for seed in [1u64, 2, 3] {
        let mut bo = BayesOpt::new(space.clone(), seed).with_candidates(128);
        let mut eval = SimEvaluator::new(model, seed);
        let h = tune(&mut bo, &mut eval, 256).unwrap();
        exact_best.push(h.best().unwrap().value);

        let handle = SharedSurrogate::new_sharded(GpHyper::default(), 64, 2);
        let mut bo = BayesOpt::new(space.clone(), seed)
            .with_shared_surrogate(handle.clone())
            .with_candidates(128);
        let mut eval = SimEvaluator::new(model, seed);
        let h = tune(&mut bo, &mut eval, 256).unwrap();
        sharded_best.push(h.best().unwrap().value);

        assert!(handle.is_sharded(), "the handle must stay on the sharded tier");
        assert!(
            handle.num_shards().unwrap_or(0) > 1,
            "256 observations at cap 64 must have split (seed {seed})"
        );
    }
    let me = stats::mean(&exact_best);
    let ms = stats::mean(&sharded_best);
    assert!(
        ms >= 0.9 * me,
        "sharded BO mean best {ms:.1} fell more than 10% below exact BO's {me:.1} \
         (per seed: sharded {sharded_best:?} vs exact {exact_best:?})"
    );
}

/// (d) Tell-cost boundedness far past the cap: factor storage is
/// O(n·cap) — deterministic, the real teeth — and a late batch of tells
/// costs about what an early batch did (loose wall-clock guard; an
/// accidental O(n²)-per-tell engine would be ~40× slower here).
#[test]
fn tell_cost_stays_bounded_far_past_the_cap() {
    let (d, cap, n) = (3usize, 32usize, 1000usize);
    let mut gp = ShardedGp::new(GpHyper::default(), cap, 2);
    let mut rng = Rng::new(0xB0);
    let push_one = |gp: &mut ShardedGp, rng: &mut Rng| {
        let x = random_row(rng, d);
        let y = surface(&x);
        assert!(gp.push(&x, y), "random shard factor must stay positive definite");
    };

    for _ in 0..100 {
        push_one(&mut gp, &mut rng);
    }
    let t0 = Instant::now();
    for _ in 0..100 {
        push_one(&mut gp, &mut rng); // rows 100..200
    }
    let early = t0.elapsed();
    for _ in 0..700 {
        push_one(&mut gp, &mut rng); // rows 200..900
    }
    let t1 = Instant::now();
    for _ in 0..100 {
        push_one(&mut gp, &mut rng); // rows 900..1000
    }
    let late = t1.elapsed();

    assert_eq!(gp.len(), n);
    assert!(gp.max_shard_rows() <= cap, "no leaf may end past the cap on spread-y data");
    assert!(
        gp.num_shards() >= n / cap,
        "{} shards cannot each hold <= {cap} of {n} rows",
        gp.num_shards()
    );
    // Every shard of m <= cap rows stores m(m+1)/2 <= m(cap+1)/2 factor
    // entries, so the ensemble is <= n(cap+1)/2 — at n = 1000, cap = 32
    // that is 16.5k entries vs the flat triangle's 500.5k.
    let bound = n * (cap + 1) / 2;
    assert!(
        gp.factor_entries() <= bound,
        "factor holds {} entries, past the O(n·cap) bound {bound}",
        gp.factor_entries()
    );
    assert!(
        gp.factor_entries() * 8 < packed_len(n),
        "factor ({} entries) should be at least 8× below the flat O(n²) triangle ({})",
        gp.factor_entries(),
        packed_len(n)
    );
    assert!(
        late <= early * 8 + Duration::from_millis(20),
        "per-tell cost grew: rows 900..1000 took {late:?} vs {early:?} for rows 100..200"
    );
}

/// (e) `convert_to_sharded` re-tiers a live handle in place: the store
/// survives, shards form, the call is idempotent, and tells keep
/// draining afterwards. Also pins the daemon's tier-flag spellings.
#[test]
fn convert_to_sharded_re_tiers_in_place() {
    let shared = SharedSurrogate::new(GpHyper::default());
    let mut rng = Rng::new(7);
    let tell_one = |shared: &SharedSurrogate, rng: &mut Rng| {
        let x = random_row(rng, 3);
        let y = surface(&x);
        shared.tell(x, y);
    };
    for _ in 0..96 {
        tell_one(&shared, &mut rng);
    }
    drop(shared.lock()); // drain into the flat factor
    assert!(!shared.is_sharded());
    assert_eq!(shared.num_shards(), None);

    shared.convert_to_sharded(24, 2);
    assert!(shared.is_sharded());
    assert_eq!(shared.len(), 96, "conversion must keep every observation");
    assert!(shared.num_shards().unwrap() > 1, "96 rows at cap 24 must split");

    let before = shared.num_shards();
    shared.convert_to_sharded(24, 2); // idempotent: second call is a no-op
    assert_eq!(shared.num_shards(), before);

    for _ in 0..32 {
        tell_one(&shared, &mut rng);
    }
    drop(shared.lock());
    assert_eq!(shared.len(), 128, "a converted store must keep draining tells");

    // The surrogate-serve tier policy spellings.
    assert_eq!(FactorTier::parse("auto"), Some(FactorTier::Auto));
    assert_eq!(FactorTier::parse("exact"), Some(FactorTier::Exact));
    assert_eq!(FactorTier::parse("native"), Some(FactorTier::Exact));
    assert_eq!(FactorTier::parse("sharded"), Some(FactorTier::Sharded));
    assert_eq!(FactorTier::parse("made-up"), None);
}
