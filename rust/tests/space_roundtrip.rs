//! Grid round-trip property suite for `space.rs`: for every `ParamDef` —
//! including ranges where `(max - min)` is not a multiple of `step` —
//! value→index→unit-cube→value is the identity, and `n_values` matches
//! what iteration actually produces.

use tftune::space::{ParamDef, SearchSpace};
use tftune::util::prop;

fn random_param(rng: &mut tftune::util::Rng, name: &str) -> ParamDef {
    let min = prop::int_biased(rng, -100, 100);
    let span = rng.range_i64(0, 400);
    let step = rng.range_i64(1, 37);
    // Deliberately allow span % step != 0: the top of the range is then
    // unreachable and the last grid point sits below `max`.
    ParamDef::new(name, min, min + span, step)
}

#[test]
fn prop_value_index_unit_round_trips() {
    prop::check("param round trips", 300, |rng| {
        let p = random_param(rng, "p");
        let n = p.n_values();
        assert!(n >= 1);
        let mut prev: Option<i64> = None;
        for i in 0..n {
            let v = p.value_at(i);
            // grid values stay inside the declared range…
            assert!(v >= p.min && v <= p.max, "{v} outside [{}, {}]", p.min, p.max);
            // …ascend in exact step increments…
            if let Some(pv) = prev {
                assert_eq!(v - pv, p.step, "non-uniform step at index {i}");
            }
            prev = Some(v);
            // value → index is the inverse of value_at
            assert_eq!(((v - p.min) / p.step) as usize, i);
            // grid values are fixed points of snap
            assert_eq!(p.snap(v), v);
            // value → unit cube → value is the identity
            let u = p.to_unit(v);
            assert!((0.0..=1.0).contains(&u), "unit coord {u} out of range");
            assert_eq!(p.from_unit(u), v, "unit round trip broke at index {i} (u={u})");
        }
        // value_at clamps past the end instead of leaving the grid
        assert_eq!(p.value_at(n), p.value_at(n - 1));
        // the reachable top of the grid, not necessarily `max`
        let top = p.value_at(n - 1);
        assert!(p.max - top < p.step, "top grid value {top} leaves a full step unused");
    });
}

#[test]
fn prop_n_values_matches_iteration_count() {
    prop::check("n_values vs grid iteration", 60, |rng| {
        // Small multi-param spaces (product capped so iteration stays fast).
        let mut params = Vec::new();
        let dims = 1 + rng.index(3);
        for k in 0..dims {
            let min = prop::int_biased(rng, -20, 20);
            let span = rng.range_i64(0, 30);
            let step = rng.range_i64(1, 7);
            params.push(ParamDef::new(&format!("p{k}"), min, min + span, step));
        }
        let space = SearchSpace::new(params);
        let want: u128 = space.params.iter().map(|p| p.n_values() as u128).product();
        assert_eq!(space.size(), want);
        let all: Vec<_> = space.grid().collect();
        assert_eq!(all.len() as u128, want, "grid iteration count != n_values product");
        // every iterated config round-trips through the unit cube
        for cfg in &all {
            assert!(space.contains(cfg));
            assert_eq!(space.from_unit(&space.to_unit(cfg)), *cfg);
        }
        // all configs distinct
        let mut uniq = all.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), all.len(), "grid iterator repeated a config");
    });
}

#[test]
fn non_divisible_range_round_trips_exhaustively() {
    // The satellite's named edge case, pinned concretely: 10-wide range
    // with step 3 → grid {0, 3, 6, 9}, max 10 unreachable.
    let p = ParamDef::new("odd", 0, 10, 3);
    assert_eq!(p.n_values(), 4);
    let values: Vec<i64> = (0..p.n_values()).map(|i| p.value_at(i)).collect();
    assert_eq!(values, vec![0, 3, 6, 9]);
    for v in values {
        assert_eq!(p.from_unit(p.to_unit(v)), v);
    }
    // off-grid raw values snap to the nearest reachable point
    assert_eq!(p.snap(10), 9);
    assert_eq!(p.from_unit(1.0), 9);
}
