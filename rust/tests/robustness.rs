//! Robustness / failure-injection integration tests: malformed inputs,
//! dying peers, pathological measurements, degenerate spaces.

use tftune::algorithms::{Algorithm, Tuner};
use tftune::evaluator::{tune, Evaluator, RemoteEvaluator, SimEvaluator};
use tftune::history::Measurement;
use tftune::server::TargetServer;
use tftune::sim::ModelId;
use tftune::space::{Config, ParamDef, SearchSpace};
use tftune::util::json;
use tftune::util::prop;
use tftune::util::Rng;

/// The JSON parser must never panic, whatever bytes arrive (a hostile or
/// broken host could send anything to the target daemon).
#[test]
fn json_parser_never_panics_on_fuzz() {
    prop::check("json fuzz", 500, |rng| {
        let len = rng.index(60);
        let chars: Vec<u8> = (0..len)
            .map(|_| {
                // mix of structural chars, digits, quotes and junk
                let pool = b"{}[]\",:0123456789.eE+-truefalsnl \\\t\n\x7f";
                pool[rng.index(pool.len())]
            })
            .collect();
        let s = String::from_utf8_lossy(&chars).to_string();
        let _ = json::parse(&s); // must return, not panic
    });
}

/// Valid JSON round-trips through the parser+serializer under fuzz.
#[test]
fn json_generated_documents_round_trip() {
    fn gen(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.bool(0.5)),
            2 => json::Json::Num((rng.range_i64(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => json::Json::Str(format!("s{}\"\\\n{}", rng.next_u64() % 100, rng.index(10))),
            4 => json::Json::Arr((0..rng.index(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.index(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop::check("json round trip fuzz", 300, |rng| {
        let doc = gen(rng, 3);
        let text = doc.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e} on {text}"));
        assert_eq!(doc, back, "round trip mismatch for {text}");
    });
}

/// NaN from the system under test must abort the run, not poison it.
struct NanEvaluator(usize);
impl Evaluator for NanEvaluator {
    fn evaluate(&mut self, _c: &Config) -> anyhow::Result<f64> {
        self.0 += 1;
        Ok(if self.0 == 5 { f64::NAN } else { 100.0 })
    }
    fn describe(&self) -> String {
        "nan".into()
    }
}

#[test]
fn nan_measurement_aborts_cleanly() {
    let space = ModelId::NcfFp32.space();
    let mut tuner = Algorithm::Bo.build(&space, 1);
    let err = tune(tuner.as_mut(), &mut NanEvaluator(0), 20).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");
}

/// A stopped daemon surfaces as clean errors: its listener is gone (new
/// connections refused) and a half-closed client connection errors rather
/// than hanging.
#[test]
fn remote_evaluator_handles_server_shutdown() {
    let model = ModelId::NcfFp32;
    let space = model.space();
    let server = TargetServer::bind(
        "127.0.0.1:0",
        space.clone(),
        Box::new(SimEvaluator::new(model, 1)),
    )
    .unwrap();
    let (addr, handle) = server.spawn().unwrap();
    let mut remote = RemoteEvaluator::connect(&addr.to_string(), space.clone()).unwrap();
    let cfg = vec![1, 8, 128, 0, 8];
    assert!(remote.evaluate(&cfg).is_ok());
    remote.shutdown().unwrap();
    let served = handle.join().unwrap().unwrap();
    assert_eq!(served, 1);
    // The listener is dropped with the server: reconnection must fail fast.
    let again = RemoteEvaluator::connect(&addr.to_string(), space.clone());
    assert!(again.is_err(), "connected to a dead daemon");
}

/// Every algorithm survives a single-parameter, single-point space.
#[test]
fn degenerate_space_single_point() {
    let space = SearchSpace::new(vec![ParamDef::new("only", 7, 7, 1)]);
    for alg in [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms, Algorithm::Random, Algorithm::Sa, Algorithm::Coord]
    {
        let mut t = alg.build(&space, 3);
        for _ in 0..8 {
            let Some(trial) = t.ask(1).pop() else { continue };
            assert_eq!(trial.config, vec![7], "{} proposed {:?}", t.name(), trial.config);
            t.tell(trial.id, &Measurement::new(1.0));
        }
    }
}

/// Every algorithm survives a two-value binary space (smallest nontrivial).
#[test]
fn degenerate_space_binary() {
    let space = SearchSpace::new(vec![ParamDef::new("bit", 0, 1, 1)]);
    for alg in Algorithm::all_paper() {
        let mut t = alg.build(&space, 4);
        let mut seen_one = false;
        for _ in 0..20 {
            let Some(trial) = t.ask(1).pop() else { continue };
            let c = &trial.config;
            assert!(c[0] == 0 || c[0] == 1);
            seen_one |= c[0] == 1;
            let v = c[0] as f64; // 1 is better
            t.tell(trial.id, &Measurement::new(v));
        }
        assert!(seen_one, "{} never sampled the better value", alg.name());
    }
}

/// Extreme objective magnitudes (NCF ~6e5, BERT ~2e2) must not break the
/// GP standardisation: tune on a scaled objective and still improve.
#[test]
fn bo_invariant_to_objective_scale() {
    let space = ModelId::Resnet50Int8.space();
    for scale in [1e-6, 1.0, 1e9] {
        let mut t = Algorithm::Bo.build(&space, 5);
        let mut inner = SimEvaluator::new(ModelId::Resnet50Int8, 5);
        struct Scaled<'a>(&'a mut SimEvaluator, f64);
        impl Evaluator for Scaled<'_> {
            fn evaluate(&mut self, c: &Config) -> anyhow::Result<f64> {
                Ok(self.0.evaluate(c)? * self.1)
            }
            fn describe(&self) -> String {
                "scaled".into()
            }
        }
        let mut eval = Scaled(&mut inner, scale);
        let h = tune(t.as_mut(), &mut eval, 30).unwrap();
        let vals = h.values();
        let first8 = vals[..8].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let best = h.best().unwrap().value;
        assert!(
            best >= first8,
            "scale {scale}: best {best} below init best {first8}"
        );
        assert!(best > 3000.0 * scale, "scale {scale}: best {best} too low");
    }
}

/// A trial whose measurement is missing a declared objective column (or
/// carries NaN) degrades that trial to its measured columns — the engine
/// keeps proposing, the shared factor is never poisoned, and fully
/// measured rows still drive the multi-objective acquisition.
#[test]
fn missing_or_nan_objective_column_degrades_the_trial_not_the_run() {
    use tftune::objectives::{ObjectiveSet, Scalarization};
    let space = ModelId::NcfFp32.space();
    let set = ObjectiveSet::parse("throughput,p99:min").unwrap();
    for scalarize in [Scalarization::Weighted(vec![0.5, 0.5]), Scalarization::Smsego] {
        let mut bo = tftune::algorithms::BayesOpt::new(space.clone(), 41)
            .with_objectives(set.clone(), scalarize);
        for i in 0..24 {
            let Some(trial) = bo.ask(1).pop() else { panic!("engine stopped issuing") };
            assert!(space.contains(&trial.config));
            let v = 100.0 + (i as f64 * 0.7).sin() * 10.0;
            let m = match i % 3 {
                0 => Measurement::new(v), // declared column absent
                1 => Measurement::new(v).with_metadata("p99", f64::NAN), // poisoned column
                _ => Measurement::new(v).with_metadata("p99", 5.0 + (i as f64) * 0.1),
            };
            bo.tell(trial.id, &m);
        }
        // The factor stayed healthy: a fresh batch still scores.
        let batch = bo.ask(4);
        assert_eq!(batch.len(), 4);
        for t in &batch {
            assert!(space.contains(&t.config));
        }
    }
}

/// The same degradation over the wire: `tell-obs` rows whose `ys` column
/// is `null` (NaN in memory) or absent entirely must land in a served
/// factor as degraded rows — siblings keep syncing, nothing panics.
#[test]
fn degraded_objective_columns_survive_the_surrogate_wire() {
    use std::io::{BufRead, BufReader, Write};
    use tftune::gp::{GpHyper, RemoteSurrogate, SurrogateHandle};
    use tftune::server::proto::{decode_surrogate_response, SurrogateResponse};

    let (server, factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let (addr, handle) = server.spawn().unwrap();

    // Raw v3 lines: a full row, a null (NaN) column, and a bare v2 row.
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    writeln!(s, r#"{{"type":"tell-obs","x":[0.2,0.2],"y":1.0,"ys":[-4.0]}}"#).unwrap();
    writeln!(s, r#"{{"type":"tell-obs","x":[0.5,0.5],"y":2.0,"ys":[null]}}"#).unwrap();
    writeln!(s, r#"{{"type":"tell-obs","x":[0.8,0.8],"y":3.0}}"#).unwrap();
    writeln!(s, r#"{{"type":"sync-factor","from_n":0}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    match decode_surrogate_response(line.trim_end()).unwrap() {
        SurrogateResponse::FactorDelta(d) => {
            assert_eq!(d.total_n, 3);
            assert_eq!(d.extras.len(), 3);
            assert_eq!(d.extras[0], vec![-4.0]);
            assert!(d.extras[1][0].is_nan(), "null column must decode to NaN");
            assert!(d.extras[2].is_empty());
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(factor.len(), 3, "degraded rows must still land in the store");

    // A replica syncing the degraded store conditions and scores fine.
    let replica = RemoteSurrogate::connect(&addr.to_string()).unwrap();
    let mut g = replica.lock();
    assert_eq!(g.len(), 3);
    assert!(g.y_extras(1)[0].is_nan());
    let idx = g.conditioning_set();
    assert!(g.sync(&idx), "factor must stay PD under degraded columns");
    drop(g);
    drop(replica);
    drop(s);
    drop(reader);

    // Shut the daemon down via the evaluate plane.
    let space = ModelId::NcfFp32.space();
    if let Ok(mut sd) = std::net::TcpStream::connect(addr) {
        use tftune::server::proto::{encode_request, Request};
        let _ = writeln!(sd, "{}", encode_request(&Request::Shutdown, &space));
    }
    let _ = handle.join();
}

/// Histories with duplicated configurations (NMS collapse) keep the GP
/// solvable (jitter floor) — BO must not crash after mass duplicates.
#[test]
fn bo_survives_duplicate_history() {
    let space = ModelId::BertFp32.space();
    let mut t = tftune::algorithms::BayesOpt::new(space.clone(), 6);
    let cfg = vec![2, 10, 32, 0, 20];
    for i in 0..30 {
        // inject the SAME config over and over (warm-start path)
        t.warm_start(&cfg, 100.0 + (i % 3) as f64);
    }
    let trial = t.ask(1).pop().unwrap();
    assert!(space.contains(&trial.config));
}
