//! Scoring-engine pins (ISSUE 7): the properties the engine's speed
//! rests on, end to end through the public API.
//!
//! - **Parallel = serial, bitwise.** The fixed contiguous candidate
//!   partition is a pure function of (pool size, thread count) and each
//!   worker's per-candidate operation order is the serial one, so every
//!   thread count produces the same bits — pinned across threads
//!   {1, 2, 4} × pools {1, 63, 512}.
//! - **Blocking never changes results.** The cache-tiled trsm/gemm
//!   kernels reorder *which* output element is touched *when*, never the
//!   ascending-index operation sequence a single element receives —
//!   pinned against the naive loops at awkward shapes.
//! - **f32 is a ranking tier, not a model change.** On well-separated
//!   gains the f32 tier's top-k agrees with the f64 oracle (property
//!   test over seeds); it is opt-in, never the default.
//! - **Multi-objective panels ride the same engine.** A K-objective
//!   parallel panel pass matches K independent single-objective models
//!   sharing the factor to ≤ 1e-9 (in practice bit-equal).
//! - **Asks do not leak.** Once warmed past the conditioning window, a
//!   `BayesOpt` ask/tell cycle never grows any engine scratch buffer.

use tftune::algorithms::{BayesOpt, Tuner};
use tftune::gp::{BlockSpec, GpHyper, IncrementalGp, ScoreTier, ScoreWorkspace};
use tftune::history::Measurement;
use tftune::util::linalg::{
    chol_packed, gemm_nt, gemm_nt_blocked, packed_idx, packed_len, trsm_lower_packed,
    trsm_lower_packed_blocked,
};
use tftune::util::Rng;

/// A conditioned model over `n` random points in `[0,1)^d` plus a flat
/// random pool of `c` candidates.
fn problem(n: usize, d: usize, c: usize, seed: u64) -> (IncrementalGp, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut gp = IncrementalGp::new(GpHyper::default());
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y = x[0] - 0.7 * x[1 % d] + 0.1 * rng.f64();
        assert!(gp.push(&x, y), "random factor must stay positive definite");
    }
    let cand: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();
    (gp, cand)
}

#[test]
fn parallel_panels_match_serial_bitwise() {
    let d = 4;
    for &c in &[1usize, 63, 512] {
        let (mut gp, cand) = problem(48, d, c, 0x5EED ^ c as u64);
        let mut ws_ref = ScoreWorkspace::default();
        gp.set_score_threads(1);
        gp.score_into(&cand, c, 1.5, 0.3, &mut ws_ref);

        for &threads in &[1usize, 2, 4] {
            gp.set_score_threads(threads);
            let mut ws = ScoreWorkspace::default();
            gp.score_into(&cand, c, 1.5, 0.3, &mut ws);
            for j in 0..c {
                assert_eq!(
                    ws.mean[j].to_bits(),
                    ws_ref.mean[j].to_bits(),
                    "mean diverged at candidate {j} (pool {c}, {threads} threads)"
                );
                assert_eq!(
                    ws.std[j].to_bits(),
                    ws_ref.std[j].to_bits(),
                    "std diverged at candidate {j} (pool {c}, {threads} threads)"
                );
                assert_eq!(
                    ws.gain[j].to_bits(),
                    ws_ref.gain[j].to_bits(),
                    "gain diverged at candidate {j} (pool {c}, {threads} threads)"
                );
            }
        }
    }
}

#[test]
fn blocked_trsm_matches_naive_at_awkward_shapes() {
    let mut rng = Rng::new(0x7351);
    for &(n, c) in &[(1usize, 1usize), (7, 5), (33, 17), (64, 64), (129, 3)] {
        // A well-conditioned packed lower factor: random SPD via a
        // diagonally dominant matrix.
        let mut a: Vec<f64> = (0..packed_len(n)).map(|_| rng.f64()).collect();
        for i in 0..n {
            a[packed_idx(i, i)] += n as f64 + 1.0;
        }
        assert!(chol_packed(&mut a, n), "dominant matrix must factor");

        let b0: Vec<f64> = (0..n * c).map(|_| rng.f64() - 0.5).collect();
        let mut naive = b0.clone();
        trsm_lower_packed_blocked(&a, n, &mut naive, c, BlockSpec::naive());

        for spec in [
            BlockSpec::default(),
            BlockSpec { mc: 3, nc: 5, kc: 4 },
            BlockSpec { mc: 1, nc: 1, kc: 1 },
        ] {
            let mut blocked = b0.clone();
            trsm_lower_packed_blocked(&a, n, &mut blocked, c, spec);
            for (i, (x, y)) in blocked.iter().zip(&naive).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "trsm {spec:?} diverged at element {i} (n={n}, c={c})"
                );
            }
        }

        // The default-spec wrapper is the blocked kernel, same bits.
        let mut wrapped = b0.clone();
        trsm_lower_packed(&a, n, &mut wrapped, c);
        for (x, y) in wrapped.iter().zip(&naive) {
            assert_eq!(x.to_bits(), y.to_bits(), "trsm wrapper diverged (n={n}, c={c})");
        }
    }
}

#[test]
fn blocked_gemm_matches_naive_at_awkward_shapes() {
    let mut rng = Rng::new(0x6E44);
    for &(m, n, k) in &[(1usize, 1usize, 0usize), (5, 7, 9), (32, 64, 128), (33, 65, 129)] {
        let a: Vec<f64> = (0..m * k).map(|_| rng.f64() - 0.5).collect();
        let b: Vec<f64> = (0..n * k).map(|_| rng.f64() - 0.5).collect();
        let mut naive = vec![f64::NAN; m * n];
        gemm_nt(&a, m, &b, n, k, &mut naive);
        for spec in [BlockSpec::default(), BlockSpec { mc: 2, nc: 3, kc: 5 }] {
            let mut blocked = vec![f64::NAN; m * n];
            gemm_nt_blocked(&a, m, &b, n, k, &mut blocked, spec);
            for (i, (x, y)) in blocked.iter().zip(&naive).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "gemm {spec:?} diverged at element {i} (m={m}, n={n}, k={k})"
                );
            }
        }
    }
}

/// Property test over seeds: wherever the f64 oracle separates two gains
/// by more than the f32 tier's error budget, the f32 tier orders them the
/// same way — and therefore agrees on the top-k for well-separated k-th
/// gaps. Near-ties (within the budget) are legitimately tier-dependent
/// and excluded; the count assertion keeps the test non-vacuous.
#[test]
fn f32_tier_top_k_agrees_with_f64_on_separated_gains() {
    const SEP: f64 = 1e-3;
    const K: usize = 5;
    let (n, d, c) = (32, 3, 64);
    let mut separated_pools = 0;
    for seed in 0..20u64 {
        let (mut gp, cand) = problem(n, d, c, 0xF32 + seed);
        assert_eq!(gp.score_tier(), ScoreTier::F64, "f64 must be the default tier");

        let mut ws64 = ScoreWorkspace::default();
        gp.score_into(&cand, c, 1.5, 0.0, &mut ws64);
        let g64 = ws64.gain.clone();

        gp.set_score_tier(ScoreTier::F32);
        let mut ws32 = ScoreWorkspace::default();
        gp.score_into(&cand, c, 1.5, 0.0, &mut ws32);
        let g32 = ws32.gain.clone();

        // Pairwise: separated f64 gains keep their order in f32.
        for i in 0..c {
            for j in 0..c {
                if g64[i] - g64[j] > SEP {
                    assert!(
                        g32[i] > g32[j],
                        "seed {seed}: f32 inverted a {:.2e}-separated pair \
                         ({i}: {} vs {j}: {})",
                        g64[i] - g64[j],
                        g32[i],
                        g32[j]
                    );
                }
            }
        }

        // Top-k: when the k-th/(k+1)-th gap is wide, the sets match.
        let mut order: Vec<usize> = (0..c).collect();
        order.sort_by(|&i, &j| g64[j].partial_cmp(&g64[i]).unwrap());
        if g64[order[K - 1]] - g64[order[K]] > SEP {
            separated_pools += 1;
            let mut order32: Vec<usize> = (0..c).collect();
            order32.sort_by(|&i, &j| g32[j].partial_cmp(&g32[i]).unwrap());
            let mut top64: Vec<usize> = order[..K].to_vec();
            let mut top32: Vec<usize> = order32[..K].to_vec();
            top64.sort_unstable();
            top32.sort_unstable();
            assert_eq!(top64, top32, "seed {seed}: f32 top-{K} diverged from f64");
        }
    }
    assert!(
        separated_pools >= 5,
        "only {separated_pools} of 20 pools were separated — property test is vacuous"
    );
}

#[test]
fn multi_objective_parallel_panel_matches_independent_models() {
    let (n, d, c, k_obj) = (40, 4, 129, 3);
    let mut rng = Rng::new(0x3B0);
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    let targets: Vec<Vec<f64>> = (0..k_obj)
        .map(|k| x.iter().map(|p| p[k % d] - 0.4 * p[(k + 1) % d]).collect())
        .collect();
    let cand: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();

    // One engine, K objectives, 4-thread panel.
    let mut multi = IncrementalGp::new(GpHyper::default());
    for (xi, &y0) in x.iter().zip(&targets[0]) {
        assert!(multi.push(xi, y0));
    }
    multi.set_score_threads(4);
    let refs: Vec<&[f64]> = targets.iter().map(Vec::as_slice).collect();
    let mut ws = ScoreWorkspace::default();
    multi.score_multi_into(&cand, c, &refs, &mut ws);
    assert_eq!(ws.n_obj, k_obj);

    // K independent serial single-objective models over the same inputs.
    for (k, yk) in targets.iter().enumerate() {
        let mut solo = IncrementalGp::new(GpHyper::default());
        for (xi, &yv) in x.iter().zip(yk) {
            assert!(solo.push(xi, yv));
        }
        let mut ws_solo = ScoreWorkspace::default();
        solo.score_into(&cand, c, 0.0, 0.0, &mut ws_solo);
        for j in 0..c {
            let dm = (ws.mean_obj[k * c + j] - ws_solo.mean[j]).abs();
            let ds = (ws.std[j] - ws_solo.std[j]).abs();
            assert!(dm <= 1e-9, "objective {k} mean off by {dm:.2e} at candidate {j}");
            assert!(ds <= 1e-9, "shared std off by {ds:.2e} at candidate {j}");
        }
    }
}

#[test]
fn warmed_bo_asks_do_not_grow_engine_scratch() {
    let space = tftune::space::threading_space(64, 1024, 64);
    let mut bo = BayesOpt::new(space, 11).with_score_threads(2);
    let mut rng = Rng::new(5);
    // Warm past the conditioning window (GpHyper::default().max_history)
    // so the candidate pool, target buffers and the scoring workspace
    // have all reached steady-state shape.
    let window = GpHyper::default().max_history;
    for _ in 0..window + 6 {
        let t = bo.ask(1).pop().unwrap();
        bo.tell(t.id, &Measurement::new(rng.f64()));
    }
    let caps = bo.scratch_capacities();
    for round in 0..6 {
        for t in bo.ask(2) {
            bo.tell(t.id, &Measurement::new(rng.f64()));
        }
        assert_eq!(
            bo.scratch_capacities(),
            caps,
            "ask/tell round {round} grew an engine scratch buffer"
        );
    }
}
