//! Multi-objective acquisition pins (ISSUE 5):
//!
//! 1. K-objective panel scoring against K independent single-objective
//!    `IncrementalGp`s — one factor, K target columns, panel passes, not
//!    refits — to ≤1e-9 (bit-equal in practice), with the factor proven
//!    untouched by the pass.
//! 2. Scalarisation invariances: permuting weights together with
//!    objectives leaves the gain unchanged, and a candidate whose
//!    optimistic vector is dominated never has the best scalarised gain.
//!    A dominated optimistic point also has zero SMSego hypervolume gain.
//! 3. Bitwise fantasy extend/retract round trip with vector-valued
//!    fantasies (per-objective lies in the target columns).
//! 4. End-to-end Pareto: a synthetic bi-objective target with a known
//!    analytic trade-off, tuned via `TuningSession` — the hypervolume of
//!    the history's non-dominated front is non-decreasing over
//!    checkpoints, and the SMSego session's final front beats random
//!    search at equal budget.

use tftune::algorithms::BayesOpt;
use tftune::evaluator::Evaluator;
use tftune::gp::{GpHyper, IncrementalGp, ScoreWorkspace};
use tftune::history::Measurement;
use tftune::objectives::{dominates, hypervolume, weighted_gain, ObjectiveSet, Scalarization};
use tftune::session::{Budget, TuningSession};
use tftune::space::{threading_space, Config, SearchSpace};
use tftune::util::prop;
use tftune::util::Rng;

fn rand_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect()
}

#[test]
fn prop_k_objective_panel_matches_independent_models() {
    prop::check("k-objective panel vs independent models", 25, |rng| {
        let n = 5 + rng.index(25);
        let d = 2 + rng.index(4);
        let k = 2 + rng.index(2); // 2 or 3 objectives
        let c = 1 + rng.index(12);
        let hyper = GpHyper::default();
        let x = rand_rows(rng, n, d);
        let targets: Vec<Vec<f64>> = (0..k)
            .map(|kk| {
                x.iter()
                    .map(|p| (3.0 * p[0] + kk as f64).sin() - 0.2 * p[d - 1])
                    .collect()
            })
            .collect();
        let cand_rows = rand_rows(rng, c, d);
        let cand_flat: Vec<f64> = cand_rows.iter().flatten().copied().collect();

        // ONE factor: built once, scored with K target columns.
        let mut joint = IncrementalGp::new(hyper);
        for (xi, y0) in x.iter().zip(&targets[0]) {
            assert!(joint.push(xi, *y0));
        }
        let factor_before: Vec<u64> =
            joint.factor_suffix(0).iter().map(|v| v.to_bits()).collect();
        let refs: Vec<&[f64]> = targets.iter().map(|t| t.as_slice()).collect();
        let mut ws = ScoreWorkspace::default();
        joint.score_multi_into(&cand_flat, c, &refs, &mut ws);
        assert_eq!(ws.n_obj, k);

        // The pass performed zero refits/appends: the factor is
        // bit-identical to the state before scoring.
        let factor_after: Vec<u64> =
            joint.factor_suffix(0).iter().map(|v| v.to_bits()).collect();
        assert_eq!(factor_before, factor_after, "multi pass mutated the factor");

        // K independent single-objective models (their own factors,
        // their own refits) must agree to ≤1e-9 per objective.
        for (kk, tk) in targets.iter().enumerate() {
            let mut solo = IncrementalGp::new(hyper);
            for (xi, yk) in x.iter().zip(tk) {
                assert!(solo.push(xi, *yk));
            }
            let mut ws_solo = ScoreWorkspace::default();
            solo.score_into(&cand_flat, c, 1.5, 0.0, &mut ws_solo);
            for j in 0..c {
                assert!(
                    (ws.mean_obj[kk * c + j] - ws_solo.mean[j]).abs() <= 1e-9,
                    "objective {kk} mean diverged at candidate {j}: {} vs {}",
                    ws.mean_obj[kk * c + j],
                    ws_solo.mean[j]
                );
                assert!(
                    (ws.std[j] - ws_solo.std[j]).abs() <= 1e-9,
                    "shared std diverged at candidate {j}"
                );
            }
        }
    });
}

#[test]
fn prop_weight_permutation_matches_objective_permutation() {
    prop::check("scalarisation permutation invariance", 200, |rng| {
        let k = 2 + rng.index(3); // 2..=4
        let w: Vec<f64> = (0..k).map(|_| 0.05 + rng.f64()).collect();
        let opt: Vec<f64> = (0..k).map(|_| (rng.f64() - 0.5) * 6.0).collect();
        let best: Vec<f64> = (0..k).map(|_| (rng.f64() - 0.5) * 2.0).collect();
        // random permutation (Fisher–Yates)
        let mut perm: Vec<usize> = (0..k).collect();
        for i in (1..k).rev() {
            let j = rng.index(i + 1);
            perm.swap(i, j);
        }
        let g = weighted_gain(&w, &opt, &best);
        let wp: Vec<f64> = perm.iter().map(|&i| w[i]).collect();
        let op: Vec<f64> = perm.iter().map(|&i| opt[i]).collect();
        let bp: Vec<f64> = perm.iter().map(|&i| best[i]).collect();
        let gp = weighted_gain(&wp, &op, &bp);
        assert!(
            (g - gp).abs() <= 1e-9 * (1.0 + g.abs()),
            "permuting weights with objectives changed the gain: {g} vs {gp}"
        );
    });
}

#[test]
fn prop_dominated_candidates_never_have_the_best_scalarised_gain() {
    prop::check("dominated never argmax", 100, |rng| {
        let k = 2 + rng.index(2);
        let n_cand = 4 + rng.index(20);
        let w: Vec<f64> = (0..k).map(|_| 0.05 + rng.f64()).collect();
        let best = vec![0.0; k];
        let cands: Vec<Vec<f64>> =
            (0..n_cand).map(|_| (0..k).map(|_| (rng.f64() - 0.5) * 4.0).collect()).collect();
        let gains: Vec<f64> = cands.iter().map(|o| weighted_gain(&w, o, &best)).collect();
        let argmax = (0..n_cand)
            .max_by(|&a, &b| gains[a].total_cmp(&gains[b]))
            .unwrap();
        for (i, c) in cands.iter().enumerate() {
            assert!(
                i == argmax || !dominates(c, &cands[argmax]),
                "candidate {i} dominates the scalarised argmax {argmax}"
            );
        }
    });
}

#[test]
fn dominated_optimistic_point_has_zero_hypervolume_gain() {
    // SMSego's gain for a candidate whose optimistic vector is inside
    // the region the front already dominates must be exactly zero.
    let front = vec![vec![1.0, 3.0], vec![3.0, 1.0]];
    let r = [0.0, 0.0];
    let base = hypervolume(&front, &r);
    for dominated in [vec![0.5, 0.5], vec![1.0, 3.0], vec![2.9, 0.9]] {
        let mut with = front.clone();
        with.push(dominated.clone());
        let gain = hypervolume(&with, &r) - base;
        assert!(
            gain.abs() < 1e-12,
            "dominated optimistic point {dominated:?} gained {gain}"
        );
    }
    // ...while a genuinely non-dominated point gains volume.
    let mut with = front.clone();
    with.push(vec![2.0, 2.0]);
    assert!(hypervolume(&with, &r) - base > 0.5);
}

#[test]
fn vector_fantasy_extend_retract_is_bitwise() {
    // Vector-valued fantasies: fantasy rows enter the factor once (the
    // factor depends only on X) while each objective column carries its
    // own lie. Retraction must restore the exact pre-extend state —
    // factor bits and K-objective posterior bits.
    let mut rng = Rng::new(51);
    let hyper = GpHyper::default();
    let (n, d, c, k) = (14usize, 3usize, 6usize, 2usize);
    let x = rand_rows(&mut rng, n, d);
    let targets: Vec<Vec<f64>> = (0..k)
        .map(|kk| x.iter().map(|p| p[0] * (kk + 1) as f64 - 0.5 * p[1]).collect())
        .collect();
    let cand: Vec<f64> = (0..c * d).map(|_| rng.f64()).collect();

    let mut gp = IncrementalGp::new(hyper);
    for (xi, y0) in x.iter().zip(&targets[0]) {
        assert!(gp.push(xi, *y0));
    }
    let refs: Vec<&[f64]> = targets.iter().map(|t| t.as_slice()).collect();
    let mut before = ScoreWorkspace::default();
    gp.score_multi_into(&cand, c, &refs, &mut before);
    let factor_before: Vec<u64> = gp.factor_suffix(0).iter().map(|v| v.to_bits()).collect();

    // Extend three fantasies; each objective column gets its own lie.
    let mut padded: Vec<Vec<f64>> = targets.clone();
    for f in 0..3 {
        let xf: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        assert!(gp.extend_fantasy(&xf, 0.0));
        for (kk, col) in padded.iter_mut().enumerate() {
            col.push(0.1 * (f as f64 + 1.0) * if kk == 0 { 1.0 } else { -1.0 });
        }
    }
    assert_eq!(gp.total(), n + 3);
    let refs_pad: Vec<&[f64]> = padded.iter().map(|t| t.as_slice()).collect();
    let mut during = ScoreWorkspace::default();
    gp.score_multi_into(&cand, c, &refs_pad, &mut during);
    // Conditioning on the fantasies must actually change the posterior
    // (otherwise this test pins nothing).
    assert!(
        (0..c).any(|j| during.std[j].to_bits() != before.std[j].to_bits()),
        "fantasies did not condition the model"
    );

    gp.retract_fantasies();
    assert_eq!(gp.total(), n);
    let factor_after: Vec<u64> = gp.factor_suffix(0).iter().map(|v| v.to_bits()).collect();
    assert_eq!(factor_before, factor_after, "retract did not restore the factor bitwise");
    let mut after = ScoreWorkspace::default();
    gp.score_multi_into(&cand, c, &refs, &mut after);
    for j in 0..c {
        for kk in 0..k {
            assert_eq!(
                before.mean_obj[kk * c + j].to_bits(),
                after.mean_obj[kk * c + j].to_bits(),
                "objective {kk} mean not restored bitwise at candidate {j}"
            );
        }
        assert_eq!(before.std[j].to_bits(), after.std[j].to_bits());
    }
}

// ---------------------------------------------------------------------------
// End-to-end Pareto: synthetic bi-objective target with a known front.
// ---------------------------------------------------------------------------

/// Analytic bi-objective target over the unit cube: `u[0]` trades
/// throughput against p99 (the known front lies along it), and every
/// other coordinate penalises *both* objectives away from 0.75 — so a
/// tuner must drive the penalty to zero to reach the front, while random
/// search almost always carries positive penalty.
struct BiObjectiveTarget {
    space: SearchSpace,
}

impl BiObjectiveTarget {
    fn penalty(u: &[f64]) -> f64 {
        u[1..].iter().map(|&v| (v - 0.75) * (v - 0.75)).sum::<f64>()
    }

    fn throughput(u: &[f64]) -> f64 {
        10.0 * u[0] + 5.0 - 4.0 * Self::penalty(u)
    }

    fn p99(u: &[f64]) -> f64 {
        2.0 + 8.0 * u[0] * u[0] + 4.0 * Self::penalty(u)
    }
}

impl Evaluator for BiObjectiveTarget {
    fn evaluate(&mut self, config: &Config) -> anyhow::Result<f64> {
        Ok(Self::throughput(&self.space.to_unit(config)))
    }

    fn measure(&mut self, config: &Config) -> anyhow::Result<Measurement> {
        let u = self.space.to_unit(config);
        Ok(Measurement::new(Self::throughput(&u)).with_metadata("p99", Self::p99(&u)))
    }

    fn describe(&self) -> String {
        "synthetic-bi-objective".into()
    }
}

/// Reference point safely below every reachable (throughput, −p99)
/// vector: tp ∈ (−inf, 15], −p99 ∈ [−10 − 4·p_max, −2], p_max ≤ 4·0.75².
const HV_REF: [f64; 2] = [0.0, -30.0];

fn run_session(seed: u64, smsego: bool, evals: usize) -> tftune::History {
    let space = threading_space(64, 1024, 64);
    let set = ObjectiveSet::parse("throughput,p99:min").unwrap();
    let tuner: Box<dyn tftune::algorithms::Tuner + Send> = if smsego {
        Box::new(
            BayesOpt::new(space.clone(), seed).with_objectives(set.clone(), Scalarization::Smsego),
        )
    } else {
        Box::new(tftune::algorithms::RandomSearch::new(space.clone(), seed))
    };
    let mut session = TuningSession::new(
        tuner,
        vec![Box::new(BiObjectiveTarget { space })],
        Budget::evaluations(evals),
    )
    .with_objectives(set);
    session.run().unwrap()
}

/// Hypervolume of the front over the first `n` evaluations.
fn hv_prefix(h: &tftune::History, n: usize) -> f64 {
    let pts: Vec<Vec<f64>> =
        h.iter().take(n).map(|e| e.objectives.clone()).collect();
    hypervolume(&pts, &HV_REF)
}

#[test]
fn pareto_session_hypervolume_grows_and_beats_random_search() {
    let evals = 40;
    let mut bo_wins = 0;
    let seeds = [11u64, 12, 13];
    for &seed in &seeds {
        let bo = run_session(seed, true, evals);
        assert_eq!(bo.len(), evals);
        // Every record carries the extracted 2-objective vector
        // (maximisation orientation: p99 negated).
        for e in bo.iter() {
            assert_eq!(e.objectives.len(), 2);
            assert_eq!(e.objectives[0], e.value);
            assert!(e.objectives[1] <= -2.0, "p99 column not negated: {:?}", e.objectives);
        }
        // Checkpointed hypervolume is non-decreasing.
        let mut prev = 0.0;
        for n in [5, 10, 20, 30, evals] {
            let hv = hv_prefix(&bo, n);
            assert!(
                hv >= prev - 1e-12,
                "seed {seed}: hypervolume shrank at checkpoint {n}: {hv} < {prev}"
            );
            prev = hv;
        }
        assert!(prev > 0.0, "seed {seed}: empty dominated region");

        let rs = run_session(seed, false, evals);
        let hv_bo = bo.hypervolume(&HV_REF);
        let hv_rs = rs.hypervolume(&HV_REF);
        if hv_bo > hv_rs {
            bo_wins += 1;
        }
    }
    assert!(
        bo_wins >= 2,
        "multi-objective BO dominated random search on only {bo_wins}/{} seeds",
        seeds.len()
    );
}

#[test]
fn pareto_session_front_spreads_along_the_trade_off() {
    // The known front lies along u[0] with zero penalty: the SMSego
    // session's final non-dominated set should hold several points, not
    // collapse onto a single throughput-optimal corner.
    let h = run_session(17, true, 40);
    let front = h.pareto_front();
    assert!(front.len() >= 2, "front collapsed: {} points", front.len());
    // Every front point's objectives are consistent with the analytic
    // target (tp ≤ 15, p99 ≥ 2 ⇒ −p99 ≤ −2).
    for e in &front {
        assert!(e.objectives[0] <= 15.0 + 1e-9);
        assert!(e.objectives[1] <= -2.0 + 1e-9);
    }
}
