//! The durable persistence plane, pinned end to end over real state
//! directories (and real loopback TCP for the daemon scenario):
//!
//! 1. A surrogate daemon killed mid-campaign — after a mid-campaign
//!    snapshot plus further WAL-only tells — restores **bit-identically**
//!    (rows, extras, packed factor) and serves a posterior within 1e-9
//!    of an uninterrupted reference on the same port.
//! 2. A torn WAL tail (crash mid-append) is truncated to the last
//!    complete record, and the heal makes the next recovery clean.
//! 3. A corrupt snapshot is rejected by its checksum and recovery falls
//!    back to full-log replay, still matching the reference bitwise.
//! 4. Multi-objective rows (secondary columns, NaN degradations) round
//!    trip through both the snapshot and the WAL.

use std::io::Write;
use std::path::PathBuf;

use tftune::gp::{GpHyper, RemoteSurrogate, ScoreWorkspace, SharedSurrogate, SurrogateHandle};
use tftune::persist::{self, PersistOptions};
use tftune::server::TargetServer;
use tftune::space::threading_space;
use tftune::util::Rng;

fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tftune_persist_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn shutdown_daemon(addr: std::net::SocketAddr) {
    use tftune::server::proto::{encode_request, Request};
    let space = threading_space(64, 1024, 64);
    if let Ok(mut s) = std::net::TcpStream::connect(addr) {
        let _ = writeln!(s, "{}", encode_request(&Request::Shutdown, &space));
    }
}

fn toy_obs(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
    (0..n)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin() - 0.5 * x[d - 1];
            (x, y)
        })
        .collect()
}

/// Tells are fire-and-forget: poll until the service has absorbed them.
fn wait_len(replica: &RemoteSurrogate, want: usize) {
    let mut seen = 0;
    for _ in 0..2000 {
        seen = replica.lock().len();
        if seen == want {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(seen, want, "service did not absorb the campaign's tells");
}

/// The full store as bit patterns: rows, secondary columns, packed
/// factor. Two surrogates with equal `delta_bits` are interchangeable.
#[allow(clippy::type_complexity)]
fn delta_bits(
    s: &SharedSurrogate,
) -> (Vec<(Vec<u64>, u64)>, Vec<Vec<u64>>, Option<Vec<u64>>) {
    let d = s.export_delta(0).expect("full export always applies");
    (
        d.rows
            .iter()
            .map(|(x, y)| (x.iter().map(|v| v.to_bits()).collect(), y.to_bits()))
            .collect(),
        d.extras
            .iter()
            .map(|r| r.iter().map(|v| v.to_bits()).collect())
            .collect(),
        d.factor.map(|f| f.iter().map(|v| v.to_bits()).collect()),
    )
}

/// Score `cand` through a guard in canonical order (the parity-suite
/// idiom from `surrogate_service.rs`).
fn posterior(g: &mut tftune::gp::SurrogateGuard<'_>, cand: &[f64], c: usize) -> ScoreWorkspace {
    let idx = g.conditioning_set();
    assert!(g.sync(&idx));
    let y: Vec<f64> = (0..g.len()).map(|i| g.y(i)).collect();
    g.set_targets(&y);
    let mut ws = ScoreWorkspace::default();
    g.score_into(cand, c, 1.5, 0.3, &mut ws);
    ws
}

#[test]
fn daemon_killed_mid_campaign_restores_bit_identically() {
    let dir = state_dir("kill_mid_campaign");
    let mut rng = Rng::new(23);
    let (n, d) = (24usize, 3usize);
    let obs = toy_obs(&mut rng, n, d);
    let cand: Vec<f64> = (0..8 * d).map(|_| rng.f64()).collect();

    // The uninterrupted reference: same observations, same order, no
    // crash anywhere near it.
    let reference = SharedSurrogate::new(GpHyper::default());

    // Daemon A: a durable authority (recover-on-boot is exercised by the
    // cold start — an empty directory recovers to an empty surrogate).
    let recovered = persist::recover(&dir, GpHyper::default()).unwrap();
    assert!(recovered.surrogate.is_empty());
    let authority = recovered.surrogate;
    let persistence = persist::attach(&authority, &dir, PersistOptions::default()).unwrap();
    let (server, _f) =
        TargetServer::bind_surrogate_with("127.0.0.1:0", authority.clone()).unwrap();
    let (addr, handle) = server.spawn().unwrap();

    // A replica campaign over TCP: half the budget, then a mid-campaign
    // checkpoint, then the rest — so recovery must replay a WAL suffix
    // on top of the snapshot.
    let replica = RemoteSurrogate::connect(&addr.to_string()).unwrap();
    for (x, y) in &obs[..12] {
        replica.tell(x.clone(), *y);
        reference.tell(x.clone(), *y);
    }
    wait_len(&replica, 12);
    let seq = persistence.snapshot(&authority).unwrap();
    assert_eq!(seq, 12);
    for (x, y) in &obs[12..] {
        replica.tell(x.clone(), *y);
        reference.tell(x.clone(), *y);
    }
    wait_len(&replica, n);

    // Kill the daemon. No final snapshot — rows 12.. exist only in the
    // WAL, exactly the crash the plane is for.
    drop(replica);
    shutdown_daemon(addr);
    let _ = handle.join();
    drop(persistence);
    drop(authority);

    // Recover: snapshot @12 seeds the store, the WAL suffix replays the
    // remaining 12 tells, and the result is bit-identical to the
    // uninterrupted reference — factor included.
    let recovered = persist::recover(&dir, GpHyper::default()).unwrap();
    assert_eq!(recovered.snapshot_seq, Some(12));
    assert_eq!(recovered.replayed, 12);
    assert_eq!(recovered.truncated_bytes, 0);
    assert_eq!(recovered.surrogate.len(), n);
    let (rows_r, extras_r, factor_r) = delta_bits(&recovered.surrogate);
    let (rows_ref, extras_ref, factor_ref) = delta_bits(&reference);
    assert_eq!(rows_r, rows_ref, "restored rows differ from the reference");
    assert_eq!(extras_r, extras_ref);
    assert!(factor_r.is_some(), "recovered factor does not cover the store");
    assert_eq!(factor_r, factor_ref, "restored factor is not bit-identical");

    // Serve the restored factor on the very same port; a fresh replica's
    // posterior matches the uninterrupted reference within the parity
    // suite's 1e-9.
    let (server2, _f2) =
        TargetServer::bind_surrogate_with(&addr.to_string(), recovered.surrogate).unwrap();
    let (_, handle2) = server2.spawn().unwrap();
    let replica2 = RemoteSurrogate::connect(&addr.to_string()).unwrap();
    {
        let mut g = replica2.lock();
        assert_eq!(g.len(), n);
        let ws = posterior(&mut g, &cand, 8);
        let mut gr = reference.lock();
        let ws_ref = posterior(&mut gr, &cand, 8);
        for j in 0..8 {
            assert!(
                (ws.mean[j] - ws_ref.mean[j]).abs() <= 1e-9,
                "posterior mean diverged after recovery: {} vs {}",
                ws.mean[j],
                ws_ref.mean[j]
            );
            assert!(
                (ws.std[j] - ws_ref.std[j]).abs() <= 1e-9,
                "posterior std diverged after recovery: {} vs {}",
                ws.std[j],
                ws_ref.std[j]
            );
        }
    }
    drop(replica2);
    shutdown_daemon(addr);
    let _ = handle2.join();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_wal_tail_is_truncated_and_healed() {
    let dir = state_dir("torn_tail");
    let shared = SharedSurrogate::new(GpHyper::default());
    let p = persist::attach(&shared, &dir, PersistOptions::default()).unwrap();
    shared.tell(vec![0.1, 0.9], 1.0);
    shared.tell(vec![0.8, 0.2], 2.0);
    drop(shared.lock()); // drain → journal
    p.sync().unwrap();
    drop(p);
    drop(shared);

    // Crash mid-append: half a record, no trailing newline.
    let mut f = std::fs::OpenOptions::new().append(true).open(persist::wal_path(&dir)).unwrap();
    f.write_all(b"{\"kind\":\"tell\",\"x\":[0.5").unwrap();
    drop(f);

    let recovered = persist::recover(&dir, GpHyper::default()).unwrap();
    assert_eq!(recovered.surrogate.len(), 2, "valid prefix lost with the torn tail");
    assert!(recovered.truncated_bytes > 0, "torn tail went unnoticed");

    // The truncation healed the file on disk: recovering again is clean
    // and yields the same store.
    let again = persist::recover(&dir, GpHyper::default()).unwrap();
    assert_eq!(again.truncated_bytes, 0);
    assert_eq!(delta_bits(&again.surrogate), delta_bits(&recovered.surrogate));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_snapshot_falls_back_to_full_log_replay() {
    let dir = state_dir("corrupt_snapshot");
    let mut rng = Rng::new(9);
    let obs = toy_obs(&mut rng, 6, 2);

    let reference = SharedSurrogate::new(GpHyper::default());
    let shared = SharedSurrogate::new(GpHyper::default());
    let p = persist::attach(&shared, &dir, PersistOptions::default()).unwrap();
    for (x, y) in &obs {
        shared.tell(x.clone(), *y);
        reference.tell(x.clone(), *y);
    }
    drop(shared.lock());
    p.snapshot(&shared).unwrap();
    drop(p);
    drop(shared);

    // Flip bytes inside the (only) snapshot: its checksum must reject it.
    let snaps = persist::list_snapshots(&dir).unwrap();
    assert_eq!(snaps.len(), 1);
    let path = &snaps[0].1;
    let corrupted = std::fs::read_to_string(path).unwrap().replace("rows", "r0ws");
    std::fs::write(path, corrupted).unwrap();

    let recovered = persist::recover(&dir, GpHyper::default()).unwrap();
    assert_eq!(recovered.snapshot_seq, None, "a corrupt snapshot was trusted");
    assert_eq!(recovered.replayed, 6, "full-log replay skipped records");
    assert_eq!(
        delta_bits(&recovered.surrogate),
        delta_bits(&reference),
        "full-log fallback is not bit-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_objective_rows_survive_snapshot_and_wal() {
    let dir = state_dir("multi_objective");
    let shared = SharedSurrogate::new(GpHyper::default());
    let p = persist::attach(&shared, &dir, PersistOptions::default()).unwrap();

    // One K=3 row into the snapshot (with a NaN degradation), one into
    // the WAL suffix, one single-objective row for the mixed case.
    shared.tell_multi(vec![0.2, 0.4], vec![1.0, 0.5, f64::NAN]);
    drop(shared.lock());
    p.snapshot(&shared).unwrap();
    shared.tell_multi(vec![0.6, 0.1], vec![2.0, -0.25, 3.5]);
    shared.tell(vec![0.9, 0.9], -1.0);
    drop(shared.lock());
    drop(p);

    let reference_bits = delta_bits(&shared);
    drop(shared);

    let recovered = persist::recover(&dir, GpHyper::default()).unwrap();
    assert_eq!(recovered.snapshot_seq, Some(1));
    assert_eq!(recovered.surrogate.len(), 3);
    let restored_bits = delta_bits(&recovered.surrogate);
    assert_eq!(
        restored_bits, reference_bits,
        "secondary objective columns did not survive the round trip"
    );
    std::fs::remove_dir_all(&dir).ok();
}
