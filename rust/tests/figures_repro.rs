//! Integration: the figure/table harnesses produce well-formed artifacts
//! and the paper's qualitative findings at reduced budgets.

use tftune::algorithms::Algorithm;
use tftune::config::SurrogateKind;
use tftune::figures::{fig5, fig6, fig7};
use tftune::sim::ModelId;
use tftune::space;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tftune_figtest_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn fig5_csvs_are_written_and_well_formed() {
    let dir = tmp_dir("fig5");
    let curves = fig5::run_figure(10, &[0], SurrogateKind::Native, &dir).unwrap();
    assert_eq!(curves.len(), 6 * 3); // 6 models x 3 algorithms x 1 seed
    for model in ModelId::all() {
        let path = dir.join(format!("fig5_{}.csv", model.short_name()));
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(
            lines.next().unwrap(),
            "algorithm,seed,iteration,throughput,best_so_far"
        );
        // 3 algorithms x 10 iterations rows
        assert_eq!(lines.count(), 30, "{}", path.display());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig6_sweep_findings_match_paper() {
    let points = fig6::run_sweep(ModelId::Resnet50Int8, false);
    assert_eq!(points.len() as u128, fig6::sweep_space(false).size());
    let f = fig6::analyze(&points);
    assert!(f.blocktime0_best);
    assert!(f.omp_influence > 5.0 * f.intra_influence);
    assert!(f.omp_influence > 2.0 * f.batch_influence);
    // "close to a month of CPU time" at 1 min/eval
    assert!(f.paper_equiv_days > 20.0 && f.paper_equiv_days < 45.0);
    // best config shape: blocktime small, omp high
    assert!(f.best.config[space::BLOCKTIME] <= 50);
    assert!(f.best.config[space::OMP_THREADS] >= 33);
}

#[test]
fn fig6_csv_row_count_matches_grid() {
    let dir = tmp_dir("fig6");
    let points = fig6::run_sweep(ModelId::Resnet50Int8, false);
    let path = fig6::write_csv(&points, &dir).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), points.len() + 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig7_table2_exploration_ordering() {
    let dir = tmp_dir("fig7");
    let samples = fig7::run_samples(50, 3, SurrogateKind::Native).unwrap();
    fig7::write_csv(&samples, &dir).unwrap();
    for model in fig7::models() {
        let csv = dir.join(format!("fig7_{}_samples.csv", model.short_name()));
        let text = std::fs::read_to_string(&csv).unwrap();
        assert_eq!(text.lines().count(), 1 + 3 * 50); // header + 3 algs x 50 iters
        let bo = fig7::avg_coverage(&samples, model, Algorithm::Bo).unwrap();
        let ga = fig7::avg_coverage(&samples, model, Algorithm::Ga).unwrap();
        assert!(bo > 90.0, "{}: BO {bo}", model.name());
        assert!(ga < bo, "{}: GA {ga} vs BO {bo}", model.name());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fine_sweep_space_is_full_grid() {
    assert_eq!(fig6::sweep_space(true).size(), 4 * 56 * 16 * 21 * 56);
}
