//! The fleet service contract (protocol v4), pinned over real loopback
//! TCP — one daemon, many search spaces:
//!
//! 1. One daemon concurrently serves three distinct spaces: interleaved
//!    tells from two replicas per space land in the right factor, and
//!    each space's posterior matches a serial private model fed the same
//!    canonical order within 1e-9 — the multi-space analogue of
//!    `tests/surrogate_service.rs`.
//! 2. Wrong-space hellos get the *typed* `hello-err` (fleet at
//!    `--max-spaces`, dimension mismatch, missing `dim`), surfaced as
//!    `Err` from [`RemoteSurrogate::connect_space`] — and none of the
//!    refusals poison the siblings that keep serving.
//! 3. Chunked and quantised catch-up (`sync-factor` `max_rows` /
//!    `quantise`): measured bytes bounded below the full transfer while
//!    the imported factor stays bit-identical.
//! 4. Idle eviction: an unbound space is snapshotted into its
//!    `space-<16 hex>/` namespace and dropped; a re-hello restores it
//!    bit-identically from disk.
//! 5. Chaos drill: kill a durable fleet daemon with three active spaces
//!    mid-campaign, restart it on the same port, and every space boots
//!    bit-identically while the in-flight replicas redial into the
//!    *right* spaces through the existing backoff.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use tftune::gp::{
    GpHyper, IncrementalGp, RemoteSurrogate, ScoreWorkspace, SharedSurrogate, SurrogateDelta,
    SurrogateHandle,
};
use tftune::persist::{list_snapshots, space_dir};
use tftune::server::proto::{
    decode_surrogate_response, encode_surrogate_request, SurrogateRequest, SurrogateResponse,
    PROTOCOL_VERSION,
};
use tftune::server::{FleetOptions, TargetServer};
use tftune::space::{threading_space, ParamDef, SearchSpace};
use tftune::util::linalg::packed_len;
use tftune::util::Rng;

fn fleet_daemon(
    opts: FleetOptions,
) -> (SocketAddr, std::thread::JoinHandle<anyhow::Result<usize>>, SharedSurrogate) {
    let (server, factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let server = server.with_fleet_options(opts).unwrap();
    let (addr, handle) = server.spawn().unwrap();
    (addr, handle, factor)
}

fn shutdown_daemon(addr: SocketAddr) {
    use tftune::server::proto::{encode_request, Request};
    let space = threading_space(64, 1024, 64);
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = writeln!(s, "{}", encode_request(&Request::Shutdown, &space));
    }
}

/// A search space per parameter-name set: distinct names give distinct
/// fingerprints, and the name count is the dimension.
fn space_of(names: &[&str]) -> SearchSpace {
    SearchSpace::new(names.iter().map(|n| ParamDef::new(n, 1, 32, 1)).collect())
}

fn toy_obs(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
    (0..n)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin() - 0.5 * x[d - 1];
            (x, y)
        })
        .collect()
}

fn obs_key(x: &[f64], y: f64) -> (Vec<u64>, u64) {
    (x.iter().map(|v| v.to_bits()).collect(), y.to_bits())
}

fn factor_bits(delta: &SurrogateDelta) -> Vec<u64> {
    delta.factor.as_ref().expect("factor present").iter().map(|v| v.to_bits()).collect()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tftune_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A raw protocol-v4 client: hand-rolled lines over its own connection,
/// for byte measurement and for requests the replica API never sends.
struct Raw {
    s: TcpStream,
    r: BufReader<TcpStream>,
}

impl Raw {
    fn connect(addr: SocketAddr) -> Raw {
        let s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        let r = BufReader::new(s.try_clone().unwrap());
        Raw { s, r }
    }

    fn roundtrip_line(&mut self, req: &SurrogateRequest) -> String {
        writeln!(self.s, "{}", encode_surrogate_request(req)).unwrap();
        let mut line = String::new();
        self.r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "daemon hung up mid-request");
        line.trim_end().to_string()
    }

    fn roundtrip(&mut self, req: &SurrogateRequest) -> SurrogateResponse {
        let line = self.roundtrip_line(req);
        decode_surrogate_response(&line).unwrap()
    }

    fn hello(&mut self, space: &SearchSpace) {
        match self.roundtrip(&SurrogateRequest::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: Some(space.fingerprint()),
            dim: Some(space.dim()),
        }) {
            SurrogateResponse::HelloOk { version } => assert_eq!(version, PROTOCOL_VERSION),
            other => panic!("fingerprinted hello refused: {other:?}"),
        }
    }

    /// One full un-chunked, un-quantised sync; returns the delta and the
    /// raw line (the byte-count baseline).
    fn sync_full(&mut self) -> (SurrogateDelta, String) {
        let line = self.roundtrip_line(&SurrogateRequest::SyncFactor {
            from_n: 0,
            max_rows: None,
            quantise: false,
        });
        match decode_surrogate_response(&line).unwrap() {
            SurrogateResponse::FactorDelta { delta, pending, quantised } => {
                assert_eq!(pending, 0, "an unbounded sync is never chunked");
                assert!(!quantised);
                (delta, line)
            }
            other => panic!("unexpected sync response: {other:?}"),
        }
    }
}

#[test]
fn one_daemon_serves_three_spaces_with_per_space_parity() {
    let (addr, handle, default_factor) = fleet_daemon(FleetOptions::default());
    let addr_s = addr.to_string();

    let spaces = [
        space_of(&["a0", "a1"]),
        space_of(&["b0", "b1", "b2"]),
        space_of(&["c0", "c1", "c2", "c3"]),
    ];
    let mut rng = Rng::new(811);
    let per_space: Vec<Vec<(Vec<f64>, f64)>> = spaces
        .iter()
        .enumerate()
        .map(|(k, sp)| toy_obs(&mut rng, 16 + 4 * k, sp.dim()))
        .collect();

    // Two replicas per space tell interleaved halves concurrently: six
    // connections, three independent factors, one daemon. Each thread's
    // final guard drop performs a sync round trip, which (TCP ordering)
    // proves the daemon absorbed that connection's tells.
    std::thread::scope(|scope| {
        for (sp, obs) in spaces.iter().zip(&per_space) {
            for half in 0..2 {
                let addr = addr_s.clone();
                let chunk: Vec<_> = obs.iter().skip(half).step_by(2).cloned().collect();
                scope.spawn(move || {
                    let replica = RemoteSurrogate::connect_space(&addr, sp).unwrap();
                    for (x, y) in &chunk {
                        replica.tell(x.clone(), *y);
                    }
                    drop(replica.lock());
                });
            }
        }
    });

    for (sp, obs) in spaces.iter().zip(&per_space) {
        let reader = RemoteSurrogate::connect_space(&addr_s, sp).unwrap();
        let mut g = reader.lock();
        let n = obs.len();
        assert_eq!(g.len(), n, "space {:016x} lost a tell", sp.fingerprint());

        // The mirrored store is a permutation of exactly this space's
        // told set — no foreign rows, bit-exact across the wire.
        let mut got: Vec<_> = (0..n).map(|i| obs_key(g.x(i), g.y(i))).collect();
        let mut want: Vec<_> = obs.iter().map(|(x, y)| obs_key(x, *y)).collect();
        got.sort();
        want.sort();
        assert_eq!(got, want, "space {:016x} mirrored a foreign row", sp.fingerprint());

        // Posterior parity ≤1e-9 against a serial private model fed the
        // same canonical (service-side) observation order.
        let mut cand_rng = Rng::new(97 + sp.dim() as u64);
        let m = 6usize;
        let cand: Vec<f64> = (0..m * sp.dim()).map(|_| cand_rng.f64()).collect();
        let idx = g.conditioning_set();
        assert_eq!(idx.len(), n);
        assert!(g.sync(&idx));
        let y_canon: Vec<f64> = (0..n).map(|i| g.y(i)).collect();
        g.set_targets(&y_canon);
        let mut ws = ScoreWorkspace::default();
        g.score_into(&cand, m, 1.5, 0.3, &mut ws);

        let mut private = IncrementalGp::new(GpHyper::default());
        for i in 0..n {
            assert!(private.push(g.x(i), g.y(i)));
        }
        private.set_targets(&y_canon);
        let mut ws_ref = ScoreWorkspace::default();
        private.score_into(&cand, m, 1.5, 0.3, &mut ws_ref);

        for j in 0..m {
            assert!(
                (ws.mean[j] - ws_ref.mean[j]).abs() <= 1e-9,
                "space {:016x} mean diverged: {} vs {}",
                sp.fingerprint(),
                ws.mean[j],
                ws_ref.mean[j]
            );
            assert!(
                (ws.std[j] - ws_ref.std[j]).abs() <= 1e-9,
                "space {:016x} std diverged: {} vs {}",
                sp.fingerprint(),
                ws.std[j],
                ws_ref.std[j]
            );
        }
    }

    // Spaces share nothing: the default space never saw a row.
    assert_eq!(default_factor.len(), 0, "a fingerprinted tell leaked into the default space");

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn wrong_space_hello_is_refused_and_siblings_keep_serving() {
    let (addr, handle, _factor) =
        fleet_daemon(FleetOptions { max_spaces: 2, ..FleetOptions::default() });
    let addr_s = addr.to_string();
    let a = space_of(&["a0", "a1"]);
    let b = space_of(&["b0", "b1", "b2"]);

    // Slot 2 of 2: space A joins the fleet next to the default space.
    let ra = RemoteSurrogate::connect_space(&addr_s, &a).unwrap();
    ra.tell(vec![0.25, 0.75], 1.0);
    drop(ra.lock());

    // The fleet is full: space B gets the typed refusal, surfaced as Err
    // by connect_space — connecting was the mistake, not a transport
    // failure, so there is nothing to retry.
    let err = RemoteSurrogate::connect_space(&addr_s, &b).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("refused this search space"), "{msg}");
    assert!(msg.contains("fleet is at --max-spaces 2"), "{msg}");

    // A fingerprinted hello for an unknown space without "dim" is
    // refused too: the fleet cannot build a store of unknown dimension.
    let mut raw = Raw::connect(addr);
    match raw.roundtrip(&SurrogateRequest::Hello {
        version: PROTOCOL_VERSION,
        fingerprint: Some(0x5eed_0000_dead_0001),
        dim: None,
    }) {
        SurrogateResponse::HelloErr { reason } => {
            assert!(reason.contains("must declare \"dim\""), "{reason}")
        }
        other => panic!("unexpected {other:?}"),
    }
    // Space A's fingerprint under the wrong dimension: a mismatched
    // client build (or a fingerprint collision), typed refusal.
    match raw.roundtrip(&SurrogateRequest::Hello {
        version: PROTOCOL_VERSION,
        fingerprint: Some(a.fingerprint()),
        dim: Some(7),
    }) {
        SurrogateResponse::HelloErr { reason } => {
            assert!(
                reason.contains("declared dimension 7 != served dimension 2"),
                "{reason}"
            );
        }
        other => panic!("unexpected {other:?}"),
    }
    drop(raw);

    // None of the refusals poisoned anything: space A keeps serving on
    // its live connection, a fresh hello into A succeeds, and the
    // default space still answers legacy (un-fingerprinted) peers.
    ra.tell(vec![0.5, 0.5], 2.0);
    assert_eq!(ra.lock().len(), 2, "space A stalled after sibling refusals");
    let ra2 = RemoteSurrogate::connect_space(&addr_s, &a).unwrap();
    assert_eq!(ra2.lock().len(), 2);
    let legacy = RemoteSurrogate::connect(&addr_s).unwrap();
    assert_eq!(legacy.lock().len(), 0, "the default space absorbed a foreign row");

    drop(ra);
    drop(ra2);
    drop(legacy);
    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn chunked_and_quantised_catchup_cut_bytes_and_keep_bit_parity() {
    let (addr, handle, authority) = fleet_daemon(FleetOptions::default());
    let addr_s = addr.to_string();
    let (n, d, k) = (48usize, 5usize, 16usize);

    // A replica that will catch up in quantised 16-row chunks connects
    // while the factor is still empty (its initial sync is trivially
    // complete), so the whole store arrives through the chunk loop.
    let replica = RemoteSurrogate::connect(&addr_s).unwrap().with_catchup(Some(k), true);

    let mut rng = Rng::new(1337);
    let obs = toy_obs(&mut rng, n, d);
    for (x, y) in &obs {
        authority.tell(x.clone(), *y);
    }
    drop(authority.lock()); // drain: the served store is now at n rows

    // Byte-count baseline: one full un-quantised transfer.
    let mut raw = Raw::connect(addr);
    let (full, full_line) = raw.sync_full();
    assert_eq!(full.total_n, n);
    let bits = factor_bits(&full);
    assert_eq!(bits.len(), packed_len(n));

    // Quantised full transfer: measurably smaller, decodes bit-identical
    // (the acceptance criterion: compressed catch-up < full transfer).
    let quant_line = raw.roundtrip_line(&SurrogateRequest::SyncFactor {
        from_n: 0,
        max_rows: None,
        quantise: true,
    });
    assert!(
        quant_line.len() < full_line.len(),
        "quantised sync ({} bytes) is not smaller than the plain one ({} bytes)",
        quant_line.len(),
        full_line.len()
    );
    match decode_surrogate_response(&quant_line).unwrap() {
        SurrogateResponse::FactorDelta { delta, pending, quantised } => {
            assert_eq!(pending, 0);
            assert!(quantised, "the daemon ignored the quantise knob");
            assert_eq!(factor_bits(&delta), bits, "quantised decode is not bit-identical");
        }
        other => panic!("unexpected {other:?}"),
    }

    // Chunked + quantised catch-up from zero: every chunk line is
    // bounded well below the full transfer, the pending counts walk
    // down, and the chunks reassemble the factor bit-identically through
    // the same import path a replica uses.
    let mirror = SharedSurrogate::new(GpHyper::default());
    let mut chunk_bytes = 0usize;
    let mut pendings = Vec::new();
    loop {
        let line = raw.roundtrip_line(&SurrogateRequest::SyncFactor {
            from_n: mirror.len(),
            max_rows: Some(k),
            quantise: true,
        });
        chunk_bytes += line.len();
        assert!(
            line.len() < full_line.len(),
            "chunk ({} bytes) is not bounded below the full transfer ({} bytes)",
            line.len(),
            full_line.len()
        );
        match decode_surrogate_response(&line).unwrap() {
            SurrogateResponse::FactorDelta { delta, pending, quantised } => {
                assert!(quantised);
                assert!(mirror.import_delta(&delta), "chunk import rejected");
                pendings.push(pending);
                if pending == 0 {
                    break;
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(pendings, vec![n - k, n - 2 * k, 0], "chunk cadence");
    assert_eq!(mirror.len(), n);
    let mirror_delta = mirror.export_delta(0).unwrap();
    assert_eq!(factor_bits(&mirror_delta), bits, "chunked reassembly is not bit-identical");
    for (i, (x, y)) in obs_key_rows(&full).iter().enumerate() {
        assert_eq!(
            (x.clone(), *y),
            obs_key(&mirror_delta.rows[i].0, mirror_delta.rows[i].1),
            "row {i} diverged across the chunked transfer"
        );
    }
    // Quantisation savings beat the per-chunk envelope overhead: the
    // whole chunked+quantised catch-up still costs fewer bytes than one
    // plain full transfer.
    assert!(
        chunk_bytes < full_line.len(),
        "chunked+quantised catch-up ({chunk_bytes} bytes) exceeds the full transfer ({} bytes)",
        full_line.len()
    );
    drop(raw);

    // The replica-level chunk loop: one lock() drives sync() through all
    // three chunks and the posterior lands bit-identical to the
    // authority's.
    let mut cand_rng = Rng::new(1338);
    let cand: Vec<f64> = (0..4 * d).map(|_| cand_rng.f64()).collect();
    let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
    {
        let mut ga = authority.lock();
        let idx = ga.conditioning_set();
        assert!(ga.sync(&idx));
        let y: Vec<f64> = idx.iter().map(|&i| ga.y(i)).collect();
        ga.set_targets(&y);
        ga.score_into(&cand, 4, 1.5, 0.0, &mut wa);
    }
    {
        let mut gr = replica.lock();
        assert_eq!(gr.len(), n, "replica chunk loop stopped early");
        let idx = gr.conditioning_set();
        assert!(gr.sync(&idx));
        let y: Vec<f64> = idx.iter().map(|&i| gr.y(i)).collect();
        gr.set_targets(&y);
        gr.score_into(&cand, 4, 1.5, 0.0, &mut wb);
    }
    for j in 0..4 {
        assert_eq!(wa.mean[j].to_bits(), wb.mean[j].to_bits(), "mean bits diverged");
        assert_eq!(wa.std[j].to_bits(), wb.std[j].to_bits(), "std bits diverged");
    }

    drop(replica);
    shutdown_daemon(addr);
    let _ = handle.join();
}

fn obs_key_rows(delta: &SurrogateDelta) -> Vec<(Vec<u64>, u64)> {
    delta.rows.iter().map(|(x, y)| obs_key(x, *y)).collect()
}

#[test]
fn idle_spaces_evict_to_disk_and_a_re_hello_restores_bit_identically() {
    let root = tmp_dir("fleet_evict");
    let (addr, handle, _factor) = fleet_daemon(FleetOptions {
        idle_ttl: Some(Duration::from_millis(60)),
        state_dir: Some(root.clone()),
        ..FleetOptions::default()
    });
    let addr_s = addr.to_string();
    let a = space_of(&["e0", "e1", "e2"]);

    let mut rng = Rng::new(271);
    let obs = toy_obs(&mut rng, 12, a.dim());
    let ra = RemoteSurrogate::connect_space(&addr_s, &a).unwrap();
    for (x, y) in &obs {
        ra.tell(x.clone(), *y);
    }
    drop(ra.lock());

    // Capture the authority factor while the space is still bound.
    let bits_before = {
        let mut raw = Raw::connect(addr);
        raw.hello(&a);
        let (d, _) = raw.sync_full();
        assert_eq!(d.total_n, obs.len());
        factor_bits(&d)
    };
    drop(ra); // last binder gone: the idle clock starts

    // Eviction observable: the sweeper snapshots the space into its
    // namespace before dropping it from memory.
    let dir = space_dir(&root, a.fingerprint());
    let mut snapped = false;
    for _ in 0..2000 {
        if !list_snapshots(&dir).unwrap().is_empty() {
            snapped = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(snapped, "idle space was never evicted (no snapshot in {})", dir.display());

    // A re-hello lazily recovers the evicted space from its namespace —
    // same rows, same packed factor, bit for bit.
    let mut raw = Raw::connect(addr);
    raw.hello(&a);
    let (d, _) = raw.sync_full();
    assert_eq!(d.total_n, obs.len(), "recovered space lost rows");
    assert_eq!(factor_bits(&d), bits_before, "recovered factor is not bit-identical");
    drop(raw);

    shutdown_daemon(addr);
    let _ = handle.join();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn chaos_drill_killed_fleet_recovers_and_replicas_redial_into_their_spaces() {
    let root = tmp_dir("fleet_chaos");
    let (addr, handle, _f1) =
        fleet_daemon(FleetOptions { state_dir: Some(root.clone()), ..FleetOptions::default() });
    let addr_s = addr.to_string();
    let spaces = [
        space_of(&["k0", "k1"]),
        space_of(&["m0", "m1", "m2"]),
        space_of(&["p0", "p1", "p2", "p3"]),
    ];
    let mut rng = Rng::new(4242);

    // Three active spaces, one in-flight replica each (generous redial
    // budget: the drill's whole point is surviving the restart).
    let replicas: Vec<RemoteSurrogate> = spaces
        .iter()
        .map(|sp| {
            RemoteSurrogate::connect_space(&addr_s, sp)
                .unwrap()
                .with_reconnect(20, Duration::from_millis(10))
        })
        .collect();
    let mut per_space = Vec::new();
    for (sp, r) in spaces.iter().zip(&replicas) {
        let obs = toy_obs(&mut rng, 6 + sp.dim(), sp.dim());
        for (x, y) in &obs {
            r.tell(x.clone(), *y);
        }
        drop(r.lock());
        per_space.push(obs);
    }
    let bits_before: Vec<Vec<u64>> = spaces
        .iter()
        .map(|sp| {
            let mut raw = Raw::connect(addr);
            raw.hello(sp);
            let (d, _) = raw.sync_full();
            factor_bits(&d)
        })
        .collect();

    // Kill the daemon mid-campaign. Severing each replica's wire stands
    // in for the daemon's sockets dying with its process (in-process the
    // handler threads would otherwise keep the port alive); the replicas
    // themselves stay live, exactly like tuner processes outliving a
    // crashed daemon.
    for r in &replicas {
        r.sever();
    }
    shutdown_daemon(addr);
    let _ = handle.join();

    // Restart on the same port against the same state dir: boot
    // recovery brings the whole fleet back before the first hello.
    let (server2, _f2) = TargetServer::bind_surrogate_only(&addr_s, GpHyper::default()).unwrap();
    let server2 = server2
        .with_fleet_options(FleetOptions {
            state_dir: Some(root.clone()),
            ..FleetOptions::default()
        })
        .unwrap();
    let (_, handle2) = server2.spawn().unwrap();

    // Every space recovered bit-identically — rows and packed factor.
    for (sp, bits) in spaces.iter().zip(&bits_before) {
        let mut raw = Raw::connect(addr);
        raw.hello(sp);
        let (d, _) = raw.sync_full();
        assert_eq!(
            &factor_bits(&d),
            bits,
            "space {:016x} did not recover bit-identically",
            sp.fingerprint()
        );
    }

    // The in-flight replicas redial through the existing backoff and
    // land on the *right* spaces: a new row told on the dim-2 replica
    // reaches space 0 and only space 0.
    replicas[0].tell(vec![0.5, 0.5], 9.0);
    assert_eq!(
        replicas[0].lock().len(),
        per_space[0].len() + 1,
        "replica 0 did not catch up after its redial"
    );
    assert_eq!(replicas[1].lock().len(), per_space[1].len(), "replica 1 caught a foreign row");
    assert_eq!(replicas[2].lock().len(), per_space[2].len(), "replica 2 caught a foreign row");
    {
        let mut raw = Raw::connect(addr);
        raw.hello(&spaces[0]);
        let (d, _) = raw.sync_full();
        assert_eq!(d.total_n, per_space[0].len() + 1, "the post-restart tell was lost");
    }

    drop(replicas);
    shutdown_daemon(addr);
    let _ = handle2.join();
    std::fs::remove_dir_all(&root).ok();
}
