//! The surrogate service contract, pinned over real loopback TCP:
//!
//! 1. N replicas (threads with their own connections — thread-per-process
//!    stand-ins) telling one served factor produce, after sync, a
//!    posterior within 1e-9 of the serial private-model path fed the same
//!    (canonical, service-side) observation order — mirroring
//!    `rust/tests/shared_surrogate.rs` one protocol layer up.
//! 2. Replica catch-up after Δn new observations transfers only the
//!    packed-factor *suffix*: a byte-count bound on the encoded
//!    `factor-delta` line.
//! 3. Two BO tuner sessions sharing one served factor match a
//!    single-process `SharedSurrogate` replay of the same observation
//!    order (the ISSUE 4 acceptance criterion).
//! 4. Constant-liar leases: one replica's in-flight fantasies surface as
//!    ambient points for its siblings, and expire when its connection
//!    dies.
//! 5. Version/handshake hygiene: a daemon without a hosted factor refuses
//!    replicas loudly.

use tftune::evaluator::{sim_pool, Objective};
use tftune::gp::{
    GpHyper, IncrementalGp, RemoteSurrogate, ScoreWorkspace, SharedSurrogate, SurrogateHandle,
};
use tftune::objectives::{ObjectiveSet, Scalarization};
use tftune::server::proto::{encode_surrogate_response, SurrogateResponse};
use tftune::server::TargetServer;
use tftune::sim::ModelId;
use tftune::space::threading_space;
use tftune::util::linalg::packed_len;
use tftune::util::Rng;

fn serve_factor() -> (
    std::net::SocketAddr,
    std::thread::JoinHandle<anyhow::Result<usize>>,
    SharedSurrogate,
) {
    let (server, factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let (addr, handle) = server.spawn().unwrap();
    (addr, handle, factor)
}

fn shutdown_daemon(addr: std::net::SocketAddr) {
    use std::io::Write;
    use tftune::server::proto::{encode_request, Request};
    let space = threading_space(64, 1024, 64);
    if let Ok(mut s) = std::net::TcpStream::connect(addr) {
        let _ = writeln!(s, "{}", encode_request(&Request::Shutdown, &space));
    }
}

fn toy_obs(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
    (0..n)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin() - 0.5 * x[d - 1];
            (x, y)
        })
        .collect()
}

fn obs_key(x: &[f64], y: f64) -> (Vec<u64>, u64) {
    (x.iter().map(|v| v.to_bits()).collect(), y.to_bits())
}

#[test]
fn replicas_over_tcp_match_serial_private_model() {
    let hyper = GpHyper::default();
    let mut rng = Rng::new(71);
    let (n, d) = (48usize, 4usize);
    let obs = toy_obs(&mut rng, n, d);
    let cand: Vec<f64> = (0..8 * d).map(|_| rng.f64()).collect();

    let (addr, handle, _factor) = serve_factor();
    let addr_s = addr.to_string();

    // Four replicas tell disjoint chunks concurrently over their own
    // connections — the thread-per-process stand-in for four tuner
    // processes.
    std::thread::scope(|scope| {
        for chunk in obs.chunks(n / 4) {
            let addr = addr_s.clone();
            scope.spawn(move || {
                let replica = RemoteSurrogate::connect(&addr).unwrap();
                for (x, y) in chunk {
                    replica.tell(x.clone(), *y);
                }
            });
        }
    });

    // Tells are fire-and-forget: poll a reader replica until the service
    // has absorbed all of them (each lock performs one sync round trip).
    let reader = RemoteSurrogate::connect(&addr_s).unwrap();
    let mut seen = 0;
    for _ in 0..2000 {
        seen = reader.lock().len();
        if seen == n {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(seen, n, "a remote tell was lost");

    let mut g = reader.lock();
    // The mirrored store is a permutation of the told set, bit-exact
    // across the wire.
    let mut got: Vec<_> = (0..n).map(|i| obs_key(g.x(i), g.y(i))).collect();
    let mut want: Vec<_> = obs.iter().map(|(x, y)| obs_key(x, *y)).collect();
    got.sort();
    want.sort();
    assert_eq!(got, want, "mirrored observations are not the told set");

    // Score through the replicated factor (canonical service order)...
    let idx = g.conditioning_set();
    assert_eq!(idx.len(), n);
    assert!(g.sync(&idx));
    let y_canon: Vec<f64> = (0..n).map(|i| g.y(i)).collect();
    g.set_targets(&y_canon);
    let mut ws = ScoreWorkspace::default();
    g.score_into(&cand, 8, 1.5, 0.3, &mut ws);

    // ...and through a serial private model fed the same canonical order.
    let mut private = IncrementalGp::new(hyper);
    for i in 0..n {
        assert!(private.push(g.x(i), g.y(i)));
    }
    private.set_targets(&y_canon);
    let mut ws_ref = ScoreWorkspace::default();
    private.score_into(&cand, 8, 1.5, 0.3, &mut ws_ref);

    for j in 0..8 {
        assert!(
            (ws.mean[j] - ws_ref.mean[j]).abs() <= 1e-9,
            "mean diverged across the service: {} vs {}",
            ws.mean[j],
            ws_ref.mean[j]
        );
        assert!(
            (ws.std[j] - ws_ref.std[j]).abs() <= 1e-9,
            "std diverged across the service: {} vs {}",
            ws.std[j],
            ws_ref.std[j]
        );
    }
    drop(g);

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn replica_catchup_transfers_only_the_factor_suffix() {
    // The byte-count bound of the ISSUE 4 acceptance criteria: catching
    // up Δn=4 rows at n=64 must ship the 4 suffix factor rows
    // (packed_len(64) - packed_len(60) = 250 values), not the full
    // packed_len(64) = 2080-value factor — bounded here on the actual
    // encoded wire line.
    let hyper = GpHyper::default();
    let mut rng = Rng::new(72);
    let obs = toy_obs(&mut rng, 64, 5);

    let authority = SharedSurrogate::new(hyper);
    for (x, y) in &obs {
        authority.tell(x.clone(), *y);
    }
    let full = authority.export_delta(0).unwrap();
    assert_eq!(full.factor.as_ref().unwrap().len(), packed_len(64));
    let full_line = encode_surrogate_response(&SurrogateResponse::FactorDelta {
        delta: full,
        pending: 0,
        quantised: false,
    });

    let delta = authority.export_delta(60).unwrap();
    assert_eq!(delta.rows.len(), 4);
    assert_eq!(
        delta.factor.as_ref().unwrap().len(),
        packed_len(64) - packed_len(60),
        "catch-up must carry exactly the suffix factor rows"
    );
    let delta_line = encode_surrogate_response(&SurrogateResponse::FactorDelta {
        delta: delta.clone(),
        pending: 0,
        quantised: false,
    });
    assert!(
        delta_line.len() * 4 < full_line.len(),
        "Δn=4 catch-up ({} bytes) is not a small fraction of a full sync ({} bytes)",
        delta_line.len(),
        full_line.len()
    );

    // And the transferred suffix is sufficient: a replica at 60 rows
    // lands bit-identical to the authority.
    let replica = SharedSurrogate::new(hyper);
    for (x, y) in &obs[..60] {
        replica.tell(x.clone(), *y);
    }
    drop(replica.lock());
    assert!(replica.import_delta(&delta));
    let cand: Vec<f64> = (0..10).map(|_| rng.f64()).collect();
    let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
    for (h, ws) in [(&authority, &mut wa), (&replica, &mut wb)] {
        let mut g = h.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        let y: Vec<f64> = idx.iter().map(|&i| g.y(i)).collect();
        g.set_targets(&y);
        g.score_into(&cand, 2, 1.5, 0.0, ws);
    }
    for j in 0..2 {
        assert_eq!(wa.mean[j].to_bits(), wb.mean[j].to_bits());
        assert_eq!(wa.std[j].to_bits(), wb.std[j].to_bits());
    }
}

#[test]
fn two_tuner_sessions_match_single_process_replay() {
    // The acceptance criterion: two BO tuners sharing one served factor
    // produce a posterior within 1e-9 of the single-process
    // SharedSurrogate replay of the same observation order.
    let model = ModelId::NcfFp32;
    let space = model.space();
    let (addr, handle, _factor) = serve_factor();

    let mut group = tftune::session::SessionGroup::remote_shared_bo(
        &space,
        &addr.to_string(),
        &[81, 82],
        tftune::session::Budget::evaluations(12),
        |i| sim_pool(model, 800 + i as u64, 0.0, Objective::Throughput, 2),
    )
    .unwrap();
    let histories = group.run().unwrap();
    assert_eq!(histories.len(), 2);
    let total: usize = histories.iter().map(|h| h.len()).sum();
    assert_eq!(total, 24);

    // Pull the canonical observation order off the service (poll: the
    // final tells are fire-and-forget).
    let reader = RemoteSurrogate::connect(&addr.to_string()).unwrap();
    let mut seen = 0;
    for _ in 0..2000 {
        seen = reader.lock().len();
        if seen == total {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(seen, total, "the served factor missed a trial");

    let mut g = reader.lock();
    // Single-process replay: the same observations, in the same order,
    // through a local SharedSurrogate. (Hyper read through the guard —
    // the handle's own accessor would re-lock the mirror state.)
    let replay = SharedSurrogate::new(g.hyper());
    for i in 0..total {
        replay.tell(g.x(i).to_vec(), g.y(i));
    }
    let mut gr = replay.lock();
    assert_eq!(gr.len(), total);
    for i in 0..total {
        assert_eq!(
            obs_key(g.x(i), g.y(i)),
            obs_key(gr.x(i), gr.y(i)),
            "replay store diverged at row {i}"
        );
    }

    let mut rng = Rng::new(83);
    let cand: Vec<f64> = (0..4 * space.dim()).map(|_| rng.f64()).collect();
    let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
    for (guard, ws) in [(&mut g, &mut wa), (&mut gr, &mut wb)] {
        let idx = guard.conditioning_set();
        assert!(guard.sync(&idx));
        let y: Vec<f64> = idx.iter().map(|&i| guard.y(i)).collect();
        guard.set_targets(&y);
        guard.score_into(&cand, 4, 1.5, 0.0, ws);
    }
    for j in 0..4 {
        assert!(
            (wa.mean[j] - wb.mean[j]).abs() <= 1e-9,
            "posterior mean diverged from the single-process replay: {} vs {}",
            wa.mean[j],
            wb.mean[j]
        );
        assert!(
            (wa.std[j] - wb.std[j]).abs() <= 1e-9,
            "posterior std diverged from the single-process replay: {} vs {}",
            wa.std[j],
            wb.std[j]
        );
    }
    drop(g);
    drop(gr);

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn two_replica_multi_objective_run_matches_single_process_replay() {
    // Two multi-objective BO tuner sessions (their own TCP connections)
    // share one served factor; the K objective columns ride the wire.
    // After the run, the mirrored store replayed through a local
    // SharedSurrogate must produce an identical K-objective posterior
    // (≤1e-9) — same rows, same columns, same factor.
    let model = ModelId::NcfFp32;
    let space = model.space();
    let set = ObjectiveSet::parse("throughput,p99_latency_ms:min").unwrap();
    let (addr, handle, _factor) = serve_factor();

    let mut group = tftune::session::SessionGroup::new();
    for (i, seed) in [91u64, 92].into_iter().enumerate() {
        let replica = RemoteSurrogate::connect(&addr.to_string()).unwrap();
        let tuner = Box::new(
            tftune::algorithms::BayesOpt::new(space.clone(), seed)
                .with_shared_surrogate(replica)
                .with_objectives(set.clone(), Scalarization::Weighted(vec![0.6, 0.4])),
        );
        group.push(
            tftune::session::TuningSession::new(
                tuner,
                sim_pool(model, 900 + i as u64, 0.0, Objective::Throughput, 2),
                tftune::session::Budget::evaluations(10),
            )
            .with_objectives(set.clone()),
        );
    }
    let histories = group.run().unwrap();
    let total: usize = histories.iter().map(|h| h.len()).sum();
    assert_eq!(total, 20);
    for h in &histories {
        for e in h.iter() {
            assert_eq!(e.objectives.len(), 2, "history must record the K-vector");
        }
    }

    // Pull the canonical store (poll: final tells are fire-and-forget).
    let reader = RemoteSurrogate::connect(&addr.to_string()).unwrap();
    let mut seen = 0;
    for _ in 0..2000 {
        seen = reader.lock().len();
        if seen == total {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(seen, total, "the served factor missed a trial");

    let mut g = reader.lock();
    // Every mirrored row carries its secondary column, bit-exact.
    for i in 0..total {
        assert_eq!(g.y_extras(i).len(), 1, "row {i} lost its p99 column over the wire");
        assert!(g.y_extras(i)[0].is_finite());
    }
    // Single-process replay of the same rows + columns.
    let replay = SharedSurrogate::new(g.hyper());
    for i in 0..total {
        let mut ys = vec![g.y(i)];
        ys.extend_from_slice(g.y_extras(i));
        replay.tell_multi(g.x(i).to_vec(), ys);
    }
    let mut gr = replay.lock();
    assert_eq!(gr.len(), total);

    let mut rng = Rng::new(93);
    let cand: Vec<f64> = (0..4 * space.dim()).map(|_| rng.f64()).collect();
    let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
    for (guard, ws) in [(&mut g, &mut wa), (&mut gr, &mut wb)] {
        let idx = guard.conditioning_set();
        assert!(guard.sync(&idx));
        let t0: Vec<f64> = idx.iter().map(|&i| guard.y(i)).collect();
        let t1: Vec<f64> = idx.iter().map(|&i| guard.y_extras(i)[0]).collect();
        guard.score_multi_into(&cand, 4, &[&t0, &t1], ws);
    }
    for j in 0..4 {
        for k in 0..2 {
            assert!(
                (wa.mean_obj[k * 4 + j] - wb.mean_obj[k * 4 + j]).abs() <= 1e-9,
                "objective {k} posterior diverged from the replay at candidate {j}: {} vs {}",
                wa.mean_obj[k * 4 + j],
                wb.mean_obj[k * 4 + j]
            );
        }
        assert!((wa.std[j] - wb.std[j]).abs() <= 1e-9);
    }
    drop(g);
    drop(gr);
    // Close every replica connection before asking the daemon to stop,
    // so its per-connection threads see EOF and serve() can join them.
    drop(reader);
    drop(group);

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn v2_client_against_v3_server_degrades_to_single_objective() {
    // A protocol-v2 peer (raw lines, no "ys" anywhere) against the
    // current daemon: the handshake negotiates down to v2, v2-format
    // tells land as single-objective rows next to v3 rows, and the sync
    // answer decodes under v2 expectations — no refusal, no panic.
    use std::io::{BufReader, Write};
    use tftune::server::proto::{decode_surrogate_response, PROTOCOL_VERSION};

    let (addr, handle, factor) = serve_factor();
    assert_eq!(PROTOCOL_VERSION, 4, "update this test alongside the protocol");

    // A v3 tuner contributes a two-column row first.
    factor.tell_multi(vec![0.25, 0.75], vec![1.0, -9.0]);

    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    fn roundtrip(
        s: &mut std::net::TcpStream,
        reader: &mut BufReader<std::net::TcpStream>,
        line: &str,
    ) -> String {
        use std::io::{BufRead, Write};
        writeln!(s, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    }

    // v2 handshake: answered at v2, not refused.
    let resp = roundtrip(&mut s, &mut reader, r#"{"type":"hello","version":2}"#);
    match decode_surrogate_response(&resp).unwrap() {
        SurrogateResponse::HelloOk { version } => assert_eq!(version, 2),
        other => panic!("unexpected {other:?}"),
    }
    // v2 tell: no "ys" key at all (fire-and-forget, no response).
    writeln!(s, r#"{{"type":"tell-obs","x":[0.5,0.5],"y":2.0}}"#).unwrap();
    // v2 sync decodes the mixed store without tripping on the v3 row.
    let resp = roundtrip(&mut s, &mut reader, r#"{"type":"sync-factor","from_n":0}"#);
    match decode_surrogate_response(&resp).unwrap() {
        SurrogateResponse::FactorDelta { delta: d, pending, quantised } => {
            assert_eq!(pending, 0, "a v2 sync is never chunked");
            assert!(!quantised, "a v2 sync is never quantised");
            assert_eq!(d.total_n, 2, "both tells landed");
            assert_eq!(d.rows[0].1, 1.0);
            assert_eq!(d.rows[1].1, 2.0);
            // the v3 row still carries its column; the v2 row is bare
            assert_eq!(d.extras.len(), 2);
            assert_eq!(d.extras[0], vec![-9.0]);
            assert!(d.extras[1].is_empty(), "v2 tell degraded to single-objective");
        }
        other => panic!("unexpected {other:?}"),
    }
    drop(s);
    drop(reader);

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn in_guard_hyper_selection_writes_through_to_siblings() {
    // The ROADMAP scale-out bullet: an in-guard `ensure_hyper` on a
    // replica (what per-ask lengthscale selection performs) must publish
    // via `set-hyper` when the guard drops, so sibling replicas converge
    // on one hyper instead of each selecting locally.
    let (addr, handle, factor) = serve_factor();
    let addr_s = addr.to_string();
    let a = RemoteSurrogate::connect(&addr_s).unwrap();
    let b = RemoteSurrogate::connect(&addr_s).unwrap();

    let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
    {
        let mut ga = a.lock();
        ga.ensure_hyper(new);
    } // guard drop publishes set-hyper synchronously (request/response)
    assert_eq!(
        factor.hyper(),
        new,
        "in-guard hyper change did not reach the served factor"
    );
    drop(b.lock()); // sibling sync adopts the authority's hypers
    assert_eq!(b.hyper(), new, "sibling replica did not converge on the selected hyper");
    drop(a);
    drop(b);

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn leases_condition_siblings_and_expire_on_disconnect() {
    let (addr, handle, _factor) = serve_factor();
    let addr_s = addr.to_string();

    let a = RemoteSurrogate::connect(&addr_s).unwrap();
    let b = RemoteSurrogate::connect(&addr_s).unwrap();

    // A batch on replica A leaves an in-flight fantasy: published as a
    // lease when its guard drops (synchronously, so no poll needed).
    {
        let mut ga = a.lock();
        assert!(ga.extend_fantasy(&[0.4, 0.6], 0.0));
    }
    {
        let gb = b.lock();
        assert_eq!(gb.ambient_len(), 1, "sibling lease not served");
        let (x, lie) = gb.ambient_point(0);
        assert_eq!(x, vec![0.4, 0.6]);
        assert_eq!(lie, 0.0);
    }
    // A's own view never includes its own lease; re-extending the same
    // in-flight point keeps the lease alive (the publish hook dedups an
    // unchanged batch instead of retract-and-republish).
    {
        let mut ga = a.lock();
        assert_eq!(ga.ambient_len(), 0, "a replica saw its own lease");
        assert!(ga.extend_fantasy(&[0.4, 0.6], 0.0));
    }
    {
        let gb = b.lock();
        assert_eq!(gb.ambient_len(), 1, "unchanged lease was dropped on republish");
    }

    // Kill replica A without retracting: the service must expire its
    // lease when the connection closes.
    drop(a);
    let mut ambient = usize::MAX;
    for _ in 0..2000 {
        ambient = b.lock().ambient_len();
        if ambient == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(ambient, 0, "dead replica's lease never expired");

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn hyper_changes_write_through_to_every_replica() {
    let (addr, handle, factor) = serve_factor();
    let addr_s = addr.to_string();
    let a = RemoteSurrogate::connect(&addr_s).unwrap();
    let b = RemoteSurrogate::connect(&addr_s).unwrap();

    let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
    a.set_hyper(new);
    assert_eq!(a.hyper(), new);
    assert_eq!(factor.hyper(), new, "set-hyper did not reach the served factor");
    drop(b.lock()); // sync adopts the authority's hypers
    assert_eq!(b.hyper(), new, "sibling replica did not adopt the new hypers");

    shutdown_daemon(addr);
    let _ = handle.join();
}

#[test]
fn replica_refuses_a_daemon_without_a_factor() {
    // A plain measurement daemon answers the handshake but hosts no
    // factor: the replica must fail loudly at connect, not limp along.
    let model = ModelId::NcfFp32;
    let server = TargetServer::bind(
        "127.0.0.1:0",
        model.space(),
        Box::new(tftune::evaluator::SimEvaluator::new(model, 1)),
    )
    .unwrap();
    let (addr, handle) = server.spawn().unwrap();
    let err = RemoteSurrogate::connect(&addr.to_string()).unwrap_err();
    assert!(err.to_string().contains("hosts no shared surrogate"), "{err}");
    shutdown_daemon(addr);
    let _ = handle.join();
}
