//! Protocol fuzz: a live fleet daemon fed hundreds of malformed frames
//! — truncated lines, wrong handshake versions, mangled fingerprints,
//! non-finite floats, wrong-dimension and oversized tells, nonsense
//! knob values — from a seeded in-tree [`Rng`].
//!
//! The contract under test is the *blast radius*: every bad frame is a
//! per-connection problem (an `error`/`hello-err` response, or a
//! silently dropped fire-and-forget tell), never a daemon crash and
//! never a corrupted sibling space. After the storm, a baseline space's
//! factor must be bit-identical to its pre-fuzz state and a well-formed
//! client must get normal service.
//!
//! A second storm aims the same contract at the read-only event plane
//! (`--events-addr`): hostile subscribes, truncated/oversized frames and
//! raw binary noise each cost one typed `error` (or a silent close) on
//! their own connection, while honest subscribers and the surrogate
//! plane keep working, bit-for-bit.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tftune::gp::{GpHyper, RemoteSurrogate, SurrogateDelta, SurrogateHandle};
use tftune::server::proto::{
    decode_surrogate_response, encode_surrogate_request, SurrogateRequest, SurrogateResponse,
    PROTOCOL_VERSION,
};
use tftune::server::{FleetOptions, TargetServer};
use tftune::space::{threading_space, ParamDef, SearchSpace};
use tftune::util::Rng;

/// How long a fuzz connection waits for a response line. Generous: the
/// daemon answers malformed frames immediately, so a timeout here means
/// the test lost a response it was owed, which is itself a failure.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

fn baseline_space() -> SearchSpace {
    SearchSpace::new(vec![
        ParamDef::new("h0", 1, 32, 1),
        ParamDef::new("h1", 1, 32, 1),
        ParamDef::new("h2", 1, 32, 1),
    ])
}

struct Fuzz {
    s: TcpStream,
    r: BufReader<TcpStream>,
}

impl Fuzz {
    fn connect(addr: SocketAddr) -> Fuzz {
        let s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        s.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
        let r = BufReader::new(s.try_clone().unwrap());
        Fuzz { s, r }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.s, "{line}").unwrap();
    }

    /// Read one response line; the daemon owes us one, so an empty read
    /// (EOF: the daemon hung up) or a timeout is a failed contract.
    fn expect_response(&mut self, ctx: &str) -> SurrogateResponse {
        let mut line = String::new();
        self.r
            .read_line(&mut line)
            .unwrap_or_else(|e| panic!("no response after {ctx}: {e}"));
        assert!(!line.is_empty(), "daemon hung up after {ctx}");
        decode_surrogate_response(line.trim_end())
            .unwrap_or_else(|e| panic!("undecodable response after {ctx}: {e} ({line:?})"))
    }

    fn hello(&mut self, space: &SearchSpace) {
        self.send(&encode_surrogate_request(&SurrogateRequest::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: Some(space.fingerprint()),
            dim: Some(space.dim()),
        }));
        match self.expect_response("hello") {
            SurrogateResponse::HelloOk { .. } => {}
            other => panic!("baseline hello refused mid-fuzz: {other:?}"),
        }
    }

    /// The per-iteration liveness probe: a well-formed sync on the same
    /// connection that just sent garbage must still be answered with a
    /// well-formed factor-delta.
    fn probe(&mut self, ctx: &str) -> SurrogateDelta {
        self.send(&encode_surrogate_request(&SurrogateRequest::SyncFactor {
            from_n: 0,
            max_rows: None,
            quantise: false,
        }));
        match self.expect_response(ctx) {
            SurrogateResponse::FactorDelta { delta, pending, .. } => {
                assert_eq!(pending, 0, "unbounded probe sync came back chunked ({ctx})");
                delta
            }
            other => panic!("probe after {ctx} got {other:?}"),
        }
    }
}

fn factor_bits(delta: &SurrogateDelta) -> Vec<u64> {
    delta.factor.as_ref().expect("factor present").iter().map(|v| v.to_bits()).collect()
}

/// One malformed frame: the line to send, how many response lines it
/// owes us (a frame that decodes as a fire-and-forget tell owes none),
/// whether it is a hello (which may legitimately re-bind the connection
/// to another space, so the probe must not pin the row count), and a
/// label for failure messages.
struct Frame {
    line: String,
    responses: usize,
    rebinds: bool,
    label: &'static str,
}

fn valid_encodings(rng: &mut Rng) -> Vec<String> {
    vec![
        encode_surrogate_request(&SurrogateRequest::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: Some(rng.next_u64()),
            dim: Some(1 + rng.index(8)),
        }),
        encode_surrogate_request(&SurrogateRequest::TellObs {
            x: (0..3).map(|_| rng.f64()).collect(),
            y: rng.f64(),
            ys: Vec::new(),
        }),
        encode_surrogate_request(&SurrogateRequest::SyncFactor {
            from_n: rng.index(4),
            max_rows: Some(1 + rng.index(16)),
            quantise: rng.bool(0.5),
        }),
        encode_surrogate_request(&SurrogateRequest::AskLease {
            points: vec![((0..3).map(|_| rng.f64()).collect(), rng.f64())],
        }),
    ]
}

fn make_frame(rng: &mut Rng) -> Frame {
    match rng.index(10) {
        // Truncated valid frames: any strict prefix of a one-line JSON
        // object is unbalanced, so the decoder must refuse it (one
        // error response), never panic on it.
        0 => {
            let encodings = valid_encodings(rng);
            let full = rng.choice(&encodings);
            let cut = 1 + rng.index(full.len() - 1);
            Frame {
                line: full[..cut].to_string(),
                responses: 1,
                rebinds: false,
                label: "truncated frame",
            }
        }
        // Printable garbage that was never JSON.
        1 => {
            let n = 1 + rng.index(120);
            let junk: String = (0..n)
                .map(|_| {
                    let c = b'!' + (rng.index(93) as u8); // '!'..='}' — printable ASCII
                    if c == b'"' || c == b'\\' { '.' } else { c as char }
                })
                .collect();
            Frame { line: junk, responses: 1, rebinds: false, label: "printable garbage" }
        }
        // Handshake versions the decoder must refuse: negative, beyond
        // u32, or not a number at all.
        2 => {
            let v = *rng.choice(&["-1", "99999999999", "\"four\"", "3.5", "null"]);
            Frame {
                line: format!("{{\"type\":\"hello\",\"version\":{v}}}"),
                responses: 1,
                rebinds: true,
                label: "mangled hello version",
            }
        }
        // Mangled fingerprints: non-hex, wrong width, or a syntactically
        // valid unknown fingerprint with no "dim" to build a store from.
        3 => {
            let fp = *rng.choice(&[
                "\"xyz\"",
                "\"0123456789abcdef0\"", // 17 digits
                "\"abc\"",               // 3 digits
                "12345",                 // not a string
                "\"00000000deadbeef\"",  // well-formed but unknown, dim-less
            ]);
            Frame {
                line: format!(
                    "{{\"type\":\"hello\",\"version\":{PROTOCOL_VERSION},\"space\":{fp}}}"
                ),
                responses: 1,
                rebinds: true,
                label: "mangled fingerprint",
            }
        }
        // Non-finite floats are not JSON: the parser must refuse the
        // line outright rather than let a NaN into a factor.
        4 => {
            let bad = *rng.choice(&["NaN", "Infinity", "-Infinity", "nan"]);
            Frame {
                line: format!("{{\"type\":\"tell-obs\",\"x\":[0.5,{bad},0.25],\"y\":1.0}}"),
                responses: 1,
                rebinds: false,
                label: "non-finite tell",
            }
        }
        // Structurally valid tells of the wrong dimension (including a
        // 2000-dim monster): they decode, so they are fire-and-forget —
        // no response — and the drain guard drops them on the floor.
        5 => {
            let d = *rng.choice(&[1usize, 2, 4, 8, 40, 2000]);
            let req = SurrogateRequest::TellObs {
                x: (0..d).map(|_| rng.f64()).collect(),
                y: rng.f64(),
                ys: Vec::new(),
            };
            Frame {
                line: encode_surrogate_request(&req),
                responses: 0,
                rebinds: false,
                label: "wrong-dimension tell",
            }
        }
        // sync-factor with hostile knobs: a from_n beyond the store is a
        // per-connection Error; negative / non-numeric knobs are decode
        // errors; max_rows 0 is clamped and served.
        6 => {
            let (body, label): (&str, &'static str) = *rng.choice(&[
                ("\"from_n\":999999999", "sync beyond store"),
                ("\"from_n\":-3", "negative from_n"),
                ("\"from_n\":0,\"max_rows\":0", "zero max_rows"),
                ("\"from_n\":0,\"quantise\":\"yes\"", "string quantise"),
                ("\"from_n\":\"zero\"", "string from_n"),
            ]);
            Frame {
                line: format!("{{\"type\":\"sync-factor\",{body}}}"),
                responses: 1,
                rebinds: false,
                label,
            }
        }
        // Lease/hyper frames with missing or mistyped required fields.
        7 => {
            let line = (*rng.choice(&[
                "{\"type\":\"ask-lease\"}",
                "{\"type\":\"ask-lease\",\"points\":[[0.5,1.0]]}",
                "{\"type\":\"retract-lease\"}",
                "{\"type\":\"retract-lease\",\"id\":\"seven\"}",
                "{\"type\":\"set-hyper\"}",
                "{\"type\":\"set-hyper\",\"hyper\":{\"lengthscale\":\"wide\"}}",
            ]))
            .to_string();
            Frame { line, responses: 1, rebinds: false, label: "malformed lease/hyper frame" }
        }
        // An unknown frame type entirely.
        8 => Frame {
            line: format!("{{\"type\":\"frobnicate\",\"n\":{}}}", rng.index(100)),
            responses: 1,
            rebinds: false,
            label: "unknown frame type",
        },
        // A random fingerprinted hello WITH a dim: legitimate up to the
        // fleet cap, a typed hello-err past it — either way a decodable
        // response and never a crash.
        _ => {
            let req = SurrogateRequest::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: Some(rng.next_u64()),
                dim: Some(1 + rng.index(6)),
            };
            Frame {
                line: encode_surrogate_request(&req),
                responses: 1,
                rebinds: true,
                label: "random-space hello",
            }
        }
    }
}

#[test]
fn malformed_frames_never_crash_the_daemon_or_touch_sibling_spaces() {
    let (server, _factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let server = server.with_fleet_options(FleetOptions::default()).unwrap();
    let (addr, handle) = server.spawn().unwrap();
    let addr_s = addr.to_string();

    // Seed the baseline space S the fuzz must not corrupt.
    let space = baseline_space();
    let mut rng = Rng::new(0xf022);
    let seeded: Vec<(Vec<f64>, f64)> = (0..6)
        .map(|_| {
            let x: Vec<f64> = (0..space.dim()).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin() - 0.5 * x[2];
            (x, y)
        })
        .collect();
    let good = RemoteSurrogate::connect_space(&addr_s, &space).unwrap();
    for (x, y) in &seeded {
        good.tell(x.clone(), *y);
    }
    drop(good.lock()); // daemon has absorbed all six rows

    let baseline_bits = {
        let mut c = Fuzz::connect(addr);
        c.hello(&space);
        factor_bits(&c.probe("baseline capture"))
    };

    // The storm: each iteration is a fresh connection (so one poisoned
    // handler can never be blamed on an earlier frame), sends one bad
    // frame — half the time after a legitimate hello into S, putting S
    // itself in the blast zone — collects exactly the responses it is
    // owed, then proves the connection still serves a well-formed sync.
    for i in 0..150 {
        let frame = make_frame(&mut rng);
        let mut c = Fuzz::connect(addr);
        let in_space = rng.bool(0.5);
        if in_space {
            c.hello(&space);
        }
        c.send(&frame.line);
        for r in 0..frame.responses {
            // Any decodable response is in-contract; which variant is
            // the frame's own business.
            let _ = c.expect_response(&format!("{} (iter {i}, response {r})", frame.label));
        }
        let delta = c.probe(&format!("{} (iter {i})", frame.label));
        // A hello-shaped frame may legitimately re-bind this connection
        // to another space, so only non-rebinding frames pin the row
        // count; the post-storm bit-identity check below covers the rest.
        if in_space && !frame.rebinds {
            assert_eq!(
                delta.total_n,
                seeded.len(),
                "{} (iter {i}) changed the baseline space's row count",
                frame.label
            );
        }
    }

    // S survived the storm bit-identically.
    let after_bits = {
        let mut c = Fuzz::connect(addr);
        c.hello(&space);
        factor_bits(&c.probe("post-fuzz capture"))
    };
    assert_eq!(after_bits, baseline_bits, "the fuzz storm corrupted the baseline factor");

    // And a well-formed client gets normal service afterwards.
    let good = RemoteSurrogate::connect_space(&addr_s, &space).unwrap();
    good.tell(vec![0.5, 0.5, 0.5], 1.25);
    assert_eq!(good.lock().len(), seeded.len() + 1, "the daemon stopped serving after the fuzz");
    drop(good);

    // Clean shutdown proves the daemon's accept loop is also intact.
    use tftune::server::proto::{encode_request, Request};
    let shutdown_space = threading_space(64, 1024, 64);
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "{}", encode_request(&Request::Shutdown, &shutdown_space)).unwrap();
    drop(s);
    let _ = handle.join();
}

// ---------------------------------------------------------------------------
// Event-plane storm (ISSUE 10): the same blast-radius contract, aimed at
// the `--events-addr` publisher. The event plane is read-only — the ONLY
// frame it accepts is `{"type":"subscribe"}` — so every hostile line owes
// exactly one typed `error` response (or, for oversized/unterminated
// frames, a silent close), strictly per-connection. The surrogate plane
// next door must never notice.
// ---------------------------------------------------------------------------

/// Send one hostile line to the events port and assert the contract: one
/// decodable `error` response, then EOF. Never a crash, never a hang.
fn expect_obs_error_then_close(events_addr: SocketAddr, line: &str, ctx: &str) {
    let mut s = TcpStream::connect(events_addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
    writeln!(s, "{line}").unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap_or_else(|e| panic!("no error line after {ctx}: {e}"));
    assert!(!resp.is_empty(), "publisher hung up without the error line after {ctx}");
    match decode_surrogate_response(resp.trim_end()) {
        Ok(SurrogateResponse::Error { .. }) => {}
        other => panic!("expected an error line after {ctx}, got {other:?} ({resp:?})"),
    }
    // One error, then close: the publisher never streams to a hostile peer.
    let mut rest = String::new();
    match r.read_line(&mut rest) {
        Ok(0) | Err(_) => {}
        Ok(_) => panic!("publisher kept talking after the error line ({ctx}): {rest:?}"),
    }
}

/// Subscribe properly, read the obs-hello, then prove the stream is live
/// by emitting marker events until one arrives. Emission retries because
/// the publisher attaches the subscriber's sink just *after* the hello —
/// a marker sent in that window can legitimately be missed.
fn probe_live_subscriber(events_addr: SocketAddr, bus: &tftune::obs::EventBus, ctx: &str) {
    use tftune::obs::{decode_event_record, Event};
    use tftune::server::proto::{decode_obs_hello, encode_obs_subscribe};

    let mut s = TcpStream::connect(events_addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
    writeln!(s, "{}", encode_obs_subscribe()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut hello = String::new();
    r.read_line(&mut hello).unwrap_or_else(|e| panic!("no obs-hello ({ctx}): {e}"));
    decode_obs_hello(hello.trim_end())
        .unwrap_or_else(|e| panic!("undecodable obs-hello ({ctx}): {e} ({hello:?})"));

    let marker = bus.source("fuzz-probe");
    for attempt in 0..100u64 {
        marker.emit(Event::TrialIssued { trial: attempt });
        bus.flush();
        let mut line = String::new();
        match r.read_line(&mut line) {
            Ok(0) => panic!("publisher hung up on a well-formed subscriber ({ctx})"),
            Ok(_) => {
                let rec = decode_event_record(line.trim_end())
                    .unwrap_or_else(|e| panic!("undecodable event line ({ctx}): {e} ({line:?})"));
                if rec.source == "fuzz-probe" {
                    return; // the stream is live end-to-end
                }
                // Someone else's event (e.g. the daemon's) — also proof of life.
                return;
            }
            Err(_) => continue, // timeout: marker raced the attach; re-emit
        }
    }
    panic!("well-formed subscriber never received an event ({ctx})");
}

#[test]
fn event_plane_storm_stays_per_connection_and_never_touches_the_surrogate_plane() {
    // One bus feeds both the TCP publisher and the daemon's own events.
    let bus = tftune::obs::EventBus::new();
    let mut publisher = tftune::obs::EventPublisher::bind("127.0.0.1:0", &bus).unwrap();
    let events_addr = publisher.addr();

    let (server, _factor) =
        TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
    let server = server
        .with_fleet_options(FleetOptions::default())
        .unwrap()
        .with_events(bus.source("daemon"));
    let (addr, handle) = server.spawn().unwrap();
    let addr_s = addr.to_string();

    // Seed the baseline space the storm must not corrupt.
    let space = baseline_space();
    let mut rng = Rng::new(0x0b5e48);
    let seeded: Vec<(Vec<f64>, f64)> = (0..6)
        .map(|_| {
            let x: Vec<f64> = (0..space.dim()).map(|_| rng.f64()).collect();
            let y = (2.0 * x[1]).cos() + 0.25 * x[0];
            (x, y)
        })
        .collect();
    let good = RemoteSurrogate::connect_space(&addr_s, &space).unwrap();
    for (x, y) in &seeded {
        good.tell(x.clone(), *y);
    }
    drop(good.lock());
    let baseline_bits = {
        let mut c = Fuzz::connect(addr);
        c.hello(&space);
        factor_bits(&c.probe("event-storm baseline capture"))
    };

    // The storm. Every iteration is a fresh connection to the EVENTS
    // port with one hostile frame; every 8th iteration a well-formed
    // subscriber proves the plane still serves honest peers.
    for i in 0..120 {
        match rng.index(6) {
            // Printable garbage that was never JSON.
            0 => {
                let n = 1 + rng.index(120);
                let junk: String = (0..n)
                    .map(|_| {
                        let c = b'!' + (rng.index(93) as u8);
                        if c == b'"' || c == b'\\' { '.' } else { c as char }
                    })
                    .collect();
                expect_obs_error_then_close(events_addr, &junk, &format!("garbage (iter {i})"));
            }
            // A strict prefix of the one legitimate frame: unbalanced
            // JSON, so the decoder must refuse it.
            1 => {
                let full = tftune::server::proto::encode_obs_subscribe();
                let cut = 1 + rng.index(full.len() - 1);
                expect_obs_error_then_close(
                    events_addr,
                    &full[..cut],
                    &format!("truncated subscribe (iter {i})"),
                );
            }
            // Well-formed JSON of the wrong type — including frames that
            // are perfectly legal on the surrogate plane next door. The
            // event plane is read-only; all of them are hostile here.
            2 => {
                let line = match rng.index(4) {
                    0 => format!("{{\"type\":\"frobnicate\",\"n\":{}}}", rng.index(100)),
                    1 => encode_surrogate_request(&SurrogateRequest::Hello {
                        version: PROTOCOL_VERSION,
                        fingerprint: Some(rng.next_u64()),
                        dim: Some(3),
                    }),
                    2 => encode_surrogate_request(&SurrogateRequest::TellObs {
                        x: (0..3).map(|_| rng.f64()).collect(),
                        y: rng.f64(),
                        ys: Vec::new(),
                    }),
                    _ => "{\"subscribe\":true}".to_string(),
                };
                expect_obs_error_then_close(
                    events_addr,
                    &line,
                    &format!("wrong-plane frame (iter {i})"),
                );
            }
            // An oversized, unterminated frame: past the cap the
            // publisher calls it hostile and closes without a response.
            3 => {
                let mut s = TcpStream::connect(events_addr).unwrap();
                s.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
                let blob = vec![b'a'; tftune::obs::OBS_MAX_SUBSCRIBE_LINE + 16];
                // The publisher may close mid-write; a broken pipe here
                // is the contract working, not a test failure.
                let _ = s.write_all(&blob);
                let _ = s.flush();
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                match r.read_line(&mut line) {
                    Ok(0) | Err(_) => {} // silent close, as specified
                    Ok(_) => panic!(
                        "publisher answered an oversized frame (iter {i}): {line:?}"
                    ),
                }
            }
            // Raw binary noise (newline-terminated so the read returns).
            4 => {
                let mut s = TcpStream::connect(events_addr).unwrap();
                s.set_read_timeout(Some(READ_TIMEOUT)).unwrap();
                let mut noise: Vec<u8> =
                    (0..64).map(|_| (rng.index(255) as u8).wrapping_add(1)).collect();
                noise.retain(|&b| b != b'\n');
                noise.push(b'\n');
                let _ = s.write_all(&noise);
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                // Binary noise is either undecodable JSON (one error
                // line) or — vanishingly — parses; never a crash/hang.
                match r.read_line(&mut line) {
                    Ok(0) | Err(_) => {}
                    Ok(_) => {
                        assert!(
                            decode_surrogate_response(line.trim_end()).is_ok()
                                || line.contains("obs-hello"),
                            "publisher sent a malformed reply to binary noise (iter {i}): {line:?}"
                        );
                    }
                }
            }
            // Connect and hang up without a word: must cost nothing.
            _ => {
                let s = TcpStream::connect(events_addr).unwrap();
                drop(s);
            }
        }
        if i % 8 == 7 {
            probe_live_subscriber(events_addr, &bus, &format!("iter {i}"));
        }
    }

    // The surrogate plane never noticed: baseline factor bit-identical,
    // and a well-formed client still gets normal service.
    let after_bits = {
        let mut c = Fuzz::connect(addr);
        c.hello(&space);
        factor_bits(&c.probe("event-storm post capture"))
    };
    assert_eq!(after_bits, baseline_bits, "the event-plane storm corrupted the baseline factor");
    let good = RemoteSurrogate::connect_space(&addr_s, &space).unwrap();
    good.tell(vec![0.25, 0.75, 0.5], -0.5);
    assert_eq!(
        good.lock().len(),
        seeded.len() + 1,
        "the daemon stopped serving after the event-plane storm"
    );
    drop(good);

    use tftune::server::proto::{encode_request, Request};
    let shutdown_space = threading_space(64, 1024, 64);
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "{}", encode_request(&Request::Shutdown, &shutdown_space)).unwrap();
    drop(s);
    let _ = handle.join();
    publisher.stop();
}
