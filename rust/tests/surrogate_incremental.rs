//! The incremental surrogate contract, pinned at integration level:
//!
//! 1. An [`IncrementalGp`] grown by rank-1 appends produces a posterior
//!    within 1e-9 of a from-scratch [`NativeGp::fit`] on the same data —
//!    across random histories, dimensions, hypers and both kernels.
//! 2. Constant-liar fantasy extend+retract is exact: the extended model
//!    matches a scratch fit on the concatenated data, and retracting
//!    restores the original posterior bitwise.
//! 3. The BO engine's incremental session proposes the *same serial
//!    trajectory* as the pre-refactor scratch-refit path
//!    ([`ExactRefitSurrogate`]) with default hypers.

use tftune::algorithms::{BayesOpt, Tuner};
use tftune::gp::{ExactRefitSurrogate, GpHyper, IncrementalGp, KernelKind, NativeGp};
use tftune::history::Measurement;
use tftune::space::threading_space;
use tftune::util::prop;
use tftune::util::Rng;

fn random_history(rng: &mut Rng, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> = x
        .iter()
        .map(|p| (7.0 * p[0]).sin() + 0.4 * p[d - 1] + 0.1 * p[0] * p[d - 1])
        .collect();
    (x, y)
}

fn random_hyper(rng: &mut Rng, kernel: KernelKind) -> GpHyper {
    GpHyper {
        lengthscale: rng.range_f64(0.08, 0.8),
        signal_var: rng.range_f64(0.5, 2.0),
        noise_var: rng.range_f64(1e-4, 1e-2),
        kernel,
        ..Default::default()
    }
}

fn build_incremental(x: &[Vec<f64>], y: &[f64], hyper: GpHyper) -> IncrementalGp {
    let mut gp = IncrementalGp::new(hyper);
    for (xi, &yi) in x.iter().zip(y) {
        assert!(gp.push(xi, yi), "rank-1 append failed");
    }
    gp
}

#[test]
fn prop_rank1_append_matches_scratch_fit_both_kernels() {
    for kernel in KernelKind::all() {
        prop::check(&format!("incremental vs oracle ({})", kernel.name()), 40, |rng| {
            let n = 1 + rng.index(40);
            let d = 1 + rng.index(6);
            let (x, y) = random_history(rng, n, d);
            let hyper = random_hyper(rng, kernel);
            let mut inc = build_incremental(&x, &y, hyper);
            let oracle = NativeGp::fit(&x, &y, hyper).expect("oracle fit failed");
            let cand: Vec<Vec<f64>> =
                (0..24).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
            let a = inc.predict(&cand);
            let b = oracle.predict(&cand);
            for j in 0..cand.len() {
                assert!(
                    (a.mean[j] - b.mean[j]).abs() <= 1e-9,
                    "mean diverged: {} vs {} (n={n} d={d})",
                    a.mean[j],
                    b.mean[j]
                );
                assert!(
                    (a.std[j] - b.std[j]).abs() <= 1e-9,
                    "std diverged: {} vs {} (n={n} d={d})",
                    a.std[j],
                    b.std[j]
                );
            }
        });
    }
}

#[test]
fn prop_fantasy_extend_matches_scratch_fit_on_extended_data() {
    for kernel in KernelKind::all() {
        prop::check(&format!("fantasy extend vs oracle ({})", kernel.name()), 25, |rng| {
            let n = 2 + rng.index(20);
            let d = 1 + rng.index(4);
            let k = 1 + rng.index(6);
            let (x, y) = random_history(rng, n, d);
            let hyper = random_hyper(rng, kernel);
            let mut inc = build_incremental(&x, &y, hyper);

            // Extend with k fantasies at the constant-liar value 0.
            let mut xf = x.clone();
            let mut yf = y.clone();
            for _ in 0..k {
                let f: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                assert!(inc.extend_fantasy(&f, 0.0));
                xf.push(f);
                yf.push(0.0);
            }
            let oracle = NativeGp::fit(&xf, &yf, hyper).expect("extended oracle fit failed");
            let cand: Vec<Vec<f64>> =
                (0..12).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
            let a = inc.predict(&cand);
            let b = oracle.predict(&cand);
            for j in 0..cand.len() {
                assert!((a.mean[j] - b.mean[j]).abs() <= 1e-9);
                assert!((a.std[j] - b.std[j]).abs() <= 1e-9);
            }
        });
    }
}

#[test]
fn prop_retract_restores_posterior_bitwise() {
    prop::check("fantasy retract exact", 30, |rng| {
        let n = 1 + rng.index(25);
        let d = 1 + rng.index(5);
        let kernel = *rng.choice(&KernelKind::all());
        let (x, y) = random_history(rng, n, d);
        let hyper = random_hyper(rng, kernel);
        let mut inc = build_incremental(&x, &y, hyper);
        let cand: Vec<Vec<f64>> =
            (0..10).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let before = inc.predict(&cand);

        let k = 1 + rng.index(5);
        for _ in 0..k {
            let f: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            assert!(inc.extend_fantasy(&f, rng.range_f64(-1.0, 1.0)));
        }
        inc.retract_fantasies();
        assert_eq!(inc.total(), n);
        let after = inc.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(
                before.mean[j].to_bits(),
                after.mean[j].to_bits(),
                "retract is not exact (mean, cand {j})"
            );
            assert_eq!(before.std[j].to_bits(), after.std[j].to_bits());
        }
    });
}

#[test]
fn serial_trajectory_pinned_to_scratch_refit_reference() {
    // The refactor must not change what BO proposes: with default hypers,
    // the persistent-incremental engine and the pre-refactor scratch-refit
    // path walk identical serial trajectories (same seeds, same tells),
    // because the incremental factor and blocked scorer perform the exact
    // oracle's floating-point operations in the exact oracle's order.
    let space = threading_space(64, 1024, 64);
    let target = space.to_unit(&vec![2, 36, 704, 120, 44]);
    let objective = |cfg: &Vec<i64>| {
        let u = space.to_unit(cfg);
        8.0 - 8.0 * u.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
    };
    for seed in [1u64, 23, 456] {
        let mut inc = BayesOpt::new(space.clone(), seed);
        let mut scratch = BayesOpt::with_surrogate(space.clone(), seed, ExactRefitSurrogate);
        for step in 0..30 {
            let a = inc.ask(1).pop().unwrap();
            let b = scratch.ask(1).pop().unwrap();
            assert_eq!(
                a.config, b.config,
                "seed {seed}: trajectories diverged at step {step}"
            );
            let v = objective(&a.config);
            inc.tell(a.id, &Measurement::new(v));
            scratch.tell(b.id, &Measurement::new(v));
        }
    }
}

#[test]
fn batched_trajectory_pinned_to_scratch_refit_reference() {
    // Same pin with in-flight fantasies: batched asks must also agree,
    // since fantasy extension reproduces the scratch path's conditioning.
    let space = threading_space(64, 1024, 64);
    let mut inc = BayesOpt::new(space.clone(), 99);
    let mut scratch = BayesOpt::with_surrogate(space.clone(), 99, ExactRefitSurrogate);
    let mut pending_a = Vec::new();
    let mut pending_b = Vec::new();
    for round in 0..8 {
        let batch_a = inc.ask(3);
        let batch_b = scratch.ask(3);
        assert_eq!(batch_a.len(), batch_b.len(), "round {round}");
        for (a, b) in batch_a.iter().zip(&batch_b) {
            assert_eq!(a.config, b.config, "round {round}: batch diverged");
        }
        pending_a.extend(batch_a);
        pending_b.extend(batch_b);
        // Settle the oldest half out of order, identically on both sides.
        let settle = pending_a.len() / 2 + 1;
        for _ in 0..settle {
            let ta = pending_a.remove(0);
            let tb = pending_b.remove(0);
            let v = (ta.config[1] as f64).sin() + ta.config[0] as f64;
            inc.tell(ta.id, &Measurement::new(v));
            scratch.tell(tb.id, &Measurement::new(v));
        }
    }
}

#[test]
fn incremental_window_overflow_matches_reference() {
    // Past the conditioning window the set reshapes every tell (best
    // quartile + recent remainder) and the incremental model rebuilds;
    // proposals must still match the scratch reference exactly.
    let space = threading_space(64, 1024, 64);
    let window = GpHyper::default().max_history;
    let mut inc = BayesOpt::new(space.clone(), 7);
    let mut scratch = BayesOpt::with_surrogate(space.clone(), 7, ExactRefitSurrogate);
    let mut rng = Rng::new(5);
    for i in 0..window + 10 {
        let c = space.random(&mut rng);
        let v = (i as f64 * 0.37).sin() * 5.0;
        inc.warm_start(&c, v);
        scratch.warm_start(&c, v);
    }
    for step in 0..6 {
        let a = inc.ask(1).pop().unwrap();
        let b = scratch.ask(1).pop().unwrap();
        assert_eq!(a.config, b.config, "diverged at step {step} past the window");
        let v = (step as f64).cos();
        inc.tell(a.id, &Measurement::new(v));
        scratch.tell(b.id, &Measurement::new(v));
    }
}
