//! Ask/tell contract tests: every engine issues on-grid, id-unique trials
//! in batches, survives shuffled/out-of-order tells interleaved with
//! further asks, and — driven strictly serially — reproduces the exact
//! best-so-far trajectory of the serial `tune()` loop. Plus the
//! `TuningSession` stopping rules (plateau, parallel budget).

use tftune::algorithms::{Algorithm, Tuner};
use tftune::evaluator::{sim_pool, tune, Evaluator, Objective, SimEvaluator};
use tftune::history::Measurement;
use tftune::session::{Budget, StopReason, TuningSession};
use tftune::sim::ModelId;
use tftune::space::{threading_space, Config};
use tftune::util::prop;

/// Deterministic smooth objective over the threading space.
fn objective(space: &tftune::space::SearchSpace, c: &Config) -> f64 {
    let target = vec![2, 28, 512, 100, 28];
    let t = space.to_unit(&target);
    let u = space.to_unit(c);
    10.0 - 10.0 * u.iter().zip(&t).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
}

/// Property: batched asks return on-grid configurations with ids that are
/// unique across the engine's lifetime, and shuffled tells — with a trial
/// occasionally held back across rounds — never wedge or panic an engine.
#[test]
fn prop_every_engine_batches_and_survives_shuffled_tells() {
    let space = threading_space(64, 1024, 64);
    for alg in Algorithm::all() {
        prop::check(&format!("ask/tell contract [{}]", alg.name()), 8, |rng| {
            let mut engine = alg.build(&space, rng.next_u64());
            let mut seen_ids = std::collections::BTreeSet::new();
            let mut held: Vec<tftune::Trial> = Vec::new();
            for _round in 0..10 {
                let n = 1 + rng.index(5);
                let mut trials = engine.ask(n);
                assert!(trials.len() <= n, "{}: ask({n}) returned more", alg.name());
                for t in &trials {
                    assert!(
                        space.contains(&t.config),
                        "{}: off-grid {:?}",
                        alg.name(),
                        t.config
                    );
                    assert!(seen_ids.insert(t.id), "{}: reused id {}", alg.name(), t.id);
                }
                // Release anything held from the previous round, then
                // occasionally hold one fresh trial back to the next round
                // to force interleaved, out-of-order completion.
                trials.extend(held.drain(..));
                if !trials.is_empty() && rng.bool(0.3) {
                    held.push(trials.remove(rng.index(trials.len())));
                }
                rng.shuffle(&mut trials);
                for t in trials {
                    let v = objective(&space, &t.config);
                    engine.tell(t.id, &Measurement::new(v));
                }
            }
            // With everything settled the engine must still make progress.
            for t in held.drain(..) {
                engine.tell(t.id, &Measurement::new(0.0));
            }
            assert!(
                !engine.ask(1).is_empty(),
                "{}: engine wedged after full drain",
                alg.name()
            );
        });
    }
}

/// Serial ask(1)/tell equals the `tune()` shim equals a 1-evaluator
/// session: the pre-refactor best-so-far trajectory is preserved.
#[test]
fn serial_trajectory_matches_across_drivers() {
    let model = ModelId::Resnet50Fp32;
    let space = model.space();
    for alg in Algorithm::all_paper() {
        let seed = 17;
        // hand-rolled serial ask/tell loop
        let mut engine = alg.build(&space, seed);
        let mut eval = SimEvaluator::new(model, seed);
        let mut manual = Vec::new();
        for _ in 0..30 {
            let t = engine.ask(1).pop().unwrap();
            let m = eval.measure(&t.config).unwrap();
            engine.tell(t.id, &m);
            manual.push(m.value);
        }
        // tune() shim
        let mut engine = alg.build(&space, seed);
        let mut eval = SimEvaluator::new(model, seed);
        let shim = tune(engine.as_mut(), &mut eval, 30).unwrap();
        // 1-evaluator session
        let mut session = TuningSession::new(
            alg.build(&space, seed),
            sim_pool(
                model,
                seed,
                tftune::sim::noise::DEFAULT_SIGMA,
                Objective::Throughput,
                1,
            ),
            Budget::evaluations(30),
        );
        let sess = session.run().unwrap();

        assert_eq!(manual, shim.values(), "{}: shim diverged", alg.name());
        assert_eq!(shim.values(), sess.values(), "{}: session diverged", alg.name());
        assert_eq!(shim.best_curve(), sess.best_curve());
    }
}

/// A parallel session completes the budget with on-grid configs and
/// engine-unique trial ids, and BO's batch stays on the grid end to end —
/// the `tftune tune --model resnet50-fp32 --alg bo --parallel 4`
/// acceptance scenario, driven through the library.
#[test]
fn parallel_bo_session_all_trials_on_grid() {
    let model = ModelId::Resnet50Fp32;
    let space = model.space();
    let mut cfg = tftune::TuneConfig::default();
    cfg.model = model;
    cfg.algorithm = Algorithm::Bo;
    cfg.iterations = 20;
    cfg.parallel = 4;
    let h = cfg.run().unwrap();
    assert_eq!(h.len(), 20);
    let mut ids: Vec<u64> = h.iter().map(|e| e.trial_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 20);
    for e in h.iter() {
        assert!(space.contains(&e.config), "off-grid {:?}", e.config);
        assert!(e.value > 0.0);
        assert!(e.cost_s >= 0.0);
    }
}

/// The plateau rule ends a session that stops improving.
#[test]
fn session_plateau_stop() {
    struct Flat;
    impl Evaluator for Flat {
        fn evaluate(&mut self, _c: &Config) -> anyhow::Result<f64> {
            Ok(7.0)
        }
        fn describe(&self) -> String {
            "flat".into()
        }
    }
    let model = ModelId::NcfFp32;
    let mut session = TuningSession::new(
        Algorithm::Random.build(&model.space(), 8),
        vec![Box::new(Flat)],
        Budget::evaluations(10_000).with_plateau(10, 0.005),
    );
    let h = session.run().unwrap();
    assert_eq!(session.stop_reason(), Some(StopReason::Plateau));
    assert_eq!(h.len(), 11, "first sample + plateau window");
}

/// Out-of-order tells with n=1 semantics: telling a batch back in reverse
/// still leaves every engine able to finish a full run, and the recorded
/// best is the true max of what was measured.
#[test]
fn reversed_batch_tells_keep_best_consistent() {
    let space = threading_space(64, 1024, 64);
    for alg in Algorithm::all() {
        let mut engine = alg.build(&space, 99);
        let mut measured: Vec<f64> = Vec::new();
        for _ in 0..12 {
            let mut trials = engine.ask(3);
            trials.reverse();
            for t in trials {
                let v = objective(&space, &t.config);
                measured.push(v);
                engine.tell(t.id, &Measurement::new(v));
            }
        }
        assert!(!measured.is_empty(), "{} never issued trials", alg.name());
        let best = measured.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(best.is_finite(), "{}", alg.name());
    }
}
