//! The scaling tier: a sharded surrogate whose per-tell cost is bounded
//! by a capacity knob, no matter how long the campaign runs.
//!
//! The exact [`IncrementalGp`] pays O(n²) per rank-1 append and O(n²)
//! factor storage — fine at the paper's n≈100–512 trial budgets, fatal at
//! the n=10⁴–10⁵ histories a production fleet accumulates. [`ShardedGp`]
//! breaks that wall by partitioning the observation history into
//! **locally exact shards** over the unit hypercube:
//!
//! - **Storage**: rows live in a KD-tree of leaf shards. Each shard *is*
//!   an [`IncrementalGp`] — the packed Cholesky, blocked kernels,
//!   partitioned score threads and f32 ranking tier are reused verbatim,
//!   not re-implemented. A shard that grows past `shard_cap` (default
//!   [`DEFAULT_SHARD_CAP`]) splits on its widest dimension at the upper
//!   median, and both children are rebuilt as fresh exact factors. A tell
//!   therefore costs O(cap²) amortised **regardless of total n**, and the
//!   factor footprint is Σ O(capᵢ²) ≈ O(n·cap) instead of O(n²).
//! - **Routing**: an ask routes each candidate down the same KD-tree to
//!   its owning shard, plus the `blend_k − 1` nearest other shards by
//!   centroid distance.
//! - **Blending**: the selected shards' posteriors are combined
//!   generalised-product-of-experts style with uniform weights
//!   `w = 1/M` over the `M = blend_k.clamp(1, shards)` experts:
//!
//!   ```text
//!   1/σ²  =  Σᵢ w / σᵢ²           μ  =  σ² · Σᵢ w · μᵢ / σᵢ²
//!   ```
//!
//!   Variance-weighting means a shard that is far from the candidate
//!   (large σᵢ) contributes little — the blend degrades gracefully to
//!   the owning shard's local posterior at the partition interior and
//!   smooths the seams between shards.
//!
//! **The 1-shard ≡ exact argument.** While only one shard exists
//! (n ≤ `shard_cap`, or `shard_cap ≥ n` by configuration), every scoring
//! and mutation call is *delegated verbatim* to the single inner
//! [`IncrementalGp`] — same rows in the same order, same factor, same
//! scoring engine, and crucially the posterior is **not** round-tripped
//! through the blend formula (`1/(1/x)` is not the identity in floating
//! point). A single-shard `ShardedGp` is therefore bit-identical to the
//! exact engine, which stays the oracle for parity tests
//! (`rust/tests/sharded_surrogate.rs`). The same short-circuit applies
//! per-candidate when the effective blend size is 1 (`blend_k = 1` with
//! many shards): the owner's raw posterior is written through unblended.
//!
//! **Fantasies** (constant-liar extends) are routed like committed rows
//! but never trigger splits and never move rows between shards; they are
//! retracted shard-locally, so the extend → score → retract cycle of an
//! ask leaves every factor bitwise unchanged, exactly like the flat
//! engine. Splits only happen inside [`ShardedGp::push`], which asserts
//! no fantasies are in place (the [`super::SharedSurrogate`] guard
//! retracts before every drain).
//!
//! **Numerical contract.** Unlike the exact engine there is no global
//! bitwise oracle once several shards exist — each shard conditions only
//! on its local rows, so the multi-shard posterior is an *approximation*
//! whose quality is pinned by tolerance and regret tests, not bit
//! parity. The multi-shard scoring pass also performs O(shards · K)
//! transient slice bookkeeping per call (unlike the flat engine's
//! zero-alloc contract); the per-candidate numeric buffers are still
//! reused across calls via an internal scratch.

use super::incremental::{IncrementalGp, ScoreTier, ScoreWorkspace};
use super::kernel::GpHyper;
use super::native::Posterior;
use crate::util::linalg::BlockSpec;

/// Default leaf capacity: a shard splits when it exceeds this many
/// committed rows. 512 matches the exact engine's comfort zone (the
/// paper's own trial budgets) — big enough that each local model is a
/// real GP, small enough that a tell's O(cap²) append stays ~sub-ms.
pub const DEFAULT_SHARD_CAP: usize = 512;

/// Default blend neighbourhood: each candidate is scored by its owning
/// shard plus this-many-minus-one nearest neighbours.
pub const DEFAULT_BLEND_K: usize = 2;

/// KD-tree node over the unit hypercube. Leaves own a shard; splits
/// route on one dimension at a threshold chosen so both sides are
/// non-empty.
#[derive(Debug, Clone, Copy)]
enum Node {
    Leaf { shard: usize },
    Split { dim: usize, thresh: f64, lo: usize, hi: usize },
}

/// One locally-exact expert: an [`IncrementalGp`] over a contiguous
/// region of the space, plus the bookkeeping the router needs.
#[derive(Debug)]
struct Shard {
    gp: IncrementalGp,
    /// Global row ids owned by this shard, ascending (committed only).
    rows: Vec<usize>,
    /// Σ of owned committed rows, per dimension — centroid = sum/len.
    centroid_sum: Vec<f64>,
}

impl Shard {
    fn new(hyper: GpHyper, d: usize, threads: usize, tier: ScoreTier, blocks: BlockSpec) -> Shard {
        let mut gp = IncrementalGp::new(hyper);
        gp.set_score_threads(threads);
        gp.set_score_tier(tier);
        gp.set_block_spec(blocks);
        Shard { gp, rows: Vec::new(), centroid_sum: vec![0.0; d] }
    }
}

/// Reused buffers for the multi-shard blend pass. All owned by the
/// model, so repeated asks stop growing the heap once shapes are seen
/// (modulo the documented O(shards · K) slice bookkeeping).
#[derive(Debug, Default)]
struct BlendScratch {
    /// Per-shard candidate index lists for the current pass.
    lists: Vec<Vec<usize>>,
    /// Flat candidate sub-panel for the shard being scored.
    panel: Vec<f64>,
    /// Per-shard gathered targets (K × shard-rows, objective-major).
    tg: Vec<f64>,
    /// Workspace the shard's own scoring engine runs in.
    ws: ScoreWorkspace,
    /// Blended precision accumulator, one per candidate.
    prec: Vec<f64>,
    /// Blended weighted-mean accumulator (K × candidates).
    acc: Vec<f64>,
    /// Shard centroids (shards × d), rebuilt each pass.
    cent: Vec<f64>,
    /// (squared centroid distance, shard id) selection scratch.
    dist: Vec<(f64, usize)>,
    /// Selected shard ids for the current candidate.
    sel: Vec<usize>,
}

/// A GP surrogate sharded over the unit hypercube: locally-exact
/// [`IncrementalGp`] leaves under a KD router, blended
/// product-of-experts style at ask time. See the module docs for the
/// cost model and the 1-shard ≡ exact bit-parity argument.
#[derive(Debug)]
pub struct ShardedGp {
    hyper: GpHyper,
    shard_cap: usize,
    blend_k: usize,
    /// Feature dimension; fixed by the first appended row.
    d: usize,
    /// Committed (real) observations across all shards.
    committed: usize,
    /// Row-major (committed × d) inputs, in global tell order.
    x: Vec<f64>,
    /// Targets, one per row (fantasies carry their lie value).
    y: Vec<f64>,
    /// KD-tree arena; root at index 0.
    nodes: Vec<Node>,
    shards: Vec<Shard>,
    /// Owning shard of each fantasy row, in extension order.
    fantasy_shard: Vec<usize>,
    threads: usize,
    tier: ScoreTier,
    blocks: BlockSpec,
    scratch: BlendScratch,
    predict_flat: Vec<f64>,
    predict_ws: ScoreWorkspace,
}

impl ShardedGp {
    /// Empty sharded model. `shard_cap` and `blend_k` are clamped to at
    /// least 1; hyperparameters are shared by every shard (same
    /// contract as the flat engine — `max_history` is a reservation
    /// hint only, conditioning windows are the caller's business).
    pub fn new(hyper: GpHyper, shard_cap: usize, blend_k: usize) -> ShardedGp {
        let shard_cap = shard_cap.max(1);
        let blend_k = blend_k.max(1);
        ShardedGp {
            hyper,
            shard_cap,
            blend_k,
            d: 0,
            committed: 0,
            x: Vec::new(),
            y: Vec::new(),
            nodes: vec![Node::Leaf { shard: 0 }],
            shards: vec![Shard::new(hyper, 0, 1, ScoreTier::F64, BlockSpec::default())],
            fantasy_shard: Vec::new(),
            threads: 1,
            tier: ScoreTier::F64,
            blocks: BlockSpec::default(),
            scratch: BlendScratch::default(),
            predict_flat: Vec::new(),
            predict_ws: ScoreWorkspace::default(),
        }
    }

    pub fn hyper(&self) -> GpHyper {
        self.hyper
    }

    /// Leaf capacity: a shard splits when it exceeds this many rows.
    pub fn shard_cap(&self) -> usize {
        self.shard_cap
    }

    /// Blend neighbourhood size (effective size is clamped to the
    /// current shard count at ask time).
    pub fn blend_k(&self) -> usize {
        self.blend_k
    }

    /// Number of leaf shards (1 until the first split).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Largest committed row count over all shards. Bounded by
    /// `shard_cap` except for degenerate zero-spread regions (identical
    /// rows cannot be split and keep accumulating in one leaf).
    pub fn max_shard_rows(&self) -> usize {
        self.shards.iter().map(|s| s.rows.len()).max().unwrap_or(0)
    }

    /// Total packed-factor entries across all shards — the storage that
    /// replaces the flat engine's O(n²) triangle. Grows ~O(n · cap).
    pub fn factor_entries(&self) -> usize {
        self.shards.iter().map(|s| s.gp.factor_len()).sum()
    }

    /// Committed (real) observations.
    pub fn len(&self) -> usize {
        self.committed
    }

    pub fn is_empty(&self) -> bool {
        self.committed == 0
    }

    /// Committed + fantasy rows.
    pub fn total(&self) -> usize {
        self.committed + self.fantasy_shard.len()
    }

    /// Replace hyperparameters and reset (same semantics as the flat
    /// engine: a kernel change invalidates every factor).
    pub fn set_hyper(&mut self, hyper: GpHyper) {
        self.hyper = hyper;
        self.clear();
    }

    /// Drop all rows and shards, keeping knobs (cap, blend, scoring
    /// tier/threads/blocking).
    pub fn clear(&mut self) {
        self.d = 0;
        self.committed = 0;
        self.x.clear();
        self.y.clear();
        self.fantasy_shard.clear();
        self.nodes.clear();
        self.nodes.push(Node::Leaf { shard: 0 });
        self.shards.clear();
        self.shards.push(Shard::new(self.hyper, 0, self.threads, self.tier, self.blocks));
    }

    pub fn score_threads(&self) -> usize {
        self.threads
    }

    /// Scoring worker threads, forwarded to every shard (present and
    /// future). Bit-identical per shard for any count, same as the flat
    /// engine.
    pub fn set_score_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        for sh in &mut self.shards {
            sh.gp.set_score_threads(threads);
        }
    }

    pub fn score_tier(&self) -> ScoreTier {
        self.tier
    }

    /// Scoring arithmetic tier, forwarded to every shard.
    pub fn set_score_tier(&mut self, tier: ScoreTier) {
        self.tier = tier;
        for sh in &mut self.shards {
            sh.gp.set_score_tier(tier);
        }
    }

    pub fn block_spec(&self) -> BlockSpec {
        self.blocks
    }

    /// Cache-blocking geometry, forwarded to every shard.
    pub fn set_block_spec(&mut self, blocks: BlockSpec) {
        self.blocks = blocks;
        for sh in &mut self.shards {
            sh.gp.set_block_spec(blocks);
        }
    }

    /// Append a committed observation: route to the owning leaf, rank-1
    /// append on that shard's exact factor (O(shard rows²), **not**
    /// O(n²)), split the leaf if it overflowed `shard_cap`. Returns
    /// false (model unchanged) if the shard's factor rejects the row as
    /// non-positive-definite.
    pub fn push(&mut self, xr: &[f64], yv: f64) -> bool {
        debug_assert!(
            self.fantasy_shard.is_empty(),
            "push with fantasies in place; retract first"
        );
        if self.total() == 0 {
            assert!(!xr.is_empty(), "empty feature vector");
            self.d = xr.len();
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        let (node_idx, sid) = route(&self.nodes, xr);
        if self.shards[sid].centroid_sum.len() != self.d {
            self.shards[sid].centroid_sum.resize(self.d, 0.0);
        }
        if !self.shards[sid].gp.push(xr, yv) {
            return false;
        }
        let g = self.committed;
        self.x.extend_from_slice(xr);
        self.y.push(yv);
        self.committed += 1;
        self.shards[sid].rows.push(g);
        for k in 0..self.d {
            self.shards[sid].centroid_sum[k] += xr[k];
        }
        if self.shards[sid].rows.len() > self.shard_cap {
            self.try_split(node_idx, sid);
        }
        true
    }

    /// Condition on an in-flight trial (constant liar), routed like a
    /// committed row but never splitting. Dropped again by
    /// [`ShardedGp::retract_fantasies`].
    pub fn extend_fantasy(&mut self, xr: &[f64], lie: f64) -> bool {
        if self.total() == 0 {
            assert!(!xr.is_empty(), "empty feature vector");
            self.d = xr.len();
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        let (_, sid) = route(&self.nodes, xr);
        if !self.shards[sid].gp.extend_fantasy(xr, lie) {
            return false;
        }
        self.y.push(lie);
        self.fantasy_shard.push(sid);
        true
    }

    /// Drop all fantasy rows shard-locally — each shard truncates its
    /// factor back, which is exact (bitwise) state restoration.
    pub fn retract_fantasies(&mut self) {
        if self.fantasy_shard.is_empty() {
            return;
        }
        for sh in &mut self.shards {
            sh.gp.retract_fantasies();
        }
        self.y.truncate(self.committed);
        self.fantasy_shard.clear();
    }

    /// Replace the targets of every current row (committed +
    /// fantasies), in global tell order. In single-shard mode this is
    /// forwarded verbatim (preserving the installed-target bit-parity
    /// path); in multi-shard mode targets are gathered per shard at
    /// scoring time.
    pub fn set_targets(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.total(), "target length mismatch");
        if self.shards.len() == 1 {
            self.shards[0].gp.set_targets(y);
        }
        if self.y == y {
            return;
        }
        self.y.clear();
        self.y.extend_from_slice(y);
    }

    /// Score `c` candidates (row-major c×d): single-objective posterior
    /// + SMSego gain `(μ + acq_alpha·σ) − y_best`. One shard →
    /// delegated verbatim (bitwise oracle); several → KD-routed gPoE
    /// blend (module docs).
    pub fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        assert!(self.total() > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        if self.shards.len() == 1 {
            self.shards[0].gp.score_into(cand, c, acq_alpha, y_best, ws);
            return;
        }
        ws.mean.clear();
        ws.mean.resize(c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);
        let (d, committed, bk) = (self.d, self.committed, self.blend_k);
        let ShardedGp { shards, nodes, y, fantasy_shard, scratch, .. } = self;
        let targets: [&[f64]; 1] = [y.as_slice()];
        blend_pass(
            shards,
            nodes,
            d,
            committed,
            bk,
            cand,
            c,
            &targets,
            fantasy_shard,
            scratch,
            &mut ws.mean,
            &mut ws.std,
        );
        for ((g, mu), s) in ws.gain.iter_mut().zip(ws.mean.iter()).zip(ws.std.iter()) {
            *g = (*mu + acq_alpha * *s) - y_best;
        }
    }

    /// Score `c` candidates against K objectives: each selected shard
    /// runs its own one-panel multi-objective pass over gathered local
    /// targets, and the per-objective means are blended with the shared
    /// per-candidate variance weights (the blend weights depend only on
    /// σ, which is objective-independent — exactly like the flat
    /// engine's shared-std contract). `ws.gain` is resized and zeroed
    /// for the caller's acquisition; `ws.mean` mirrors `targets[0]`.
    pub fn score_multi_into(
        &mut self,
        cand: &[f64],
        c: usize,
        targets: &[&[f64]],
        ws: &mut ScoreWorkspace,
    ) {
        assert!(self.total() > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        let k_obj = targets.len();
        assert!(k_obj > 0, "need at least one objective");
        for t in targets {
            assert_eq!(t.len(), self.total(), "target length mismatch");
        }
        if self.shards.len() == 1 {
            self.shards[0].gp.score_multi_into(cand, c, targets, ws);
            return;
        }
        ws.n_obj = k_obj;
        ws.mean_obj.clear();
        ws.mean_obj.resize(k_obj * c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);
        let (d, committed, bk) = (self.d, self.committed, self.blend_k);
        let ShardedGp { shards, nodes, fantasy_shard, scratch, .. } = self;
        blend_pass(
            shards,
            nodes,
            d,
            committed,
            bk,
            cand,
            c,
            targets,
            fantasy_shard,
            scratch,
            &mut ws.mean_obj,
            &mut ws.std,
        );
        ws.mean.clear();
        ws.mean.extend_from_slice(&ws.mean_obj[..c]);
    }

    /// Posterior at candidate points — the convenience/test entry,
    /// routed through the same scoring path as the hot loop.
    pub fn predict(&mut self, cand: &[Vec<f64>]) -> Posterior {
        if self.shards.len() == 1 {
            return self.shards[0].gp.predict(cand);
        }
        let mut flat = std::mem::take(&mut self.predict_flat);
        let mut ws = std::mem::take(&mut self.predict_ws);
        flat.clear();
        flat.reserve(cand.len() * self.d);
        for row in cand {
            assert_eq!(row.len(), self.d, "candidate dim mismatch");
            flat.extend_from_slice(row);
        }
        self.score_into(&flat, cand.len(), 0.0, 0.0, &mut ws);
        let post = Posterior { mean: ws.mean.clone(), std: ws.std.clone() };
        self.predict_flat = flat;
        self.predict_ws = ws;
        post
    }

    /// Split leaf `node_idx`/`sid` on its widest dimension at the upper
    /// median. No-op when every owned row is identical on every
    /// dimension (zero spread — nothing separates them) or when a child
    /// rebuild hits a non-PD factor (the oversized leaf is kept and the
    /// split retried on the next overflow).
    fn try_split(&mut self, node_idx: usize, sid: usize) {
        let d = self.d;
        let rows = &self.shards[sid].rows;
        let mut best_dim = 0usize;
        let mut best_spread = 0.0f64;
        let mut best_min = 0.0f64;
        for dim in 0..d {
            let mut mn = f64::INFINITY;
            let mut mx = f64::NEG_INFINITY;
            for &g in rows {
                let v = self.x[g * d + dim];
                mn = mn.min(v);
                mx = mx.max(v);
            }
            if mx - mn > best_spread {
                best_spread = mx - mn;
                best_dim = dim;
                best_min = mn;
            }
        }
        if !(best_spread > 0.0) {
            return;
        }
        let mut vals: Vec<f64> = rows.iter().map(|&g| self.x[g * d + best_dim]).collect();
        vals.sort_by(f64::total_cmp);
        // Upper median, bumped above the minimum so both sides of the
        // strict `< thresh` test are non-empty whenever spread > 0.
        let mut thresh = vals[vals.len() / 2];
        if thresh <= best_min {
            thresh = vals
                .iter()
                .copied()
                .find(|&v| v > best_min)
                .expect("spread > 0 guarantees a value above the minimum");
        }
        let (lo_rows, hi_rows): (Vec<usize>, Vec<usize>) =
            rows.iter().partition(|&&g| self.x[g * d + best_dim] < thresh);
        debug_assert!(!lo_rows.is_empty() && !hi_rows.is_empty());
        let lo_sh = build_shard(
            &self.x, &self.y, d, &lo_rows, self.hyper, self.threads, self.tier, self.blocks,
        );
        let hi_sh = build_shard(
            &self.x, &self.y, d, &hi_rows, self.hyper, self.threads, self.tier, self.blocks,
        );
        let (Some(lo_sh), Some(hi_sh)) = (lo_sh, hi_sh) else {
            return;
        };
        self.shards[sid] = lo_sh;
        let hi_sid = self.shards.len();
        self.shards.push(hi_sh);
        let lo_node = self.nodes.len();
        self.nodes.push(Node::Leaf { shard: sid });
        let hi_node = self.nodes.len();
        self.nodes.push(Node::Leaf { shard: hi_sid });
        self.nodes[node_idx] = Node::Split { dim: best_dim, thresh, lo: lo_node, hi: hi_node };
    }
}

/// Descend the KD-tree to the leaf owning `xr`; returns (node index,
/// shard index).
fn route(nodes: &[Node], xr: &[f64]) -> (usize, usize) {
    let mut idx = 0;
    loop {
        match nodes[idx] {
            Node::Leaf { shard } => return (idx, shard),
            Node::Split { dim, thresh, lo, hi } => {
                idx = if xr[dim] < thresh { lo } else { hi };
            }
        }
    }
}

/// Rebuild one child shard by re-pushing its rows (ascending global id,
/// current targets). None if any append hits a non-PD factor.
#[allow(clippy::too_many_arguments)]
fn build_shard(
    x: &[f64],
    y: &[f64],
    d: usize,
    rows: &[usize],
    hyper: GpHyper,
    threads: usize,
    tier: ScoreTier,
    blocks: BlockSpec,
) -> Option<Shard> {
    let mut sh = Shard::new(hyper, d, threads, tier, blocks);
    sh.rows.reserve(rows.len());
    for &g in rows {
        if !sh.gp.push(&x[g * d..(g + 1) * d], y[g]) {
            return None;
        }
        sh.rows.push(g);
        for k in 0..d {
            sh.centroid_sum[k] += x[g * d + k];
        }
    }
    Some(sh)
}

/// The multi-shard scoring core: route every candidate to its blend set
/// (owner + nearest-centroid neighbours), score each shard's sub-panel
/// through that shard's own engine over gathered local targets, and
/// combine posteriors gPoE-style. `out_mean_obj` (K×c) and `out_std`
/// (c) must be pre-sized by the caller. When the effective blend size
/// is 1 the raw shard posterior is written through verbatim — no
/// `1/(1/x)` float round-trip.
#[allow(clippy::too_many_arguments)]
fn blend_pass(
    shards: &mut [Shard],
    nodes: &[Node],
    d: usize,
    committed: usize,
    blend_k: usize,
    cand: &[f64],
    c: usize,
    targets: &[&[f64]],
    fantasy_shard: &[usize],
    scratch: &mut BlendScratch,
    out_mean_obj: &mut [f64],
    out_std: &mut [f64],
) {
    let n_sh = shards.len();
    debug_assert!(n_sh > 1, "blend_pass requires at least two shards");
    let k_obj = targets.len();
    debug_assert_eq!(out_mean_obj.len(), k_obj * c);
    debug_assert_eq!(out_std.len(), c);
    let m_eff = blend_k.clamp(1, n_sh);

    let BlendScratch { lists, panel, tg, ws, prec, acc, cent, dist, sel } = scratch;

    // Shard centroids for neighbour selection (committed rows only —
    // every shard has >= 1 once a split has happened).
    cent.clear();
    cent.resize(n_sh * d, 0.0);
    for (s, sh) in shards.iter().enumerate() {
        let inv = 1.0 / sh.rows.len() as f64;
        for k in 0..d {
            cent[s * d + k] = sh.centroid_sum[k] * inv;
        }
    }

    // Blend-set selection: owner + (m_eff - 1) nearest other shards.
    lists.resize(n_sh, Vec::new());
    for l in lists.iter_mut() {
        l.clear();
    }
    for j in 0..c {
        let xj = &cand[j * d..(j + 1) * d];
        let (_, owner) = route(nodes, xj);
        sel.clear();
        sel.push(owner);
        if m_eff > 1 {
            dist.clear();
            for s in 0..n_sh {
                if s == owner {
                    continue;
                }
                let mut sq = 0.0;
                for k in 0..d {
                    let dv = xj[k] - cent[s * d + k];
                    sq += dv * dv;
                }
                dist.push((sq, s));
            }
            dist.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            sel.extend(dist.iter().take(m_eff - 1).map(|&(_, s)| s));
        }
        for &s in sel.iter() {
            lists[s].push(j);
        }
    }

    if m_eff > 1 {
        prec.clear();
        prec.resize(c, 0.0);
        acc.clear();
        acc.resize(k_obj * c, 0.0);
    }

    // Score each shard's sub-panel through its own engine, gathering
    // that shard's local targets (committed rows in ascending global
    // order, then its fantasies in global extension order — matching
    // the shard factor's row order exactly).
    for sid in 0..n_sh {
        if lists[sid].is_empty() {
            continue;
        }
        let js = &lists[sid];
        let w = js.len();
        panel.clear();
        for &j in js {
            panel.extend_from_slice(&cand[j * d..(j + 1) * d]);
        }
        let sh = &mut shards[sid];
        let m_s = sh.gp.total();
        tg.clear();
        for t in targets {
            for &g in &sh.rows {
                tg.push(t[g]);
            }
            for (fj, &fs) in fantasy_shard.iter().enumerate() {
                if fs == sid {
                    tg.push(t[committed + fj]);
                }
            }
        }
        debug_assert_eq!(tg.len(), k_obj * m_s);
        let refs: Vec<&[f64]> = tg.chunks(m_s).collect();
        sh.gp.score_multi_into(panel, w, &refs, ws);
        if m_eff == 1 {
            // Pure routing: the owner's posterior verbatim.
            for (p, &j) in js.iter().enumerate() {
                out_std[j] = ws.std[p];
                for k in 0..k_obj {
                    out_mean_obj[k * c + j] = ws.mean_obj[k * w + p];
                }
            }
        } else {
            let wgt = 1.0 / m_eff as f64;
            for (p, &j) in js.iter().enumerate() {
                let var = ws.std[p] * ws.std[p];
                prec[j] += wgt / var;
                for k in 0..k_obj {
                    acc[k * c + j] += ws.mean_obj[k * w + p] * (wgt / var);
                }
            }
        }
    }

    if m_eff > 1 {
        for j in 0..c {
            let var = 1.0 / prec[j];
            out_std[j] = var.sqrt();
            for k in 0..k_obj {
                out_mean_obj[k * c + j] = var * acc[k * c + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rowv(rng: &mut u64, d: usize) -> Vec<f64> {
        (0..d)
            .map(|_| {
                *rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((*rng >> 33) as f64) / ((1u64 << 31) as f64)
            })
            .collect()
    }

    fn obj(x: &[f64]) -> f64 {
        10.0 - x.iter().map(|v| (v - 0.4) * (v - 0.4)).sum::<f64>() * 10.0
    }

    #[test]
    fn splits_partition_all_rows_and_respect_cap() {
        let mut g = ShardedGp::new(GpHyper::default(), 16, 2);
        let mut rng = 7u64;
        for _ in 0..200 {
            let x = rowv(&mut rng, 3);
            let y = obj(&x);
            assert!(g.push(&x, y));
        }
        assert!(g.num_shards() > 1, "200 rows at cap 16 must split");
        assert!(g.max_shard_rows() <= 16);
        // Every global row owned by exactly one shard.
        let mut seen = vec![0usize; g.len()];
        for sh in &g.shards {
            assert!(!sh.rows.is_empty());
            assert!(sh.rows.windows(2).all(|w| w[0] < w[1]), "rows ascending");
            for &r in &sh.rows {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&n| n == 1));
        // Routing agrees with ownership: each stored row routes to the
        // shard that holds it.
        for sh in 0..g.shards.len() {
            for &r in &g.shards[sh].rows {
                let xr = &g.x[r * 3..(r + 1) * 3];
                assert_eq!(route(&g.nodes, xr).1, sh);
            }
        }
    }

    #[test]
    fn single_shard_predict_is_bitwise_exact() {
        let mut flat = IncrementalGp::new(GpHyper::default());
        let mut sharded = ShardedGp::new(GpHyper::default(), 1024, 2);
        let mut rng = 11u64;
        for _ in 0..40 {
            let x = rowv(&mut rng, 4);
            let y = obj(&x);
            assert!(flat.push(&x, y));
            assert!(sharded.push(&x, y));
        }
        assert_eq!(sharded.num_shards(), 1);
        let cand: Vec<Vec<f64>> = (0..16).map(|_| rowv(&mut rng, 4)).collect();
        let a = flat.predict(&cand);
        let b = sharded.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(a.mean[j].to_bits(), b.mean[j].to_bits());
            assert_eq!(a.std[j].to_bits(), b.std[j].to_bits());
        }
    }

    #[test]
    fn multi_shard_posterior_tracks_exact_loosely() {
        let hyper = GpHyper::default();
        let mut exact = IncrementalGp::new(hyper);
        let mut sharded = ShardedGp::new(hyper, 32, 2);
        let mut rng = 3u64;
        for _ in 0..128 {
            let x = rowv(&mut rng, 2);
            let y = obj(&x);
            assert!(exact.push(&x, y));
            assert!(sharded.push(&x, y));
        }
        assert!(sharded.num_shards() > 1);
        let cand: Vec<Vec<f64>> = (0..32).map(|_| rowv(&mut rng, 2)).collect();
        let a = exact.predict(&cand);
        let b = sharded.predict(&cand);
        for j in 0..cand.len() {
            assert!(b.mean[j].is_finite() && b.std[j].is_finite() && b.std[j] > 0.0);
            // Local experts are an approximation: loose envelope only.
            assert!(
                (a.mean[j] - b.mean[j]).abs() < 2.0,
                "blend mean drifted: exact {} vs sharded {}",
                a.mean[j],
                b.mean[j]
            );
        }
    }

    #[test]
    fn fantasy_extend_retract_restores_factors_bitwise() {
        let mut g = ShardedGp::new(GpHyper::default(), 8, 2);
        let mut rng = 19u64;
        for _ in 0..40 {
            let x = rowv(&mut rng, 2);
            assert!(g.push(&x, obj(&x)));
        }
        assert!(g.num_shards() > 1);
        let before = g.factor_entries();
        let n = g.total();
        let f1 = rowv(&mut rng, 2);
        let f2 = rowv(&mut rng, 2);
        assert!(g.extend_fantasy(&f1, 0.0));
        assert!(g.extend_fantasy(&f2, 0.0));
        assert_eq!(g.total(), n + 2);
        let cand: Vec<Vec<f64>> = (0..8).map(|_| rowv(&mut rng, 2)).collect();
        let _ = g.predict(&cand);
        g.retract_fantasies();
        assert_eq!(g.total(), n);
        assert_eq!(g.factor_entries(), before);
    }

    #[test]
    fn factor_entries_stay_linear_in_n() {
        let cap = 16;
        let mut g = ShardedGp::new(GpHyper::default(), cap, 2);
        let mut rng = 23u64;
        for _ in 0..256 {
            let x = rowv(&mut rng, 3);
            assert!(g.push(&x, obj(&x)));
        }
        // Flat engine would hold packed_len(256) = 32 896 entries; the
        // sharded tier holds at most n·(cap+1)/...
        let flat_entries = 256 * 257 / 2;
        assert!(
            g.factor_entries() < flat_entries / 4,
            "sharded factor {} not ≪ flat {}",
            g.factor_entries(),
            flat_entries
        );
    }
}
