//! Native-Rust Gaussian process (exact, from-scratch Cholesky).
//!
//! Role in the surrogate subsystem: the **correctness oracle**. The
//! incremental engine model (`gp::incremental`) must reproduce this
//! posterior bit-for-bit, and integration tests compare the AOT HLO
//! artifact's posterior against this exact solve. It also remains the
//! scratch-refit surrogate behind [`crate::gp::ExactRefitSurrogate`].
//!
//! This implementation is deliberately simple and allocation-heavy — it
//! is the reference, not the hot path (that is `gp::incremental` for the
//! native stack and `runtime::gp` for the artifact stack).

use super::kernel::{eval_sqdist, GpHyper};
use crate::util::linalg::{cholesky, solve_lower, solve_lower_t, sqdist, Mat};

/// Posterior over candidate points.
#[derive(Debug, Clone)]
pub struct Posterior {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

/// Fitted GP: training inputs + Cholesky factor + alpha weights.
pub struct NativeGp {
    x: Vec<Vec<f64>>,
    l: Mat,
    alpha: Vec<f64>,
    hyper: GpHyper,
}

fn kern(a: &[f64], b: &[f64], h: &GpHyper) -> f64 {
    eval_sqdist(h.kernel, sqdist(a, b), h)
}

impl NativeGp {
    /// Fit on training data. `x` rows are points in [0,1]^d; `y` should be
    /// standardised by the caller. Fails if the kernel matrix is not PD
    /// (cannot happen for distinct points + positive noise).
    pub fn fit(x: &[Vec<f64>], y: &[f64], hyper: GpHyper) -> Option<NativeGp> {
        assert_eq!(x.len(), y.len(), "x/y length mismatch");
        assert!(!x.is_empty(), "cannot fit GP on empty data");
        let n = x.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k[(i, j)] = kern(&x[i], &x[j], &hyper);
            }
            k[(i, i)] += hyper.noise_var;
        }
        let l = cholesky(&k)?;
        let alpha = solve_lower_t(&l, &solve_lower(&l, y));
        Some(NativeGp { x: x.to_vec(), l, alpha, hyper })
    }

    /// Posterior mean/std at candidate points.
    pub fn predict(&self, cand: &[Vec<f64>]) -> Posterior {
        let n = self.x.len();
        let mut mean = Vec::with_capacity(cand.len());
        let mut std = Vec::with_capacity(cand.len());
        for c in cand {
            let kc: Vec<f64> = (0..n).map(|i| kern(c, &self.x[i], &self.hyper)).collect();
            let mu: f64 = kc.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
            // var = k(c,c) - kc^T K^-1 kc  via v = L^-1 kc
            let v = solve_lower(&self.l, &kc);
            let var = self.hyper.signal_var - v.iter().map(|x| x * x).sum::<f64>();
            mean.push(mu);
            std.push(var.max(1e-12).sqrt());
        }
        Posterior { mean, std }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn toy_data(rng: &mut Rng, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x.iter().map(|p| (6.0 * p[0]).sin() + 0.5 * p[d - 1]).collect();
        (x, y)
    }

    #[test]
    fn interpolates_training_points_with_small_noise() {
        let mut rng = Rng::new(1);
        let (x, y) = toy_data(&mut rng, 20, 3);
        let gp = NativeGp::fit(&x, &y, GpHyper { noise_var: 1e-8, ..Default::default() }).unwrap();
        let post = gp.predict(&x);
        for (m, yv) in post.mean.iter().zip(&y) {
            assert!((m - yv).abs() < 1e-3, "mean {m} vs y {yv}");
        }
        for s in &post.std {
            assert!(*s < 1e-2);
        }
    }

    #[test]
    fn reverts_to_prior_far_away() {
        let mut rng = Rng::new(2);
        let (x, y) = toy_data(&mut rng, 10, 2);
        let gp = NativeGp::fit(&x, &y, GpHyper { lengthscale: 0.05, ..Default::default() }).unwrap();
        let post = gp.predict(&[vec![50.0, 50.0]]);
        assert!(post.mean[0].abs() < 1e-6);
        assert!((post.std[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn uncertainty_smaller_near_data() {
        let x = vec![vec![0.5, 0.5]];
        let y = vec![1.0];
        let gp = NativeGp::fit(&x, &y, GpHyper::default()).unwrap();
        let post = gp.predict(&[vec![0.5, 0.5], vec![0.9, 0.9]]);
        assert!(post.std[0] < post.std[1]);
    }

    #[test]
    fn hand_computed_single_point_posterior() {
        // n=1: mu(c) = k(c,x) * y / (sv + nv); var = sv - k^2/(sv+nv).
        let h = GpHyper { lengthscale: 0.5, signal_var: 2.0, noise_var: 0.5, ..Default::default() };
        let gp = NativeGp::fit(&[vec![0.0]], &[3.0], h).unwrap();
        let c = vec![0.3];
        let k = 2.0 * f64::exp(-0.5 * 0.09 / 0.25);
        let want_mu = k * 3.0 / 2.5;
        let want_var: f64 = 2.0 - k * k / 2.5;
        let post = gp.predict(&[c]);
        assert!((post.mean[0] - want_mu).abs() < 1e-10);
        assert!((post.std[0] - want_var.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn prop_posterior_sane_everywhere() {
        prop::check("gp posterior sane", 30, |rng| {
            let n = 1 + rng.index(30);
            let (x, y) = toy_data(rng, n, 4);
            let gp = NativeGp::fit(&x, &y, GpHyper::default()).unwrap();
            let cand: Vec<Vec<f64>> = (0..20).map(|_| (0..4).map(|_| rng.f64()).collect()).collect();
            let post = gp.predict(&cand);
            let ymax = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let ymin = y.iter().cloned().fold(f64::INFINITY, f64::min);
            let span = (ymax - ymin).max(1.0);
            for (m, s) in post.mean.iter().zip(&post.std) {
                assert!(m.is_finite() && s.is_finite());
                assert!(*s >= 0.0 && *s <= (GpHyper::default().signal_var.sqrt() + 1e-9));
                // posterior mean can't wildly exceed the data range for an RBF GP
                assert!(*m < ymax + 3.0 * span && *m > ymin - 3.0 * span);
            }
        });
    }

    #[test]
    fn duplicate_points_still_pd_with_noise() {
        let x = vec![vec![0.2, 0.2], vec![0.2, 0.2]];
        let y = vec![1.0, 1.2];
        assert!(NativeGp::fit(&x, &y, GpHyper::default()).is_some());
    }
}
