//! [`RemoteSurrogate`]: a replica of a GP factor served over TCP — the
//! cross-process rung of the shared-surrogate ladder ("Learning to
//! Optimize Tensor Programs" regime: many tuner *processes*, one
//! statistical model).
//!
//! A surrogate service (`server::TargetServer` with an attached
//! [`SharedSurrogate`], or the `surrogate-serve` CLI daemon) owns the
//! authoritative factor. Each tuner process connects a `RemoteSurrogate`
//! and hands it to its BO engine via `BayesOpt::with_shared_surrogate` —
//! the engine neither knows nor cares that the model lives elsewhere,
//! because the replica implements the same [`SurrogateHandle`] contract
//! as the in-process handle:
//!
//! - **tell never blocks on scoring** — [`SurrogateHandle::tell`] writes
//!   one fire-and-forget `tell-obs` line to the service and returns; the
//!   service folds it into the authoritative store in arrival order.
//! - **ask drains in observation order** — [`SurrogateHandle::lock`]
//!   first performs a `sync-factor` round trip: the service exports a
//!   [`SurrogateDelta`](super::shared::SurrogateDelta) holding the rows
//!   this replica is missing *plus the packed Cholesky suffix for them*,
//!   so catching up after Δn observations is an O(Δn·n) verbatim import
//!   (bit-identical to the authority), not an O(n³) refit. TCP ordering
//!   guarantees every tell this process sent earlier is included. The
//!   guard then scores against the local mirror with zero further
//!   network traffic.
//! - **guard-drop retracts fantasies** — locally via the ordinary guard
//!   drop; *cross-process* via leases. On every guard drop the replica
//!   publishes the batch's own constant-liar points as a lease
//!   (`ask-lease`, replacing its previous one); sibling processes receive
//!   those points in their next delta and condition on them as ambient
//!   fantasies. If the process dies instead of retracting, the service
//!   expires its leases when the connection closes.
//!
//! # Reconnection
//!
//! Real campaigns outlive daemon restarts (a `surrogate-serve
//! --state-dir` daemon may be killed and restored mid-run), so the
//! replica's connection layer retries transparently with exponential
//! backoff, mirroring `RemoteEvaluator`: on a transport failure the
//! wire is torn down, re-dialled, and the protocol handshake is redone.
//! Because **leases are liveness state, not model state**, they are NOT
//! journaled by the durability plane — a restarted daemon boots with an
//! empty lease table, and a replica's old lease died with its old
//! connection anyway. The redial path therefore re-publishes this
//! process's current in-flight set under a fresh lease id, so siblings
//! keep conditioning on it across the restart. `with_reconnect(0, ..)`
//! restores strict fail-fast semantics (one shot, no redial budget).
//!
//! A tell that was buffered by the kernel but never reached a dying
//! daemon is still lost (fire-and-forget has no acknowledgement); the
//! durable authority only guarantees what it *received* survives.
//!
//! Known limitation: in-guard hyper changes (`SurrogateGuard::ensure_hyper`,
//! e.g. lengthscale re-selection) act on the local mirror only and are
//! overwritten by the authority's hypers on the next sync; use
//! [`SurrogateHandle::set_hyper`] (which writes through via `set-hyper`)
//! for changes that should win group-wide.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::kernel::GpHyper;
use super::shared::{SharedSurrogate, SurrogateGuard, SurrogateHandle};
use crate::obs::{Event, EventSource};
use crate::space::SearchSpace;
use crate::server::proto::{
    decode_surrogate_response, encode_surrogate_request, SurrogateRequest, SurrogateResponse,
    PROTOCOL_VERSION,
};

/// Default reconnect budget: up to 4 redials with exponential backoff
/// starting at [`DEFAULT_RECONNECT_BASE`] (20, 40, 80, 160 ms) — enough
/// to ride out a daemon kill-restart-restore cycle without stalling a
/// healthy session noticeably. Mirrors `RemoteEvaluator`'s defaults.
pub const DEFAULT_RECONNECT_ATTEMPTS: usize = 4;
/// First-retry backoff delay (doubles per attempt).
pub const DEFAULT_RECONNECT_BASE: Duration = Duration::from_millis(20);

/// One line-oriented connection to the surrogate service. Requests that
/// expect a response are serialised behind the connection mutex; tells
/// write without reading.
struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn send(&mut self, req: &SurrogateRequest) -> Result<()> {
        writeln!(self.writer, "{}", encode_surrogate_request(req))?;
        Ok(())
    }

    /// One round trip; the second element is the raw response line length
    /// in bytes (newline included) — the wire cost the observability
    /// plane attributes to `sync-factor` events.
    fn request(&mut self, req: &SurrogateRequest) -> Result<(SurrogateResponse, usize)> {
        self.send(req)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("surrogate service closed the connection");
        }
        let resp = decode_surrogate_response(line.trim_end()).map_err(|e| anyhow::anyhow!(e))?;
        Ok((resp, n))
    }
}

/// Dial the service once: connect, handshake, negotiate the protocol
/// version (min of ours and the service's; v2 is the oldest surrogate
/// plane we speak). `space` — the fingerprint + dimension pair of the
/// search space this replica conditions — targets that space on a v4
/// fleet daemon; a typed `hello-err` (wrong space, fleet full) is a hard
/// error, not a retry. An older daemon ignores the fingerprint and binds
/// its default space, exactly the pre-v4 contract.
fn dial(addr: &str, space: Option<(u64, usize)>) -> Result<(Conn, u32)> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting surrogate service {addr}"))?;
    // Line-oriented request/response: dodge Nagle/delayed-ACK stalls
    // (same rationale as RemoteEvaluator::connect).
    stream.set_nodelay(true)?;
    let writer = stream.try_clone()?;
    let mut conn = Conn { writer, reader: BufReader::new(stream) };
    let hello = SurrogateRequest::Hello {
        version: PROTOCOL_VERSION,
        fingerprint: space.map(|(fp, _)| fp),
        dim: space.map(|(_, d)| d),
    };
    let version = match conn.request(&hello)?.0 {
        SurrogateResponse::HelloOk { version } => {
            anyhow::ensure!(
                (2..=PROTOCOL_VERSION).contains(&version),
                "surrogate service speaks protocol v{version}, this replica \
                 v{PROTOCOL_VERSION} (v2 is the oldest surrogate plane)"
            );
            version
        }
        SurrogateResponse::HelloErr { reason } => {
            bail!("surrogate service refused this search space: {reason}")
        }
        SurrogateResponse::Error { message } => bail!("handshake refused: {message}"),
        other => bail!("unexpected handshake response: {other:?}"),
    };
    if space.is_some() && version < 4 {
        eprintln!(
            "tftune: surrogate service {addr} speaks protocol v{version} — no search-space \
             fingerprinting, so this replica conditions the daemon's default space"
        );
    }
    Ok((conn, version))
}

/// The wire (None between a transport failure and the next successful
/// redial) and the protocol version it negotiated.
struct ConnState {
    wire: Option<Conn>,
    version: u32,
}

/// Bit-exact identity of a published point set — the dedup key that
/// keeps an unchanged in-flight batch from being retract-and-republished
/// on every guard drop.
fn lease_key(points: &[(Vec<f64>, f64)]) -> Vec<(Vec<u64>, u64)> {
    points
        .iter()
        .map(|(x, lie)| (x.iter().map(|v| v.to_bits()).collect(), lie.to_bits()))
        .collect()
}

/// This process's lease bookkeeping, shared by the guard-drop hook and
/// the redial path (which must re-publish after a daemon restart).
/// Lock order: connection state strictly before lease state.
#[derive(Default)]
struct LeaseState {
    /// Server-side id of our currently published lease, if any.
    active: Option<u64>,
    /// Bit-key of the last successfully published (or empty) point set —
    /// the guard-drop dedup that avoids republishing an unchanged batch.
    last_key: Vec<(Vec<u64>, u64)>,
    /// The current in-flight point set itself, kept so a redial can
    /// re-publish it under a fresh id.
    points: Vec<(Vec<f64>, f64)>,
}

/// The replica's connection layer: address, wire state, reconnect
/// budget and lease bookkeeping — everything the guard-drop hooks and
/// the request paths share.
struct Link {
    addr: String,
    /// Fingerprint + dimension of the fleet space this replica targets
    /// (None = the daemon's default space, the pre-v4 contract). Stored
    /// so a redial re-handshakes into the *same* space.
    space: Option<(u64, usize)>,
    state: Mutex<ConnState>,
    lease: Mutex<LeaseState>,
    attempts: AtomicUsize,
    base_ms: AtomicU64,
    /// Catch-up chunk size in rows (0 = whole delta in one response).
    chunk: AtomicUsize,
    /// Whether catch-up factors ride the quantised-with-exact-residual
    /// encoding (bit-identical either way; this only shrinks the wire).
    quant: AtomicBool,
    /// Observability: emits `sync-factor` / `lease-published` events once
    /// a source is attached ([`RemoteSurrogate::set_event_source`]).
    /// Write-once so the request hot paths read it lock-free.
    events: OnceLock<EventSource>,
}

impl Link {
    fn backoff(&self) -> (usize, Duration) {
        (
            self.attempts.load(Ordering::SeqCst),
            Duration::from_millis(self.base_ms.load(Ordering::SeqCst)),
        )
    }

    /// The `sync-factor` knobs to use right now, gated on the negotiated
    /// version: a pre-v4 daemon would silently ignore `max_rows` (so the
    /// chunk loop's `pending` would never arrive) — ask it for the full
    /// delta instead.
    fn catchup_knobs(&self) -> (Option<usize>, bool) {
        if self.state.lock().unwrap().version < 4 {
            return (None, false);
        }
        let chunk = self.chunk.load(Ordering::SeqCst);
        (if chunk == 0 { None } else { Some(chunk) }, self.quant.load(Ordering::SeqCst))
    }

    /// Re-dial and re-handshake, then re-publish the current lease: the
    /// old lease expired with the old connection (and a restarted daemon
    /// boots with an empty lease table regardless), so siblings would
    /// otherwise stop conditioning on our in-flight trials.
    fn redial(&self, st: &mut ConnState) -> Result<()> {
        let (conn, version) = dial(&self.addr, self.space)?;
        st.wire = Some(conn);
        st.version = version;
        let mut ls = self.lease.lock().unwrap();
        ls.active = None;
        ls.last_key.clear();
        if !ls.points.is_empty() {
            if let Ok((SurrogateResponse::Lease { id }, _)) = st
                .wire
                .as_mut()
                .expect("wire installed above")
                .request(&SurrogateRequest::AskLease { points: ls.points.clone() })
            {
                ls.active = Some(id);
                // Restore the dedup key so the next guard drop with the
                // same in-flight set keeps this lease, and an *empty*
                // drop (batch finished) still retracts it.
                ls.last_key = lease_key(&ls.points);
                if let Some(src) = self.events.get() {
                    src.emit(Event::LeasePublished { id, points: ls.points.len() });
                }
            }
        }
        Ok(())
    }

    /// One request/response round trip with transparent reconnect.
    /// Transport failures tear the wire down and retry with exponential
    /// backoff up to the configured budget; protocol-level refusals
    /// (decoded [`SurrogateResponse::Error`]s) are returned to the
    /// caller, never retried.
    fn roundtrip(&self, req: &SurrogateRequest) -> Result<SurrogateResponse> {
        self.roundtrip_counted(req).map(|(resp, _)| resp)
    }

    /// [`Link::roundtrip`] that also reports the raw response line length
    /// in bytes — the catch-up path sums these into `sync-factor` events
    /// so the dashboard's wire-cost column reflects actual octets moved.
    fn roundtrip_counted(&self, req: &SurrogateRequest) -> Result<(SurrogateResponse, usize)> {
        let (attempts, base) = self.backoff();
        let mut delay = base;
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=attempts {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
            let mut st = self.state.lock().unwrap();
            if st.wire.is_none() {
                match self.redial(&mut st) {
                    Ok(()) => eprintln!(
                        "tftune: reconnected to surrogate service {} (attempt {attempt})",
                        self.addr
                    ),
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            match st.wire.as_mut().expect("wire present").request(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    st.wire = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).with_context(|| {
            format!(
                "surrogate service {} unreachable after {attempts} reconnect attempt(s)",
                self.addr
            )
        })
    }

    /// One fire-and-forget `tell-obs` line with the same reconnect
    /// discipline as [`Link::roundtrip`]. The secondary columns are
    /// re-evaluated against the *current* negotiated version on every
    /// attempt (a redial may land on an older daemon).
    fn send_tell(
        &self,
        x: &[f64],
        y: f64,
        extras: &[f64],
        warned_v2: &AtomicBool,
    ) -> Result<()> {
        let (attempts, base) = self.backoff();
        let mut delay = base;
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=attempts {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = delay.saturating_mul(2);
            }
            let mut st = self.state.lock().unwrap();
            if st.wire.is_none() {
                match self.redial(&mut st) {
                    Ok(()) => eprintln!(
                        "tftune: reconnected to surrogate service {} (attempt {attempt})",
                        self.addr
                    ),
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            let ys = if st.version >= 3 {
                extras.to_vec()
            } else {
                if !extras.is_empty() && !warned_v2.swap(true, Ordering::SeqCst) {
                    eprintln!(
                        "tftune: the surrogate service speaks protocol v{} — secondary \
                         objective columns cannot cross the wire, so the shared factor \
                         degrades to the primary objective (upgrade the daemon for \
                         fleet-wide multi-objective tuning)",
                        st.version
                    );
                }
                Vec::new()
            };
            let req = SurrogateRequest::TellObs { x: x.to_vec(), y, ys };
            match st.wire.as_mut().expect("wire present").send(&req) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    st.wire = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).with_context(|| {
            format!(
                "surrogate service {} unreachable after {attempts} reconnect attempt(s)",
                self.addr
            )
        })
    }
}

struct Remote {
    link: Arc<Link>,
    /// The local replica: a plain [`SharedSurrogate`] whose store mirrors
    /// the authority's, in the authority's (canonical) order.
    mirror: SharedSurrogate,
    /// Tells sent since the last successful sync. TCP ordering makes the
    /// next sync observe all of them, so this resets to zero per sync.
    pending_tells: AtomicUsize,
    /// Whether the v2-degradation warning has fired (once per replica).
    warned_v2_extras: AtomicBool,
}

/// Handle to a GP factor served by a surrogate service (module docs).
/// Cloning is cheap and shares the connection and the local mirror.
pub struct RemoteSurrogate {
    inner: Arc<Remote>,
}

impl Clone for RemoteSurrogate {
    fn clone(&self) -> RemoteSurrogate {
        RemoteSurrogate { inner: Arc::clone(&self.inner) }
    }
}

impl std::fmt::Debug for RemoteSurrogate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteSurrogate").finish_non_exhaustive()
    }
}

impl RemoteSurrogate {
    /// Connect to a surrogate service, perform the protocol handshake,
    /// and pull the initial full-factor sync (adopting the authority's
    /// hypers). Fails loudly on a version mismatch or a daemon that hosts
    /// no surrogate — the *initial* connection never retries; the
    /// reconnect budget ([`RemoteSurrogate::with_reconnect`]) covers
    /// failures after a session is established.
    pub fn connect(addr: &str) -> Result<RemoteSurrogate> {
        RemoteSurrogate::connect_with(addr, None)
    }

    /// [`RemoteSurrogate::connect`] targeting one space of a protocol-v4
    /// *fleet* daemon: the hello carries `space`'s fingerprint
    /// ([`SearchSpace::fingerprint`]) and dimension, and the daemon binds
    /// this connection to the matching factor (creating it on first
    /// hello). A typed `hello-err` — dimension mismatch, fleet at
    /// `--max-spaces` — surfaces as an `Err` here instead of silently
    /// conditioning the wrong model. Pre-v4 daemons ignore the
    /// fingerprint and serve their single space, with a warning.
    pub fn connect_space(addr: &str, space: &SearchSpace) -> Result<RemoteSurrogate> {
        RemoteSurrogate::connect_with(addr, Some((space.fingerprint(), space.dim())))
    }

    fn connect_with(addr: &str, space: Option<(u64, usize)>) -> Result<RemoteSurrogate> {
        let (conn, version) = dial(addr, space)?;
        let link = Arc::new(Link {
            addr: addr.to_string(),
            space,
            state: Mutex::new(ConnState { wire: Some(conn), version }),
            lease: Mutex::new(LeaseState::default()),
            attempts: AtomicUsize::new(DEFAULT_RECONNECT_ATTEMPTS),
            base_ms: AtomicU64::new(DEFAULT_RECONNECT_BASE.as_millis() as u64),
            chunk: AtomicUsize::new(0),
            quant: AtomicBool::new(false),
            events: OnceLock::new(),
        });

        let initial =
            SurrogateRequest::SyncFactor { from_n: 0, max_rows: None, quantise: false };
        let (delta, pending) = match link.roundtrip(&initial)? {
            SurrogateResponse::FactorDelta { delta, pending, .. } => (delta, pending),
            SurrogateResponse::Error { message } => bail!("initial sync refused: {message}"),
            other => bail!("unexpected sync response: {other:?}"),
        };
        let mirror = SharedSurrogate::new(delta.hyper);
        anyhow::ensure!(mirror.import_delta(&delta), "initial surrogate delta rejected");

        // Lease publication: every guard drop replaces this process's
        // lease with the batch's own fantasy points (publish the new one
        // before retracting the old, so siblings never see a gap). Runs
        // with the mirror's model lock already released. The current
        // point set is stored in the shared LeaseState *before*
        // publishing so a redial re-publishes exactly what is in flight.
        let hook_link = Arc::clone(&link);
        mirror.set_lease_hook(move |points| {
            let key = lease_key(points);
            {
                let mut ls = hook_link.lease.lock().unwrap();
                if key == ls.last_key {
                    return; // unchanged in-flight set: nothing to republish
                }
                ls.points = points.to_vec();
            }
            let next = if points.is_empty() {
                None
            } else {
                match hook_link
                    .roundtrip(&SurrogateRequest::AskLease { points: points.to_vec() })
                {
                    Ok(SurrogateResponse::Lease { id }) => {
                        if let Some(src) = hook_link.events.get() {
                            src.emit(Event::LeasePublished { id, points: points.len() });
                        }
                        Some(id)
                    }
                    // Transport hiccup past the reconnect budget: skip —
                    // disconnect expiry is the backstop for a lease that
                    // never got replaced.
                    _ => None,
                }
            };
            let old = {
                let mut ls = hook_link.lease.lock().unwrap();
                let old = ls.active.take();
                ls.active = next;
                if points.is_empty() || next.is_some() {
                    ls.last_key = key;
                } else {
                    // Publish failed: the service holds no lease for us
                    // now, so forget the key — the next guard drop with
                    // the same in-flight set must retry instead of
                    // deduping away.
                    ls.last_key.clear();
                }
                old
            };
            if let Some(old) = old {
                let _ = hook_link.roundtrip(&SurrogateRequest::RetractLease { id: old });
            }
        });

        // Hyper write-through: an in-guard hyper change (e.g. lengthscale
        // selection inside the engine's batch) publishes `set-hyper` to
        // the service when the guard drops, so sibling replicas adopt the
        // same hypers on their next sync instead of fighting the served
        // factor. Runs with the model lock already released.
        let hyper_link = Arc::clone(&link);
        mirror.set_hyper_hook(move |hyper| {
            match hyper_link.roundtrip(&SurrogateRequest::SetHyper { hyper }) {
                Ok(SurrogateResponse::HyperOk) => {}
                Ok(other) => eprintln!("tftune: unexpected set-hyper response: {other:?}"),
                Err(e) => eprintln!(
                    "tftune: surrogate set-hyper write-through failed ({e}); the service \
                     re-adopts on the next explicit set_hyper"
                ),
            }
        });

        let replica = RemoteSurrogate {
            inner: Arc::new(Remote {
                link,
                mirror,
                pending_tells: AtomicUsize::new(0),
                warned_v2_extras: AtomicBool::new(false),
            }),
        };
        // The initial sync asked for the whole delta, so a conforming
        // daemon reports nothing pending; drain defensively anyway.
        if pending > 0 {
            replica.sync().context("completing the initial factor sync")?;
        }
        Ok(replica)
    }

    /// Configure how catch-up deltas cross the wire (protocol v4 only;
    /// pre-v4 daemons always send the full delta in one response).
    /// `chunk_rows = Some(k)` bounds each `factor-delta` response to `k`
    /// rows — the replica loops, resumably, until the service reports
    /// nothing pending. `quantise` switches the packed factor suffix to
    /// the quantised-with-exact-residual encoding: an f32 mantissa plus
    /// the XOR residual to the exact f64 bits, smaller on the wire and
    /// still bit-identical after import. Both default off. Applies to
    /// every clone sharing this connection.
    pub fn with_catchup(self, chunk_rows: Option<usize>, quantise: bool) -> RemoteSurrogate {
        self.inner.link.chunk.store(chunk_rows.unwrap_or(0), Ordering::SeqCst);
        self.inner.link.quant.store(quantise, Ordering::SeqCst);
        self
    }

    /// Override the transparent-reconnect budget: up to `attempts`
    /// redials per request with exponential backoff starting at `base`.
    /// `with_reconnect(0, ..)` restores strict fail-fast behaviour — one
    /// shot per request, errors surface immediately. Applies to every
    /// clone sharing this connection.
    pub fn with_reconnect(self, attempts: usize, base: Duration) -> RemoteSurrogate {
        self.inner.link.attempts.store(attempts, Ordering::SeqCst);
        self.inner.link.base_ms.store(base.as_millis() as u64, Ordering::SeqCst);
        self
    }

    /// Attach an observability event source: every catch-up sync emits
    /// one `sync-factor` event (rows imported, raw wire bytes, elapsed
    /// nanos) and every successful lease publication — guard-drop hook
    /// and redial re-publish alike — emits `lease-published`. A clone is
    /// forwarded to the local mirror so its drain/factor-size events flow
    /// under the same source name. Write-once: the first source wins and
    /// later calls are ignored, keeping the request hot paths lock-free.
    pub fn set_event_source(&self, src: EventSource) {
        self.inner.mirror.set_event_source(src.clone());
        let _ = self.inner.link.events.set(src);
    }

    /// Drop the live wire now, as if the daemon had just died: the
    /// client socket closes and the next round trip goes through the
    /// redial path under the configured reconnect budget. Chaos drills
    /// (`tests/fleet_service.rs`) sever every replica of a daemon being
    /// killed so its connection handlers unblock on EOF and the listener
    /// port frees deterministically; production code never needs this.
    pub fn sever(&self) {
        self.inner.link.state.lock().unwrap().wire = None;
    }

    /// Catch up with the service: ask for everything past the mirror's
    /// current length and import it (factor suffix verbatim when
    /// present). With a chunked budget ([`RemoteSurrogate::with_catchup`])
    /// this loops — each round trip imports one bounded chunk, advancing
    /// the mirror, until the service reports nothing pending; a
    /// mid-catch-up reconnect simply resumes from wherever the mirror
    /// got to. Serialised behind the connection mutex; rides the
    /// reconnect budget, so a daemon restored from `--state-dir` between
    /// two asks is caught up transparently.
    fn sync(&self) -> Result<()> {
        let events = self.inner.link.events.get().filter(|s| s.enabled());
        let t0 = events.map(|_| Instant::now());
        let start_n = self.inner.mirror.len();
        let mut wire_bytes = 0usize;
        loop {
            let from_n = self.inner.mirror.len();
            let (max_rows, quantise) = self.inner.link.catchup_knobs();
            let req = SurrogateRequest::SyncFactor { from_n, max_rows, quantise };
            match self.inner.link.roundtrip_counted(&req)? {
                (SurrogateResponse::FactorDelta { delta: d, pending, .. }, n) => {
                    wire_bytes += n;
                    anyhow::ensure!(
                        self.inner.mirror.import_delta(&d),
                        "surrogate delta rejected (replica at {from_n}, delta from {})",
                        d.from_n
                    );
                    if pending == 0 {
                        break;
                    }
                    anyhow::ensure!(
                        self.inner.mirror.len() > from_n,
                        "surrogate chunked sync stalled at row {from_n} with {pending} \
                         row(s) still pending"
                    );
                }
                (SurrogateResponse::Error { message }, _) => {
                    bail!("surrogate service error: {message}")
                }
                (other, _) => bail!("unexpected sync response: {other:?}"),
            }
        }
        if let (Some(src), Some(t0)) = (events, t0) {
            src.emit(Event::SyncFactor {
                rows: self.inner.mirror.len() - start_n,
                bytes: wire_bytes,
                ns: t0.elapsed().as_nanos() as u64,
            });
        }
        self.inner.pending_tells.store(0, Ordering::SeqCst);
        Ok(())
    }
}

impl SurrogateHandle for RemoteSurrogate {
    /// Fire-and-forget: one `tell-obs` line to the service. Never blocks
    /// on a scoring pass (scoring happens against the local mirror with
    /// the connection released); a transport failure retries through the
    /// reconnect budget and then drops the observation with a warning
    /// rather than poisoning the session.
    fn tell(&self, x: Vec<f64>, y: f64) {
        self.tell_multi(x, vec![y]);
    }

    /// K-column tell: the secondary objective columns ride the same
    /// `tell-obs` line (`ys`). Against a v2 service the extras are
    /// dropped at the wire — the served factor (and therefore every
    /// mirror) degrades to single-objective rather than confusing an
    /// old daemon; a one-time warning makes the degradation visible.
    fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>) {
        let Some((&y, extra)) = ys.split_first() else {
            eprintln!("tftune: dropping observation with no objective columns");
            return;
        };
        match self.inner.link.send_tell(&x, y, extra, &self.inner.warned_v2_extras) {
            Ok(()) => {
                self.inner.pending_tells.fetch_add(1, Ordering::SeqCst);
            }
            Err(e) => eprintln!(
                "tftune: surrogate tell lost ({e}); continuing on the remaining observations"
            ),
        }
    }

    /// Sync with the service (catch-up delta, sibling leases), then lock
    /// the local mirror. If the service is unreachable past the
    /// reconnect budget the engine scores on the stale replica —
    /// degraded, not dead.
    fn lock(&self) -> SurrogateGuard<'_> {
        if let Err(e) = self.sync() {
            eprintln!("tftune: surrogate sync failed ({e}); scoring on the stale replica");
        }
        self.inner.mirror.lock()
    }

    fn hyper(&self) -> GpHyper {
        self.inner.mirror.hyper()
    }

    /// Write-through: the mirror switches hypers through a guard, whose
    /// drop publishes `set-hyper` to the service (the hyper hook
    /// installed at connect) — the same path in-guard `ensure_hyper`
    /// changes take, so explicit and in-guard switches cannot diverge.
    /// Every sibling replica adopts the new hypers on its next sync.
    fn set_hyper(&self, hyper: GpHyper) {
        self.inner.mirror.set_hyper(hyper);
    }

    /// Local-mirror policy only (the service keeps its own factoring
    /// eagerness; it must, since other replicas rely on the suffix).
    fn set_eager_factoring(&self, on: bool) {
        self.inner.mirror.set_eager_factoring(on)
    }

    /// Rows in the local mirror (the service may hold more until the next
    /// sync).
    fn len(&self) -> usize {
        self.inner.mirror.len()
    }

    /// Mirrored rows plus tells this process sent since the last sync —
    /// a lower bound on what the next lock will condition on.
    fn total_observations(&self) -> usize {
        self.inner.mirror.len() + self.inner.pending_tells.load(Ordering::SeqCst)
    }

    fn clone_handle(&self) -> Box<dyn SurrogateHandle> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::TargetServer;

    fn shutdown_daemon(addr: std::net::SocketAddr) {
        use crate::server::proto::{encode_request, Request};
        let space = crate::space::threading_space(64, 1024, 64);
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = writeln!(s, "{}", encode_request(&Request::Shutdown, &space));
        }
    }

    /// Sever the replica's wire as if the connection had just died: the
    /// client socket closes (so the daemon's handler unblocks on EOF and
    /// the daemon can be shut down and joined deterministically) and the
    /// replica's next request goes through the redial path.
    fn sever(replica: &RemoteSurrogate) {
        replica.sever();
    }

    #[test]
    fn connect_failure_is_clean_error() {
        // Port 1 is never a surrogate service.
        let err = RemoteSurrogate::connect("127.0.0.1:1").unwrap_err();
        assert!(err.to_string().contains("connecting surrogate service"), "{err}");
    }

    #[test]
    fn reconnects_and_republishes_lease_after_daemon_restart() {
        let (server, _factor) =
            TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
        let (addr, handle) = server.spawn().unwrap();
        let a = RemoteSurrogate::connect(&addr.to_string())
            .unwrap()
            .with_reconnect(20, Duration::from_millis(5));
        a.tell(vec![0.25, 0.75], 1.0);
        {
            let mut ga = a.lock();
            assert_eq!(ga.len(), 1);
            // Leave a fantasy in flight: the guard drop publishes it as
            // this process's lease.
            assert!(ga.extend_fantasy(&[0.4, 0.6], 0.0));
        }

        // The daemon dies mid-campaign.
        sever(&a);
        shutdown_daemon(addr);
        let _ = handle.join();

        // Restart on the very same port hosting a restored factor (the
        // durable-daemon path: persist::recover + bind_surrogate_with).
        // Its lease table starts empty by design.
        let restored = SharedSurrogate::new(GpHyper::default());
        restored.tell(vec![0.25, 0.75], 1.0);
        let (server2, _f2) =
            TargetServer::bind_surrogate_with(&addr.to_string(), restored).unwrap();
        let (_, handle2) = server2.spawn().unwrap();

        // The next tell redials, re-handshakes and — because leases died
        // with the old connection — re-publishes the stored in-flight
        // set under a fresh id before the observation goes out.
        a.tell(vec![0.5, 0.5], 2.0);

        // A sibling connecting to the restarted daemon still conditions
        // on A's pre-crash in-flight point.
        let b = RemoteSurrogate::connect(&addr.to_string()).unwrap();
        {
            let gb = b.lock();
            assert_eq!(gb.ambient_len(), 1, "lease not re-published after restart");
            let (x, lie) = gb.ambient_point(0);
            assert_eq!(x, vec![0.4, 0.6]);
            assert_eq!(lie, 0.0);
        }

        // A's catch-up sync sees both the restored row and the
        // post-restart tell; re-extending the same in-flight point
        // dedups against the redial's lease instead of republishing.
        {
            let mut ga = a.lock();
            assert_eq!(ga.len(), 2, "post-restart catch-up incomplete");
            assert!(ga.extend_fantasy(&[0.4, 0.6], 0.0));
        }
        {
            let gb = b.lock();
            assert_eq!(gb.ambient_len(), 1, "unchanged lease republished after dedup");
        }

        drop(a);
        drop(b);
        shutdown_daemon(addr);
        let _ = handle2.join();
    }

    #[test]
    fn zero_attempts_restores_fail_fast() {
        let (server, _factor) =
            TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
        let (addr, handle) = server.spawn().unwrap();
        let replica = RemoteSurrogate::connect(&addr.to_string())
            .unwrap()
            .with_reconnect(0, Duration::from_millis(1));
        replica.tell(vec![0.25, 0.75], 1.0);

        // Kill the daemon for good (no restart): with a zero reconnect
        // budget the next round trip gets exactly one shot and fails
        // with the fail-fast error instead of retrying.
        sever(&replica);
        shutdown_daemon(addr);
        let _ = handle.join();
        let err = replica
            .inner
            .link
            .roundtrip(&SurrogateRequest::SyncFactor {
                from_n: 0,
                max_rows: None,
                quantise: false,
            })
            .unwrap_err();
        assert!(err.to_string().contains("unreachable after 0"), "{err}");
    }
}
