//! [`RemoteSurrogate`]: a replica of a GP factor served over TCP — the
//! cross-process rung of the shared-surrogate ladder ("Learning to
//! Optimize Tensor Programs" regime: many tuner *processes*, one
//! statistical model).
//!
//! A surrogate service (`server::TargetServer` with an attached
//! [`SharedSurrogate`], or the `surrogate-serve` CLI daemon) owns the
//! authoritative factor. Each tuner process connects a `RemoteSurrogate`
//! and hands it to its BO engine via `BayesOpt::with_shared_surrogate` —
//! the engine neither knows nor cares that the model lives elsewhere,
//! because the replica implements the same [`SurrogateHandle`] contract
//! as the in-process handle:
//!
//! - **tell never blocks on scoring** — [`SurrogateHandle::tell`] writes
//!   one fire-and-forget `tell-obs` line to the service and returns; the
//!   service folds it into the authoritative store in arrival order.
//! - **ask drains in observation order** — [`SurrogateHandle::lock`]
//!   first performs a `sync-factor` round trip: the service exports a
//!   [`SurrogateDelta`](super::shared::SurrogateDelta) holding the rows
//!   this replica is missing *plus the packed Cholesky suffix for them*,
//!   so catching up after Δn observations is an O(Δn·n) verbatim import
//!   (bit-identical to the authority), not an O(n³) refit. TCP ordering
//!   guarantees every tell this process sent earlier is included. The
//!   guard then scores against the local mirror with zero further
//!   network traffic.
//! - **guard-drop retracts fantasies** — locally via the ordinary guard
//!   drop; *cross-process* via leases. On every guard drop the replica
//!   publishes the batch's own constant-liar points as a lease
//!   (`ask-lease`, replacing its previous one); sibling processes receive
//!   those points in their next delta and condition on them as ambient
//!   fantasies. If the process dies instead of retracting, the service
//!   expires its leases when the connection closes.
//!
//! Known limitation: in-guard hyper changes (`SurrogateGuard::ensure_hyper`,
//! e.g. lengthscale re-selection) act on the local mirror only and are
//! overwritten by the authority's hypers on the next sync; use
//! [`SurrogateHandle::set_hyper`] (which writes through via `set-hyper`)
//! for changes that should win group-wide.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::kernel::GpHyper;
use super::shared::{SharedSurrogate, SurrogateGuard, SurrogateHandle};
use crate::server::proto::{
    decode_surrogate_response, encode_surrogate_request, SurrogateRequest, SurrogateResponse,
    PROTOCOL_VERSION,
};

/// One line-oriented connection to the surrogate service. Requests that
/// expect a response are serialised behind the connection mutex; tells
/// write without reading.
struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn send(&mut self, req: &SurrogateRequest) -> Result<()> {
        writeln!(self.writer, "{}", encode_surrogate_request(req))?;
        Ok(())
    }

    fn request(&mut self, req: &SurrogateRequest) -> Result<SurrogateResponse> {
        self.send(req)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("surrogate service closed the connection");
        }
        decode_surrogate_response(line.trim_end()).map_err(|e| anyhow::anyhow!(e))
    }
}

struct Remote {
    conn: Arc<Mutex<Conn>>,
    /// The local replica: a plain [`SharedSurrogate`] whose store mirrors
    /// the authority's, in the authority's (canonical) order.
    mirror: SharedSurrogate,
    /// Tells sent since the last successful sync. TCP ordering makes the
    /// next sync observe all of them, so this resets to zero per sync.
    pending_tells: AtomicUsize,
    /// Protocol version negotiated at connect (min of ours and the
    /// service's). Against a v2 service the replica degrades to
    /// single-objective tells: secondary columns are **dropped at the
    /// wire** (the authoritative store never sees them, so neither does
    /// any mirror) — announced by a one-time warning on the first
    /// multi-column tell.
    version: u32,
    /// Whether the v2-degradation warning has fired (once per replica).
    warned_v2_extras: AtomicBool,
}

/// Handle to a GP factor served by a surrogate service (module docs).
/// Cloning is cheap and shares the connection and the local mirror.
pub struct RemoteSurrogate {
    inner: Arc<Remote>,
}

impl Clone for RemoteSurrogate {
    fn clone(&self) -> RemoteSurrogate {
        RemoteSurrogate { inner: Arc::clone(&self.inner) }
    }
}

impl std::fmt::Debug for RemoteSurrogate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteSurrogate").finish_non_exhaustive()
    }
}

impl RemoteSurrogate {
    /// Connect to a surrogate service, perform the protocol handshake,
    /// and pull the initial full-factor sync (adopting the authority's
    /// hypers). Fails loudly on a version mismatch or a daemon that hosts
    /// no surrogate.
    pub fn connect(addr: &str) -> Result<RemoteSurrogate> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting surrogate service {addr}"))?;
        // Line-oriented request/response: dodge Nagle/delayed-ACK stalls
        // (same rationale as RemoteEvaluator::connect).
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        let mut conn = Conn { writer, reader: BufReader::new(stream) };

        // Version negotiation: the service answers with min(its version,
        // ours). Anything from v2 up is workable — against a v2 service
        // this replica simply degrades to single-objective tells (the
        // surrogate plane itself predates v2, so below that we refuse).
        let version = match conn.request(&SurrogateRequest::Hello { version: PROTOCOL_VERSION })?
        {
            SurrogateResponse::HelloOk { version } => {
                anyhow::ensure!(
                    (2..=PROTOCOL_VERSION).contains(&version),
                    "surrogate service speaks protocol v{version}, this replica \
                     v{PROTOCOL_VERSION} (v2 is the oldest surrogate plane)"
                );
                version
            }
            SurrogateResponse::Error { message } => bail!("handshake refused: {message}"),
            other => bail!("unexpected handshake response: {other:?}"),
        };
        let delta = match conn.request(&SurrogateRequest::SyncFactor { from_n: 0 })? {
            SurrogateResponse::FactorDelta(d) => d,
            SurrogateResponse::Error { message } => bail!("initial sync refused: {message}"),
            other => bail!("unexpected sync response: {other:?}"),
        };
        let mirror = SharedSurrogate::new(delta.hyper);
        anyhow::ensure!(mirror.import_delta(&delta), "initial surrogate delta rejected");

        let conn = Arc::new(Mutex::new(conn));
        // Lease publication: every guard drop replaces this process's
        // lease with the batch's own fantasy points (publish the new one
        // before retracting the old, so siblings never see a gap). Runs
        // with the mirror's model lock already released.
        let hook_conn = Arc::clone(&conn);
        let mut active: Option<u64> = None;
        let mut last_key: Vec<(Vec<u64>, u64)> = Vec::new();
        mirror.set_lease_hook(move |points| {
            let key: Vec<(Vec<u64>, u64)> = points
                .iter()
                .map(|(x, lie)| (x.iter().map(|v| v.to_bits()).collect(), lie.to_bits()))
                .collect();
            if key == last_key {
                return; // unchanged in-flight set: nothing to republish
            }
            let mut c = hook_conn.lock().unwrap();
            let next = if points.is_empty() {
                None
            } else {
                match c.request(&SurrogateRequest::AskLease { points: points.to_vec() }) {
                    Ok(SurrogateResponse::Lease { id }) => Some(id),
                    // Transport hiccup: skip — disconnect expiry is the
                    // backstop for a lease that never got replaced.
                    _ => None,
                }
            };
            if let Some(old) = active.take() {
                let _ = c.request(&SurrogateRequest::RetractLease { id: old });
            }
            active = next;
            if points.is_empty() || active.is_some() {
                last_key = key;
            } else {
                // Publish failed: the service holds no lease for us now,
                // so forget the key — the next guard drop with the same
                // in-flight set must retry instead of deduping away.
                last_key.clear();
            }
        });

        // Hyper write-through: an in-guard hyper change (e.g. lengthscale
        // selection inside the engine's batch) publishes `set-hyper` to
        // the service when the guard drops, so sibling replicas adopt the
        // same hypers on their next sync instead of fighting the served
        // factor. Runs with the model lock already released.
        let hyper_conn = Arc::clone(&conn);
        mirror.set_hyper_hook(move |hyper| {
            let mut c = hyper_conn.lock().unwrap();
            match c.request(&SurrogateRequest::SetHyper { hyper }) {
                Ok(SurrogateResponse::HyperOk) => {}
                Ok(other) => eprintln!("tftune: unexpected set-hyper response: {other:?}"),
                Err(e) => eprintln!(
                    "tftune: surrogate set-hyper write-through failed ({e}); the service \
                     re-adopts on the next explicit set_hyper"
                ),
            }
        });

        Ok(RemoteSurrogate {
            inner: Arc::new(Remote {
                conn,
                mirror,
                pending_tells: AtomicUsize::new(0),
                version,
                warned_v2_extras: AtomicBool::new(false),
            }),
        })
    }

    /// One catch-up round trip: ask the service for everything past the
    /// mirror's current length and import it (factor suffix verbatim when
    /// present). Serialised behind the connection mutex.
    fn sync(&self) -> Result<()> {
        let mut conn = self.inner.conn.lock().unwrap();
        let from_n = self.inner.mirror.len();
        match conn.request(&SurrogateRequest::SyncFactor { from_n })? {
            SurrogateResponse::FactorDelta(d) => {
                anyhow::ensure!(
                    self.inner.mirror.import_delta(&d),
                    "surrogate delta rejected (replica at {from_n}, delta from {})",
                    d.from_n
                );
                self.inner.pending_tells.store(0, Ordering::SeqCst);
                Ok(())
            }
            SurrogateResponse::Error { message } => bail!("surrogate service error: {message}"),
            other => bail!("unexpected sync response: {other:?}"),
        }
    }
}

impl SurrogateHandle for RemoteSurrogate {
    /// Fire-and-forget: one `tell-obs` line to the service. Never blocks
    /// on a scoring pass (scoring happens against the local mirror with
    /// the connection released); a transport failure drops the
    /// observation with a warning rather than poisoning the session.
    fn tell(&self, x: Vec<f64>, y: f64) {
        self.tell_multi(x, vec![y]);
    }

    /// K-column tell: the secondary objective columns ride the same
    /// `tell-obs` line (`ys`). Against a v2 service the extras are
    /// dropped at the wire — the served factor (and therefore every
    /// mirror) degrades to single-objective rather than confusing an
    /// old daemon; a one-time warning makes the degradation visible.
    fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>) {
        let Some((&y, extra)) = ys.split_first() else {
            eprintln!("tftune: dropping observation with no objective columns");
            return;
        };
        let ys = if self.inner.version >= 3 {
            extra.to_vec()
        } else {
            if !extra.is_empty() && !self.inner.warned_v2_extras.swap(true, Ordering::SeqCst) {
                eprintln!(
                    "tftune: the surrogate service speaks protocol v{} — secondary \
                     objective columns cannot cross the wire, so the shared factor \
                     degrades to the primary objective (upgrade the daemon for \
                     fleet-wide multi-objective tuning)",
                    self.inner.version
                );
            }
            Vec::new()
        };
        let mut conn = self.inner.conn.lock().unwrap();
        match conn.send(&SurrogateRequest::TellObs { x, y, ys }) {
            Ok(()) => {
                self.inner.pending_tells.fetch_add(1, Ordering::SeqCst);
            }
            Err(e) => eprintln!(
                "tftune: surrogate tell lost ({e}); continuing on the remaining observations"
            ),
        }
    }

    /// Sync with the service (catch-up delta, sibling leases), then lock
    /// the local mirror. If the service is unreachable the engine scores
    /// on the stale replica — degraded, not dead.
    fn lock(&self) -> SurrogateGuard<'_> {
        if let Err(e) = self.sync() {
            eprintln!("tftune: surrogate sync failed ({e}); scoring on the stale replica");
        }
        self.inner.mirror.lock()
    }

    fn hyper(&self) -> GpHyper {
        self.inner.mirror.hyper()
    }

    /// Write-through: the mirror switches hypers through a guard, whose
    /// drop publishes `set-hyper` to the service (the hyper hook
    /// installed at connect) — the same path in-guard `ensure_hyper`
    /// changes take, so explicit and in-guard switches cannot diverge.
    /// Every sibling replica adopts the new hypers on its next sync.
    fn set_hyper(&self, hyper: GpHyper) {
        self.inner.mirror.set_hyper(hyper);
    }

    /// Local-mirror policy only (the service keeps its own factoring
    /// eagerness; it must, since other replicas rely on the suffix).
    fn set_eager_factoring(&self, on: bool) {
        self.inner.mirror.set_eager_factoring(on)
    }

    /// Rows in the local mirror (the service may hold more until the next
    /// sync).
    fn len(&self) -> usize {
        self.inner.mirror.len()
    }

    /// Mirrored rows plus tells this process sent since the last sync —
    /// a lower bound on what the next lock will condition on.
    fn total_observations(&self) -> usize {
        self.inner.mirror.len() + self.inner.pending_tells.load(Ordering::SeqCst)
    }

    fn clone_handle(&self) -> Box<dyn SurrogateHandle> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_failure_is_clean_error() {
        // Port 1 is never a surrogate service.
        let err = RemoteSurrogate::connect("127.0.0.1:1").unwrap_err();
        assert!(err.to_string().contains("connecting surrogate service"), "{err}");
    }
}
