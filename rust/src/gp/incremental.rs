//! Incremental Gaussian process: a persistent Cholesky factor with O(n²)
//! rank-1 appends, cheap constant-liar *extend/retract*, and a
//! zero-allocation blocked scoring path.
//!
//! Role in the surrogate subsystem: this is the model the BO engine keeps
//! alive across the whole tuning run. [`IncrementalGp::push`] folds a new
//! observation into the factor in O(n²) (vs the oracle's O(n³) refit);
//! [`IncrementalGp::extend_fantasy`] conditions on an in-flight trial the
//! same way and [`IncrementalGp::retract_fantasies`] truncates the factor
//! back — fantasies are pure appends, so retracting is exact (bitwise)
//! state restoration, not an approximate downdate.
//!
//! Scoring ([`IncrementalGp::score_into`]) is a real *scoring engine*:
//! the cross-kernel panel `Kc` is built candidate-block-major in a
//! caller-owned [`ScoreWorkspace`], the posterior mean formed as one
//! panel·α accumulation, and the variance taken through one cache-blocked
//! multi-RHS trsm ([`trsm_lower_packed_blocked`], geometry tunable via
//! [`BlockSpec`]) — one pass over the whole candidate pool instead of a
//! per-candidate fit/solve, with no buffer growth once the workspace has
//! warmed up. Two knobs scale it:
//!
//! - [`IncrementalGp::set_score_threads`] partitions the pool into
//!   **fixed contiguous candidate blocks** scored by scoped worker
//!   threads, each owning its exclusive slice of the workspace. Because a
//!   candidate's panel column, mean accumulation and variance solve touch
//!   only that candidate's column — and the partition is a pure function
//!   of (pool size, thread count) — every candidate's result is
//!   **bit-identical** to the serial sweep for any thread count.
//! - [`IncrementalGp::set_score_tier`] selects [`ScoreTier::F32`], which
//!   downcasts factor/inputs/panel to f32 for acquisition *ranking* only;
//!   [`ScoreTier::F64`] stays the default and the pinned oracle.
//!
//! Numerical contract: on the f64 tier every routine performs the same
//! floating-point operations in the same order as the exact oracle
//! (`gp::native`), so an incrementally grown posterior is bit-equal to a
//! from-scratch [`NativeGp::fit`](super::NativeGp::fit) on the same data
//! — for any thread count or blocking. The `surrogate_incremental` and
//! `scoring_engine` integration suites pin this; keep per-entry operation
//! order (ascending-index accumulation) intact when editing.

use super::kernel::{eval_sqdist, eval_sqdist_f32, GpHyper};
use super::native::Posterior;
use crate::util::linalg::{
    chol_append_packed, packed_len, solve_lower_packed_inplace, solve_lower_t_packed_inplace,
    sqdist, sqdist_f32, trsm_lower_packed_blocked, trsm_lower_packed_blocked_f32, BlockSpec,
};

/// Arithmetic width of the scoring pass.
///
/// [`ScoreTier::F64`] (the default) is the pinned oracle path: bit-equal
/// to the from-scratch reference for any thread count or [`BlockSpec`].
/// [`ScoreTier::F32`] downcasts the factor, inputs and panel to f32 for
/// acquisition *ranking* only — the mean/std handed back are cast up but
/// carry f32 precision and must never feed a parity pin. BO tolerates the
/// ranking noise on well-separated gains (property-tested in
/// `rust/tests/scoring_engine.rs`); everything the model *learns* (the
/// factor, α, appended rows) stays f64 regardless of tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScoreTier {
    /// Full f64 scoring — the default and the bitwise oracle.
    #[default]
    F64,
    /// Downcast f32 fast tier, for acquisition ranking only.
    F32,
}

impl ScoreTier {
    pub fn name(self) -> &'static str {
        match self {
            ScoreTier::F64 => "f64",
            ScoreTier::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<ScoreTier> {
        match s.to_lowercase().as_str() {
            "f64" | "double" | "exact" => Some(ScoreTier::F64),
            "f32" | "single" | "fast" => Some(ScoreTier::F32),
            _ => None,
        }
    }
}

/// Reusable buffers for the scoring hot path. Own one per engine and pass
/// it to every [`IncrementalGp::score_into`] call; after the first call at
/// a given (history, candidates) shape, none of these buffers grow again
/// (the no-per-ask-heap-growth contract — probe with
/// [`ScoreWorkspace::heap_capacities`]).
#[derive(Debug, Default)]
pub struct ScoreWorkspace {
    /// n×c cross-kernel panel; overwritten by L⁻¹Kc during scoring.
    panel: Vec<f64>,
    /// Posterior mean per candidate (primary objective).
    pub mean: Vec<f64>,
    /// Posterior stddev per candidate.
    pub std: Vec<f64>,
    /// Acquisition gain per candidate.
    pub gain: Vec<f64>,
    /// Scratch index order (filled by [`ScoreWorkspace::argsort_gain_desc`]).
    pub order: Vec<usize>,
    /// K×c posterior means of a multi-objective panel pass
    /// ([`IncrementalGp::score_multi_into`]): objective `k`'s mean at
    /// candidate `j` lives at `k * c + j`. The posterior *std* is shared
    /// across objectives (it depends only on X and the kernel) and stays
    /// in [`ScoreWorkspace::std`].
    pub mean_obj: Vec<f64>,
    /// Objective count of the last multi-objective pass (0 = none).
    pub n_obj: usize,
    /// K×n per-objective α = K⁻¹y scratch for the multi pass.
    alpha_obj: Vec<f64>,
    /// Downcast scratch for the [`ScoreTier::F32`] fast tier.
    f32buf: F32Buffers,
}

/// Downcast scratch for the [`ScoreTier::F32`] fast tier, grouped in one
/// struct so the scoring core can split-borrow it from the f64 output
/// buffers. Empty (and never filled) on the default f64 tier.
#[derive(Debug, Default)]
struct F32Buffers {
    /// Downcast packed factor.
    l: Vec<f32>,
    /// Downcast per-objective α (objective-major, K×n).
    alpha: Vec<f32>,
    /// Downcast history inputs (row-major n×d).
    x: Vec<f32>,
    /// Downcast candidate pool (row-major c×d).
    cand: Vec<f32>,
    /// f32 cross-kernel panel (n×c).
    panel: Vec<f32>,
    /// f32 per-objective means (K×c), cast up after the pass.
    mean: Vec<f32>,
    /// f32 variance accumulators / stds (c), cast up after the pass.
    std: Vec<f32>,
}

impl ScoreWorkspace {
    /// Fill `order` with candidate indices sorted by descending gain and
    /// return it. Reuses the buffer — no allocation once warmed up.
    pub fn argsort_gain_desc(&mut self) -> &[usize] {
        self.order.clear();
        self.order.extend(0..self.gain.len());
        let gain = &self.gain;
        // total_cmp: panic-free and deterministic even for NaN gains.
        self.order.sort_by(|&a, &b| gain[b].total_cmp(&gain[a]));
        &self.order
    }

    /// Capacities of every owned buffer — the allocation-stability probe
    /// behind the engine's no-per-ask-heap-growth test: once a workload's
    /// shapes have been seen, repeated scoring passes must leave all of
    /// these unchanged.
    pub fn heap_capacities(&self) -> [usize; 14] {
        [
            self.panel.capacity(),
            self.mean.capacity(),
            self.std.capacity(),
            self.gain.capacity(),
            self.order.capacity(),
            self.mean_obj.capacity(),
            self.alpha_obj.capacity(),
            self.f32buf.l.capacity(),
            self.f32buf.alpha.capacity(),
            self.f32buf.x.capacity(),
            self.f32buf.cand.capacity(),
            self.f32buf.panel.capacity(),
            self.f32buf.mean.capacity(),
            self.f32buf.std.capacity(),
        ]
    }
}

/// A fitted GP whose factor grows in place.
///
/// Targets are mutable separately from inputs ([`IncrementalGp::set_targets`]):
/// the Cholesky factor depends only on X, so the engine can restandardise
/// y every iteration and pay two O(n²) triangular solves, not a refit.
#[derive(Debug)]
pub struct IncrementalGp {
    hyper: GpHyper,
    /// Feature dimension; fixed by the first appended row.
    d: usize,
    /// Committed (real) observations; rows beyond this are fantasies.
    committed: usize,
    /// Row-major (total×d) inputs.
    x: Vec<f64>,
    /// Targets, one per row (fantasies carry their lie value).
    y: Vec<f64>,
    /// Packed-lower Cholesky factor of K + σₙ²I over all rows.
    l: Vec<f64>,
    /// α = K⁻¹y for the current targets (valid iff !alpha_dirty).
    alpha: Vec<f64>,
    alpha_dirty: bool,
    /// Scratch for new-row covariances (capacity-reserved).
    kbuf: Vec<f64>,
    /// Scoring arithmetic tier (default [`ScoreTier::F64`]).
    tier: ScoreTier,
    /// Scoring worker threads (default 1 = serial; results bit-identical
    /// for every count).
    threads: usize,
    /// Cache-blocking geometry for the panel build and trsm.
    blocks: BlockSpec,
    /// Reused workspace for [`IncrementalGp::predict`].
    predict_ws: ScoreWorkspace,
    /// Reused flat-candidate scratch for [`IncrementalGp::predict`].
    predict_flat: Vec<f64>,
}

impl IncrementalGp {
    pub fn new(hyper: GpHyper) -> IncrementalGp {
        // Reservation hint only: an unbounded window (UNBOUNDED_HISTORY =
        // usize::MAX) must not translate into a usize::MAX reservation.
        let cap = hyper.max_history.clamp(1, 1024);
        IncrementalGp {
            hyper,
            d: 0,
            committed: 0,
            x: Vec::new(),
            y: Vec::with_capacity(cap),
            l: Vec::with_capacity(packed_len(cap)),
            alpha: Vec::with_capacity(cap),
            alpha_dirty: true,
            kbuf: Vec::with_capacity(cap),
            tier: ScoreTier::F64,
            threads: 1,
            blocks: BlockSpec::default(),
            predict_ws: ScoreWorkspace::default(),
            predict_flat: Vec::new(),
        }
    }

    pub fn hyper(&self) -> GpHyper {
        self.hyper
    }

    /// Scoring arithmetic tier. Scoring config lives on the engine, never
    /// in [`GpHyper`]: hypers are serialized over the wire/WAL as a pure
    /// model parameterisation, while tier/threads/blocking only change
    /// *how fast* (and on f32, at what ranking precision) the same model
    /// is scored.
    pub fn score_tier(&self) -> ScoreTier {
        self.tier
    }

    /// Select the scoring tier; see [`ScoreTier`] for the contract.
    pub fn set_score_tier(&mut self, tier: ScoreTier) {
        self.tier = tier;
    }

    /// Scoring worker threads.
    pub fn score_threads(&self) -> usize {
        self.threads
    }

    /// Set the scoring worker-thread count (clamped to ≥ 1). Results are
    /// bit-identical for every count: the candidate pool is partitioned
    /// into fixed contiguous blocks — a pure function of (pool size,
    /// thread count) — and each candidate's per-column op sequence is
    /// unchanged from the serial sweep.
    pub fn set_score_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Cache-blocking geometry used by the panel build and blocked trsm.
    pub fn block_spec(&self) -> BlockSpec {
        self.blocks
    }

    /// Set the cache-blocking geometry (bitwise output-invariant; see
    /// [`BlockSpec`]). Tuned by `examples/self_tune_scoring.rs`.
    pub fn set_block_spec(&mut self, blocks: BlockSpec) {
        self.blocks = blocks;
    }

    /// Change hyperparameters. The factor is kernel-dependent, so this
    /// clears the model; the caller re-pushes its conditioning set.
    pub fn set_hyper(&mut self, hyper: GpHyper) {
        self.hyper = hyper;
        self.clear();
    }

    /// Committed (non-fantasy) observations.
    pub fn len(&self) -> usize {
        self.committed
    }

    pub fn is_empty(&self) -> bool {
        self.committed == 0
    }

    /// Committed + fantasy rows currently factored in.
    pub fn total(&self) -> usize {
        self.y.len()
    }

    /// Entries held by the packed Cholesky factor — `packed_len(total)`.
    /// The storage-cost probe behind the sharded tier's boundedness
    /// tests (a flat factor grows O(n²); a sharded ensemble ~O(n·cap)).
    pub fn factor_len(&self) -> usize {
        self.l.len()
    }

    pub fn clear(&mut self) {
        self.committed = 0;
        self.x.clear();
        self.y.clear();
        self.l.clear();
        self.alpha.clear();
        self.alpha_dirty = true;
    }

    /// Rank-1 append of one row (O(total²)). Returns false — leaving the
    /// model unchanged — if the extended kernel matrix is not PD (only
    /// possible with zero/negative noise and duplicate points).
    fn append_row(&mut self, xr: &[f64], yv: f64) -> bool {
        let m = self.total();
        if m == 0 {
            self.d = xr.len();
            assert!(self.d > 0, "empty feature vector");
            self.x.reserve(self.hyper.max_history.clamp(1, 1024) * self.d);
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        self.kbuf.clear();
        for i in 0..m {
            let xi = &self.x[i * self.d..(i + 1) * self.d];
            self.kbuf.push(eval_sqdist(self.hyper.kernel, sqdist(xr, xi), &self.hyper));
        }
        let diag = self.hyper.signal_var + self.hyper.noise_var;
        // Split borrows: chol_append_packed mutates l and kbuf only.
        let IncrementalGp { l, kbuf, .. } = self;
        if !chol_append_packed(l, m, kbuf, diag) {
            return false;
        }
        self.x.extend_from_slice(xr);
        self.y.push(yv);
        self.alpha_dirty = true;
        true
    }

    /// Append a committed observation.
    pub fn push(&mut self, xr: &[f64], yv: f64) -> bool {
        debug_assert_eq!(
            self.committed,
            self.total(),
            "push with fantasies in place; retract first"
        );
        if !self.append_row(xr, yv) {
            return false;
        }
        self.committed += 1;
        true
    }

    /// Condition on an in-flight trial (constant liar): identical math to
    /// [`IncrementalGp::push`], but the row is dropped again by
    /// [`IncrementalGp::retract_fantasies`].
    pub fn extend_fantasy(&mut self, xr: &[f64], lie: f64) -> bool {
        self.append_row(xr, lie)
    }

    /// The packed Cholesky rows `from..total`, concatenated — the suffix a
    /// replica needs to catch up after `total - from` appends. Row `m`
    /// contributes `m + 1` entries, so the slice holds
    /// `packed_len(total) - packed_len(from)` values. Appends never modify
    /// earlier factor entries, which is exactly why a suffix transfer is
    /// sound: the replica's prefix is already bit-identical.
    pub fn factor_suffix(&self, from: usize) -> &[f64] {
        assert!(from <= self.total(), "suffix start {from} past factor end");
        &self.l[packed_len(from)..]
    }

    /// Append a committed row whose packed factor row was computed
    /// elsewhere (the authoritative factor of a surrogate service) — the
    /// O(n) import counterpart of the O(n²) [`IncrementalGp::push`].
    /// `lrow` must be the `total() + 1` packed entries of the next factor
    /// row, produced by the same kernel/hyper/row-order as this model.
    /// Returns false (model unchanged) on a non-positive diagonal.
    pub fn import_row(&mut self, xr: &[f64], yv: f64, lrow: &[f64]) -> bool {
        let m = self.total();
        debug_assert_eq!(self.committed, m, "import with fantasies in place; retract first");
        if m == 0 {
            self.d = xr.len();
            assert!(self.d > 0, "empty feature vector");
            self.x.reserve(self.hyper.max_history.clamp(1, 1024) * self.d);
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        assert_eq!(lrow.len(), m + 1, "factor row length mismatch");
        let diag = lrow[m];
        if !(diag.is_finite() && diag > 0.0) {
            return false;
        }
        self.l.extend_from_slice(lrow);
        self.x.extend_from_slice(xr);
        self.y.push(yv);
        self.committed += 1;
        self.alpha_dirty = true;
        true
    }

    /// Drop all fantasy rows, restoring the exact pre-extend state: the
    /// factor is truncated (appends never modify earlier entries), so no
    /// numerical downdate is involved.
    pub fn retract_fantasies(&mut self) {
        let m = self.committed;
        if self.total() == m {
            return;
        }
        self.x.truncate(m * self.d);
        self.y.truncate(m);
        self.l.truncate(packed_len(m));
        self.alpha_dirty = true;
    }

    /// Replace the targets of every current row (committed + fantasies).
    /// O(1) when unchanged; otherwise α is lazily recomputed on the next
    /// score from the persistent factor (two O(n²) triangular solves).
    pub fn set_targets(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.total(), "target length mismatch");
        if self.y == y {
            return;
        }
        self.y.clear();
        self.y.extend_from_slice(y);
        self.alpha_dirty = true;
    }

    fn refresh_alpha(&mut self) {
        if !self.alpha_dirty {
            return;
        }
        let m = self.total();
        self.alpha.clear();
        self.alpha.extend_from_slice(&self.y);
        solve_lower_packed_inplace(&self.l, m, &mut self.alpha);
        solve_lower_t_packed_inplace(&self.l, m, &mut self.alpha);
        self.alpha_dirty = false;
    }

    /// Score `c` candidates (row-major c×d in `cand`) into `ws`: posterior
    /// mean/std and the SMSego gain `(μ + acq_alpha·σ) − y_best`, through
    /// the scoring engine (tier / threads / blocking — see the module
    /// docs). The numeric buffers allocate nothing once `ws` has grown to
    /// shape; a pass adds only O(threads · objectives) transient slice
    /// bookkeeping on top.
    pub fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        let m = self.total();
        assert!(m > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        self.refresh_alpha();

        ws.mean.clear();
        ws.mean.resize(c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);

        let gp: &IncrementalGp = self;
        let ScoreWorkspace { panel, mean, std, gain, f32buf, .. } = ws;
        score_partitioned(gp, cand, c, &gp.alpha, 1, panel, mean, std, f32buf);

        for ((g, mu), s) in gain.iter_mut().zip(mean.iter()).zip(std.iter()) {
            *g = (*mu + acq_alpha * *s) - y_best;
        }
    }

    /// Solve `out = (K + σₙ²I)⁻¹ y` against the current factor without
    /// touching model state — the per-objective α of a multi-objective
    /// panel pass. Performs exactly the two triangular solves
    /// [`IncrementalGp::set_targets`] + scoring would perform for the
    /// same targets, in the same order, so a K-objective pass is
    /// bit-equal to K independent single-objective models sharing this
    /// factor.
    pub fn solve_alpha(&self, y: &[f64], out: &mut Vec<f64>) {
        let m = self.total();
        assert_eq!(y.len(), m, "target length mismatch");
        out.clear();
        out.extend_from_slice(y);
        solve_lower_packed_inplace(&self.l, m, out);
        solve_lower_t_packed_inplace(&self.l, m, out);
    }

    /// Score `c` candidates against **K objectives in one blocked panel
    /// pass**: the cross-kernel panel and the variance triangular solve
    /// are computed once (they depend only on X), and each objective
    /// contributes one α solve plus one panel·α accumulation. Mean of
    /// objective `k` lands in `ws.mean_obj[k*c..(k+1)*c]`; the shared
    /// posterior std in `ws.std`; `ws.mean` mirrors the primary
    /// objective (`targets[0]`). `ws.gain` is resized and zeroed — the
    /// caller's acquisition (scalarised or hypervolume gain) fills it.
    ///
    /// `targets` are per-objective target vectors over every current row
    /// (committed + fantasies, standardised by the caller; fantasy rows
    /// carry their per-objective lies). The factor is read, never
    /// modified: K objectives cost K panel accumulations over one
    /// factor, not K refits.
    pub fn score_multi_into(
        &mut self,
        cand: &[f64],
        c: usize,
        targets: &[&[f64]],
        ws: &mut ScoreWorkspace,
    ) {
        let m = self.total();
        assert!(m > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        let k_obj = targets.len();
        assert!(k_obj > 0, "need at least one objective");
        for t in targets {
            assert_eq!(t.len(), m, "target length mismatch");
        }

        // Per-objective α against the shared factor (no state touched;
        // the same two solves `solve_alpha` performs, into ws scratch so
        // a warmed-up pass allocates nothing).
        ws.alpha_obj.clear();
        ws.alpha_obj.reserve(k_obj * m);
        for t in targets {
            let start = ws.alpha_obj.len();
            ws.alpha_obj.extend_from_slice(t);
            let col = &mut ws.alpha_obj[start..];
            solve_lower_packed_inplace(&self.l, m, col);
            solve_lower_t_packed_inplace(&self.l, m, col);
        }

        ws.n_obj = k_obj;
        ws.mean_obj.clear();
        ws.mean_obj.resize(k_obj * c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);

        // One engine pass: the panel and variance trsm are computed once
        // (they depend only on X), each objective contributes one panel·α
        // accumulation. Runs through the same partitioned core as
        // score_into, so threads/tier/blocking apply here too.
        let gp: &IncrementalGp = self;
        let ScoreWorkspace { panel, std, mean, mean_obj, alpha_obj, f32buf, .. } = ws;
        score_partitioned(gp, cand, c, alpha_obj, k_obj, panel, mean_obj, std, f32buf);

        // Mirror the primary objective into the single-objective slot.
        mean.clear();
        mean.extend_from_slice(&mean_obj[..c]);
    }

    /// Convenience wrapper over [`IncrementalGp::score_into`] for tests
    /// and oracle comparisons. Routes through the same scoring engine and
    /// a model-owned reused [`ScoreWorkspace`], so repeated predictions
    /// exercise exactly the kernels the hot path uses and stop allocating
    /// scratch once warmed up (only the returned [`Posterior`] allocates).
    pub fn predict(&mut self, cand: &[Vec<f64>]) -> Posterior {
        let mut flat = std::mem::take(&mut self.predict_flat);
        let mut ws = std::mem::take(&mut self.predict_ws);
        flat.clear();
        flat.reserve(cand.len() * self.d);
        for row in cand {
            assert_eq!(row.len(), self.d, "candidate dim mismatch");
            flat.extend_from_slice(row);
        }
        self.score_into(&flat, cand.len(), 0.0, 0.0, &mut ws);
        let post = Posterior { mean: ws.mean.clone(), std: ws.std.clone() };
        self.predict_flat = flat;
        self.predict_ws = ws;
        post
    }
}

/// Refill a downcast scratch buffer from an f64 source, reusing capacity.
fn fill_f32(dst: &mut Vec<f32>, src: &[f64]) {
    dst.clear();
    dst.extend(src.iter().map(|&v| v as f32));
}

/// Fixed contiguous partition of `c` candidates over `threads` workers: a
/// pure function of `(c, threads)` (first `c % threads` workers take one
/// extra), so the parallel sweep's per-column operation order — and
/// therefore every output bit — matches the serial one. Requires
/// `1 <= threads <= c`, so every range is non-empty.
fn partition_bounds(c: usize, threads: usize) -> Vec<(usize, usize)> {
    let base = c / threads;
    let rem = c % threads;
    let mut bounds = Vec::with_capacity(threads);
    let mut j0 = 0;
    for wi in 0..threads {
        let w = base + usize::from(wi < rem);
        bounds.push((j0, j0 + w));
        j0 += w;
    }
    debug_assert_eq!(j0, c);
    bounds
}

/// Split each row of an objective-major `K×c` buffer at the worker
/// bounds, transposed worker-major: `result[wi]` holds worker `wi`'s
/// exclusive `[j0, j1)` sub-slice of every objective row.
fn carve_rows<'a, T>(
    buf: &'a mut [T],
    c: usize,
    bounds: &[(usize, usize)],
) -> Vec<Vec<&'a mut [T]>> {
    let mut per: Vec<Vec<&'a mut [T]>> =
        bounds.iter().map(|_| Vec::with_capacity(buf.len() / c.max(1))).collect();
    for row in buf.chunks_mut(c) {
        let mut rest = row;
        for (wi, &(j0, j1)) in bounds.iter().enumerate() {
            let (chunk, r) = std::mem::take(&mut rest).split_at_mut(j1 - j0);
            per[wi].push(chunk);
            rest = r;
        }
    }
    per
}

/// One worker's exclusive view of the scoring buffers for a contiguous
/// candidate range — carved up front so scoped threads write disjoint
/// slices with no synchronisation. The f64 variant is the pinned oracle
/// path; the f32 variant carries the downcast inputs (shared) plus the
/// worker's f32 scratch and the f64 output slices the results are cast
/// up into.
enum RangeOut<'a> {
    F64 {
        /// Worker-private m×w panel slab (row stride = range width).
        panel: &'a mut [f64],
        /// Per-objective mean output, this worker's `[j0, j1)` slice.
        means: Vec<&'a mut [f64]>,
        /// Posterior-std output slice (arrives zeroed).
        stds: &'a mut [f64],
    },
    F32 {
        l: &'a [f32],
        alphas: &'a [f32],
        x: &'a [f32],
        cand: &'a [f32],
        panel: &'a mut [f32],
        means32: Vec<&'a mut [f32]>,
        stds32: &'a mut [f32],
        /// f64 output slices the f32 results are cast up into.
        means: Vec<&'a mut [f64]>,
        stds: &'a mut [f64],
    },
}

/// Score candidates `[j0, j1)` of the pool into `out`: panel build →
/// per-objective mean accumulation → blocked variance trsm → std
/// finalisation. On the f64 tier every per-candidate operation sequence
/// is identical to the full serial sweep (ascending-index accumulation
/// throughout, blocking only reorders *which column when*), which is the
/// whole bit-identical-parallelism argument.
fn score_range(
    gp: &IncrementalGp,
    alphas: &[f64],
    k_obj: usize,
    cand: &[f64],
    j0: usize,
    j1: usize,
    out: RangeOut<'_>,
) {
    let w = j1 - j0;
    if w == 0 {
        return;
    }
    let m = gp.total();
    debug_assert_eq!(alphas.len(), k_obj * m, "alphas must be objective-major K x m");
    let d = gp.d;
    let h = &gp.hyper;
    let blocks = gp.blocks;
    let nc = blocks.nc.max(1);
    match out {
        RangeOut::F64 { panel, means, stds } => {
            // Candidate-block-major panel build: each nc-wide block of
            // candidate d-vectors stays cache-hot across all m kernel
            // rows (entries are pure per-(i, j) functions — build order
            // cannot change a bit).
            let mut jb = 0usize;
            while jb < w {
                let je = jb.saturating_add(nc).min(w);
                for i in 0..m {
                    let xi = &gp.x[i * d..(i + 1) * d];
                    let row = &mut panel[i * w + jb..i * w + je];
                    for (jj, kij) in row.iter_mut().enumerate() {
                        let cj0 = (j0 + jb + jj) * d;
                        let cj = &cand[cj0..cj0 + d];
                        *kij = eval_sqdist(h.kernel, sqdist(xi, cj), h);
                    }
                }
                jb = je;
            }
            // μ_k = Kcᵀα_k, ascending-i per candidate — the oracle's
            // dot-product order.
            for (k, mean) in means.into_iter().enumerate() {
                let alpha = &alphas[k * m..(k + 1) * m];
                for (i, &a) in alpha.iter().enumerate() {
                    let row = &panel[i * w..(i + 1) * w];
                    for (mu, kij) in mean.iter_mut().zip(row) {
                        *mu += kij * a;
                    }
                }
            }
            // V = L⁻¹Kc; σ² = k(x,x) − Σᵢ Vᵢⱼ², ascending i.
            trsm_lower_packed_blocked(&gp.l, m, panel, w, blocks);
            for i in 0..m {
                let row = &panel[i * w..(i + 1) * w];
                for (acc, v) in stds.iter_mut().zip(row) {
                    *acc += v * v;
                }
            }
            for s in stds.iter_mut() {
                let var = h.signal_var - *s;
                *s = var.max(1e-12).sqrt();
            }
        }
        RangeOut::F32 {
            l,
            alphas: alphas32,
            x,
            cand: cand32,
            panel,
            mut means32,
            stds32,
            means,
            stds,
        } => {
            // Same structure as the f64 arm at f32 width; results are
            // cast up at the end. Ranking-quality only — never a parity
            // source.
            let mut jb = 0usize;
            while jb < w {
                let je = jb.saturating_add(nc).min(w);
                for i in 0..m {
                    let xi = &x[i * d..(i + 1) * d];
                    let row = &mut panel[i * w + jb..i * w + je];
                    for (jj, kij) in row.iter_mut().enumerate() {
                        let cj0 = (j0 + jb + jj) * d;
                        let cj = &cand32[cj0..cj0 + d];
                        *kij = eval_sqdist_f32(h.kernel, sqdist_f32(xi, cj), h);
                    }
                }
                jb = je;
            }
            for (k, mean) in means32.iter_mut().enumerate() {
                let alpha = &alphas32[k * m..(k + 1) * m];
                for (i, &a) in alpha.iter().enumerate() {
                    let row = &panel[i * w..(i + 1) * w];
                    for (mu, kij) in mean.iter_mut().zip(row) {
                        *mu += kij * a;
                    }
                }
            }
            trsm_lower_packed_blocked_f32(l, m, panel, w, blocks);
            for i in 0..m {
                let row = &panel[i * w..(i + 1) * w];
                for (acc, v) in stds32.iter_mut().zip(row) {
                    *acc += v * v;
                }
            }
            let sv = h.signal_var as f32;
            for s in stds32.iter_mut() {
                let var = sv - *s;
                *s = var.max(1e-12_f32).sqrt();
            }
            for (dst, src) in means.into_iter().zip(means32.iter()) {
                for (o, v) in dst.iter_mut().zip(src.iter()) {
                    *o = *v as f64;
                }
            }
            for (o, v) in stds.iter_mut().zip(stds32.iter()) {
                *o = *v as f64;
            }
        }
    }
}

/// The scoring-engine core shared by [`IncrementalGp::score_into`] and
/// [`IncrementalGp::score_multi_into`]: panel build + per-objective mean
/// accumulation + blocked variance trsm over the candidate pool, run at
/// `gp.tier` precision, tiled by `gp.blocks`, and partitioned over
/// `gp.threads` scoped workers on fixed contiguous candidate blocks.
/// `alphas` is objective-major (K×m), `means` objective-major (K×c),
/// `stds` arrives zeroed (length c). The numeric buffers never grow once
/// warmed; a pass performs only O(threads · objectives) transient slice
/// bookkeeping beyond them (none of it on the serial path's panel/std
/// math itself).
#[allow(clippy::too_many_arguments)]
fn score_partitioned(
    gp: &IncrementalGp,
    cand: &[f64],
    c: usize,
    alphas: &[f64],
    k_obj: usize,
    panel: &mut Vec<f64>,
    means: &mut [f64],
    stds: &mut [f64],
    f32b: &mut F32Buffers,
) {
    if c == 0 {
        return;
    }
    let m = gp.total();
    match gp.tier {
        ScoreTier::F64 => {
            panel.clear();
            panel.resize(m * c, 0.0);
        }
        ScoreTier::F32 => {
            fill_f32(&mut f32b.l, &gp.l);
            fill_f32(&mut f32b.alpha, alphas);
            fill_f32(&mut f32b.x, &gp.x);
            fill_f32(&mut f32b.cand, cand);
            f32b.panel.clear();
            f32b.panel.resize(m * c, 0.0);
            f32b.mean.clear();
            f32b.mean.resize(k_obj * c, 0.0);
            f32b.std.clear();
            f32b.std.resize(c, 0.0);
        }
    }

    let threads = gp.threads.max(1).min(c);
    if threads <= 1 {
        let out = match gp.tier {
            ScoreTier::F64 => RangeOut::F64 {
                panel: &mut panel[..],
                means: means.chunks_mut(c).collect(),
                stds,
            },
            ScoreTier::F32 => RangeOut::F32 {
                l: &f32b.l,
                alphas: &f32b.alpha,
                x: &f32b.x,
                cand: &f32b.cand,
                panel: &mut f32b.panel[..],
                means32: f32b.mean.chunks_mut(c).collect(),
                stds32: &mut f32b.std[..],
                means: means.chunks_mut(c).collect(),
                stds,
            },
        };
        score_range(gp, alphas, k_obj, cand, 0, c, out);
        return;
    }

    // Carve every worker's exclusive output view up front, then fan out
    // on scoped threads (the caller thread takes the first range). Panel
    // slabs are worker-private m×w blocks; mean/std rows are split at the
    // partition bounds.
    let bounds = partition_bounds(c, threads);
    let mut outs: Vec<RangeOut<'_>> = Vec::with_capacity(threads);
    match gp.tier {
        ScoreTier::F64 => {
            let mut panel_rest = &mut panel[..];
            let mut stds_rest = stds;
            let mut means_per = carve_rows(means, c, &bounds);
            for (wi, &(j0, j1)) in bounds.iter().enumerate() {
                let w = j1 - j0;
                let (p, pr) = std::mem::take(&mut panel_rest).split_at_mut(m * w);
                panel_rest = pr;
                let (s, sr) = std::mem::take(&mut stds_rest).split_at_mut(w);
                stds_rest = sr;
                outs.push(RangeOut::F64 {
                    panel: p,
                    means: std::mem::take(&mut means_per[wi]),
                    stds: s,
                });
            }
        }
        ScoreTier::F32 => {
            let F32Buffers { l, alpha, x, cand: cand32, panel: panel32, mean: mean32, std: std32 } =
                f32b;
            let mut panel_rest = &mut panel32[..];
            let mut stds32_rest = &mut std32[..];
            let mut stds_rest = stds;
            let mut means32_per = carve_rows(mean32, c, &bounds);
            let mut means_per = carve_rows(means, c, &bounds);
            for (wi, &(j0, j1)) in bounds.iter().enumerate() {
                let w = j1 - j0;
                let (p, pr) = std::mem::take(&mut panel_rest).split_at_mut(m * w);
                panel_rest = pr;
                let (s32, s32r) = std::mem::take(&mut stds32_rest).split_at_mut(w);
                stds32_rest = s32r;
                let (s, sr) = std::mem::take(&mut stds_rest).split_at_mut(w);
                stds_rest = sr;
                outs.push(RangeOut::F32 {
                    l: &l[..],
                    alphas: &alpha[..],
                    x: &x[..],
                    cand: &cand32[..],
                    panel: p,
                    means32: std::mem::take(&mut means32_per[wi]),
                    stds32: s32,
                    means: std::mem::take(&mut means_per[wi]),
                    stds: s,
                });
            }
        }
    }

    std::thread::scope(|sc| {
        let mut outs = outs.into_iter();
        let first = outs.next().expect("at least one worker range");
        for (&(j0, j1), out) in bounds[1..].iter().zip(outs) {
            sc.spawn(move || score_range(gp, alphas, k_obj, cand, j0, j1, out));
        }
        let (j0, j1) = bounds[0];
        score_range(gp, alphas, k_obj, cand, j0, j1, first);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{KernelKind, NativeGp};
    use crate::util::Rng;

    fn toy(rng: &mut Rng, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x.iter().map(|p| (5.0 * p[0]).sin() + 0.3 * p[d - 1]).collect();
        (x, y)
    }

    fn build(x: &[Vec<f64>], y: &[f64], hyper: GpHyper) -> IncrementalGp {
        let mut gp = IncrementalGp::new(hyper);
        for (xi, &yi) in x.iter().zip(y) {
            assert!(gp.push(xi, yi), "append failed");
        }
        gp
    }

    #[test]
    fn matches_scratch_oracle_both_kernels() {
        let mut rng = Rng::new(7);
        for kind in KernelKind::all() {
            let hyper = GpHyper { kernel: kind, ..Default::default() };
            let (x, y) = toy(&mut rng, 24, 4);
            let mut inc = build(&x, &y, hyper);
            let oracle = NativeGp::fit(&x, &y, hyper).unwrap();
            let cand: Vec<Vec<f64>> =
                (0..16).map(|_| (0..4).map(|_| rng.f64()).collect()).collect();
            let a = inc.predict(&cand);
            let b = oracle.predict(&cand);
            for j in 0..cand.len() {
                assert!(
                    (a.mean[j] - b.mean[j]).abs() <= 1e-9,
                    "{}: mean {} vs {}",
                    kind.name(),
                    a.mean[j],
                    b.mean[j]
                );
                assert!((a.std[j] - b.std[j]).abs() <= 1e-9);
            }
        }
    }

    #[test]
    fn extend_retract_restores_state_bitwise() {
        let mut rng = Rng::new(8);
        let (x, y) = toy(&mut rng, 10, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand: Vec<Vec<f64>> = (0..8).map(|_| (0..3).map(|_| rng.f64()).collect()).collect();
        let before = gp.predict(&cand);
        let l_before = gp.l.clone();

        for _ in 0..3 {
            let f: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            assert!(gp.extend_fantasy(&f, 0.0));
        }
        assert_eq!(gp.total(), 13);
        assert_eq!(gp.len(), 10);
        gp.retract_fantasies();
        assert_eq!(gp.total(), 10);
        assert_eq!(gp.l.len(), l_before.len());
        for (a, b) in gp.l.iter().zip(&l_before) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let after = gp.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(before.mean[j].to_bits(), after.mean[j].to_bits());
            assert_eq!(before.std[j].to_bits(), after.std[j].to_bits());
        }
    }

    #[test]
    fn set_targets_reuses_factor() {
        let mut rng = Rng::new(9);
        let (x, y) = toy(&mut rng, 12, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand = vec![vec![0.4, 0.6]];
        let _ = gp.predict(&cand);
        // New targets: posterior must equal a scratch fit on (x, y2).
        let y2: Vec<f64> = y.iter().map(|v| v * 2.0 - 1.0).collect();
        gp.set_targets(&y2);
        let a = gp.predict(&cand);
        let b = NativeGp::fit(&x, &y2, GpHyper::default()).unwrap().predict(&cand);
        assert!((a.mean[0] - b.mean[0]).abs() <= 1e-9);
        assert!((a.std[0] - b.std[0]).abs() <= 1e-9);
    }

    #[test]
    fn rejects_non_pd_append_and_stays_usable() {
        let hyper = GpHyper { noise_var: 0.0, ..Default::default() };
        let mut gp = IncrementalGp::new(hyper);
        assert!(gp.push(&[0.5, 0.5], 1.0));
        // Exact duplicate with zero noise: not PD.
        assert!(!gp.push(&[0.5, 0.5], 2.0));
        assert_eq!(gp.len(), 1);
        let p = gp.predict(&[vec![0.5, 0.5]]);
        assert!((p.mean[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn score_gain_formula() {
        let mut rng = Rng::new(10);
        let (x, y) = toy(&mut rng, 6, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand: Vec<f64> = vec![0.2, 0.8, 0.9, 0.1];
        let mut ws = ScoreWorkspace::default();
        gp.score_into(&cand, 2, 1.5, 0.7, &mut ws);
        for j in 0..2 {
            let want = (ws.mean[j] + 1.5 * ws.std[j]) - 0.7;
            assert_eq!(ws.gain[j].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn multi_pass_matches_independent_models_bitwise() {
        // One factor, two target columns: the panel pass must reproduce
        // two independent single-objective models (same X, same hypers)
        // bit for bit — mean per objective, shared std.
        let mut rng = Rng::new(21);
        let (x, y0) = toy(&mut rng, 18, 4);
        let y1: Vec<f64> = x.iter().map(|p| p[1] - 0.4 * p[2]).collect();
        let hyper = GpHyper::default();
        let mut joint = build(&x, &y0, hyper);
        let l_before = joint.l.clone();

        let cand: Vec<f64> = (0..12 * 4).map(|_| rng.f64()).collect();
        let mut ws = ScoreWorkspace::default();
        joint.score_multi_into(&cand, 12, &[&y0, &y1], &mut ws);
        assert_eq!(ws.n_obj, 2);

        for (k, yk) in [&y0, &y1].into_iter().enumerate() {
            let mut solo = build(&x, yk, hyper);
            let mut ws_solo = ScoreWorkspace::default();
            solo.score_into(&cand, 12, 1.5, 0.0, &mut ws_solo);
            for j in 0..12 {
                assert_eq!(
                    ws.mean_obj[k * 12 + j].to_bits(),
                    ws_solo.mean[j].to_bits(),
                    "objective {k} mean diverged at candidate {j}"
                );
                assert_eq!(ws.std[j].to_bits(), ws_solo.std[j].to_bits());
            }
        }
        // Primary mirror and an untouched factor (no refit happened).
        for j in 0..12 {
            assert_eq!(ws.mean[j].to_bits(), ws.mean_obj[j].to_bits());
        }
        assert_eq!(joint.l.len(), l_before.len());
        for (a, b) in joint.l.iter().zip(&l_before) {
            assert_eq!(a.to_bits(), b.to_bits(), "multi pass must not touch the factor");
        }
    }

    #[test]
    fn solve_alpha_matches_installed_targets() {
        let mut rng = Rng::new(22);
        let (x, y) = toy(&mut rng, 9, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        let y2: Vec<f64> = y.iter().map(|v| 1.0 - v).collect();
        let mut out = Vec::new();
        gp.solve_alpha(&y2, &mut out);
        gp.set_targets(&y2);
        gp.refresh_alpha();
        assert_eq!(out.len(), gp.alpha.len());
        for (a, b) in out.iter().zip(&gp.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn factor_suffix_import_matches_push_bitwise() {
        // A replica that imports exported factor rows must be bit-equal to
        // one that recomputed every append itself.
        let mut rng = Rng::new(11);
        let (x, y) = toy(&mut rng, 14, 3);
        let hyper = GpHyper::default();
        let authoritative = build(&x, &y, hyper);

        let split = 9usize;
        let mut replica = build(&x[..split], &y[..split], hyper);
        let suffix = authoritative.factor_suffix(split);
        assert_eq!(
            suffix.len(),
            crate::util::linalg::packed_len(14) - crate::util::linalg::packed_len(split)
        );
        let mut off = 0;
        for (k, (xi, &yi)) in x[split..].iter().zip(&y[split..]).enumerate() {
            let m = split + k;
            assert!(replica.import_row(xi, yi, &suffix[off..off + m + 1]));
            off += m + 1;
        }
        assert_eq!(off, suffix.len());
        assert_eq!(replica.total(), 14);

        let cand: Vec<Vec<f64>> =
            (0..6).map(|_| (0..3).map(|_| rng.f64()).collect()).collect();
        let mut a = authoritative;
        let pa = a.predict(&cand);
        let pb = replica.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(pa.mean[j].to_bits(), pb.mean[j].to_bits());
            assert_eq!(pa.std[j].to_bits(), pb.std[j].to_bits());
        }
    }

    #[test]
    fn import_rejects_bad_diagonal() {
        let mut gp = IncrementalGp::new(GpHyper::default());
        assert!(gp.push(&[0.2, 0.4], 1.0));
        assert!(!gp.import_row(&[0.6, 0.1], 0.5, &[0.3, 0.0]));
        assert!(!gp.import_row(&[0.6, 0.1], 0.5, &[0.3, f64::NAN]));
        assert_eq!(gp.total(), 1, "rejected import must leave the model unchanged");
    }

    #[test]
    fn clear_then_reuse() {
        let mut gp = IncrementalGp::new(GpHyper::default());
        assert!(gp.push(&[0.1], 0.0));
        gp.clear();
        assert!(gp.is_empty());
        // Dimension can change after clear.
        assert!(gp.push(&[0.1, 0.2, 0.3], 1.0));
        assert_eq!(gp.total(), 1);
    }

    #[test]
    fn parallel_scoring_bitwise_matches_serial() {
        // The fixed-partition determinism contract, at module scope: any
        // thread count (including counts exceeding the pool) reproduces
        // the serial sweep bit for bit. The full {threads}×{pool} matrix
        // lives in rust/tests/scoring_engine.rs.
        let mut rng = Rng::new(31);
        let (x, y) = toy(&mut rng, 20, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        let c = 37;
        let cand: Vec<f64> = (0..c * 3).map(|_| rng.f64()).collect();
        let mut ws_serial = ScoreWorkspace::default();
        gp.score_into(&cand, c, 1.5, 0.2, &mut ws_serial);
        for threads in [2, 3, 64] {
            gp.set_score_threads(threads);
            let mut ws = ScoreWorkspace::default();
            gp.score_into(&cand, c, 1.5, 0.2, &mut ws);
            for j in 0..c {
                assert_eq!(ws.mean[j].to_bits(), ws_serial.mean[j].to_bits(), "t={threads} j={j}");
                assert_eq!(ws.std[j].to_bits(), ws_serial.std[j].to_bits(), "t={threads} j={j}");
                assert_eq!(ws.gain[j].to_bits(), ws_serial.gain[j].to_bits(), "t={threads} j={j}");
            }
        }
    }

    #[test]
    fn block_spec_bitwise_invariant_end_to_end() {
        let mut rng = Rng::new(32);
        let (x, y) = toy(&mut rng, 15, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let c = 21;
        let cand: Vec<f64> = (0..c * 2).map(|_| rng.f64()).collect();
        let mut want = ScoreWorkspace::default();
        gp.set_block_spec(BlockSpec::naive());
        gp.score_into(&cand, c, 1.0, 0.0, &mut want);
        for spec in [BlockSpec { mc: 3, nc: 5, kc: 4 }, BlockSpec::default()] {
            gp.set_block_spec(spec);
            let mut got = ScoreWorkspace::default();
            gp.score_into(&cand, c, 1.0, 0.0, &mut got);
            for j in 0..c {
                assert_eq!(got.mean[j].to_bits(), want.mean[j].to_bits(), "{spec:?}");
                assert_eq!(got.std[j].to_bits(), want.std[j].to_bits(), "{spec:?}");
            }
        }
    }

    #[test]
    fn f32_tier_is_opt_in_and_tracks_f64() {
        let mut rng = Rng::new(33);
        let (x, y) = toy(&mut rng, 16, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        assert_eq!(gp.score_tier(), ScoreTier::F64, "f64 must be the default tier");
        let c = 11;
        let cand: Vec<f64> = (0..c * 3).map(|_| rng.f64()).collect();
        let mut exact = ScoreWorkspace::default();
        gp.score_into(&cand, c, 1.5, 0.0, &mut exact);
        gp.set_score_tier(ScoreTier::F32);
        for threads in [1, 3] {
            gp.set_score_threads(threads);
            let mut fast = ScoreWorkspace::default();
            gp.score_into(&cand, c, 1.5, 0.0, &mut fast);
            for j in 0..c {
                assert!(
                    (fast.mean[j] - exact.mean[j]).abs() < 1e-3,
                    "t={threads} j={j}: f32 mean {} vs f64 {}",
                    fast.mean[j],
                    exact.mean[j]
                );
                assert!((fast.std[j] - exact.std[j]).abs() < 1e-3);
            }
        }
        assert_eq!(ScoreTier::parse("f32"), Some(ScoreTier::F32));
        assert_eq!(ScoreTier::parse("exact"), Some(ScoreTier::F64));
        assert_eq!(ScoreTier::parse("bogus"), None);
    }

    #[test]
    fn multi_objective_parallel_bitwise_matches_serial() {
        let mut rng = Rng::new(34);
        let (x, y0) = toy(&mut rng, 14, 3);
        let y1: Vec<f64> = x.iter().map(|p| p[2] - p[0]).collect();
        let mut gp = build(&x, &y0, GpHyper::default());
        let c = 19;
        let cand: Vec<f64> = (0..c * 3).map(|_| rng.f64()).collect();
        let mut serial = ScoreWorkspace::default();
        gp.score_multi_into(&cand, c, &[&y0, &y1], &mut serial);
        gp.set_score_threads(4);
        let mut par = ScoreWorkspace::default();
        gp.score_multi_into(&cand, c, &[&y0, &y1], &mut par);
        assert_eq!(par.n_obj, 2);
        for k in 0..2 {
            for j in 0..c {
                assert_eq!(
                    par.mean_obj[k * c + j].to_bits(),
                    serial.mean_obj[k * c + j].to_bits(),
                    "objective {k} candidate {j}"
                );
            }
        }
        for j in 0..c {
            assert_eq!(par.std[j].to_bits(), serial.std[j].to_bits());
            assert_eq!(par.mean[j].to_bits(), serial.mean[j].to_bits());
        }
    }

    #[test]
    fn predict_reuses_workspace_and_stays_deterministic() {
        let mut rng = Rng::new(35);
        let (x, y) = toy(&mut rng, 12, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand: Vec<Vec<f64>> = (0..9).map(|_| (0..2).map(|_| rng.f64()).collect()).collect();
        let first = gp.predict(&cand);
        let caps = gp.predict_ws.heap_capacities();
        let flat_cap = gp.predict_flat.capacity();
        for _ in 0..5 {
            let again = gp.predict(&cand);
            for j in 0..cand.len() {
                assert_eq!(first.mean[j].to_bits(), again.mean[j].to_bits());
                assert_eq!(first.std[j].to_bits(), again.std[j].to_bits());
            }
        }
        assert_eq!(caps, gp.predict_ws.heap_capacities(), "predict must reuse its workspace");
        assert_eq!(flat_cap, gp.predict_flat.capacity());
    }
}
