//! Incremental Gaussian process: a persistent Cholesky factor with O(n²)
//! rank-1 appends, cheap constant-liar *extend/retract*, and a
//! zero-allocation blocked scoring path.
//!
//! Role in the surrogate subsystem: this is the model the BO engine keeps
//! alive across the whole tuning run. [`IncrementalGp::push`] folds a new
//! observation into the factor in O(n²) (vs the oracle's O(n³) refit);
//! [`IncrementalGp::extend_fantasy`] conditions on an in-flight trial the
//! same way and [`IncrementalGp::retract_fantasies`] truncates the factor
//! back — fantasies are pure appends, so retracting is exact (bitwise)
//! state restoration, not an approximate downdate.
//!
//! Scoring ([`IncrementalGp::score_into`]) builds the cross-kernel panel
//! `Kc` row-blocked in a caller-owned [`ScoreWorkspace`], forms the
//! posterior mean as one panel·α accumulation, and the variance through a
//! single multi-RHS [`trsm_lower_packed`] — one blocked pass over the
//! whole candidate pool instead of a per-candidate fit/solve, with zero
//! heap allocation once the workspace has warmed up.
//!
//! Numerical contract: every routine performs the same floating-point
//! operations in the same order as the exact oracle (`gp::native`), so an
//! incrementally grown posterior is bit-equal to a from-scratch
//! [`NativeGp::fit`](super::NativeGp::fit) on the same data. The
//! `surrogate_incremental` integration suite pins this; keep operation
//! order intact when editing.

use super::kernel::{eval_sqdist, GpHyper};
use super::native::Posterior;
use crate::util::linalg::{
    chol_append_packed, packed_len, solve_lower_packed_inplace, solve_lower_t_packed_inplace,
    sqdist, trsm_lower_packed,
};

/// Reusable buffers for the scoring hot path. Own one per engine and pass
/// it to every [`IncrementalGp::score_into`] call; after the first call at
/// a given (history, candidates) shape, scoring allocates nothing.
#[derive(Debug, Default)]
pub struct ScoreWorkspace {
    /// n×c cross-kernel panel; overwritten by L⁻¹Kc during scoring.
    panel: Vec<f64>,
    /// Posterior mean per candidate (primary objective).
    pub mean: Vec<f64>,
    /// Posterior stddev per candidate.
    pub std: Vec<f64>,
    /// Acquisition gain per candidate.
    pub gain: Vec<f64>,
    /// Scratch index order (filled by [`ScoreWorkspace::argsort_gain_desc`]).
    pub order: Vec<usize>,
    /// K×c posterior means of a multi-objective panel pass
    /// ([`IncrementalGp::score_multi_into`]): objective `k`'s mean at
    /// candidate `j` lives at `k * c + j`. The posterior *std* is shared
    /// across objectives (it depends only on X and the kernel) and stays
    /// in [`ScoreWorkspace::std`].
    pub mean_obj: Vec<f64>,
    /// Objective count of the last multi-objective pass (0 = none).
    pub n_obj: usize,
    /// K×n per-objective α = K⁻¹y scratch for the multi pass.
    alpha_obj: Vec<f64>,
}

impl ScoreWorkspace {
    /// Fill `order` with candidate indices sorted by descending gain and
    /// return it. Reuses the buffer — no allocation once warmed up.
    pub fn argsort_gain_desc(&mut self) -> &[usize] {
        self.order.clear();
        self.order.extend(0..self.gain.len());
        let gain = &self.gain;
        // total_cmp: panic-free and deterministic even for NaN gains.
        self.order.sort_by(|&a, &b| gain[b].total_cmp(&gain[a]));
        &self.order
    }
}

/// A fitted GP whose factor grows in place.
///
/// Targets are mutable separately from inputs ([`IncrementalGp::set_targets`]):
/// the Cholesky factor depends only on X, so the engine can restandardise
/// y every iteration and pay two O(n²) triangular solves, not a refit.
#[derive(Debug)]
pub struct IncrementalGp {
    hyper: GpHyper,
    /// Feature dimension; fixed by the first appended row.
    d: usize,
    /// Committed (real) observations; rows beyond this are fantasies.
    committed: usize,
    /// Row-major (total×d) inputs.
    x: Vec<f64>,
    /// Targets, one per row (fantasies carry their lie value).
    y: Vec<f64>,
    /// Packed-lower Cholesky factor of K + σₙ²I over all rows.
    l: Vec<f64>,
    /// α = K⁻¹y for the current targets (valid iff !alpha_dirty).
    alpha: Vec<f64>,
    alpha_dirty: bool,
    /// Scratch for new-row covariances (capacity-reserved).
    kbuf: Vec<f64>,
}

impl IncrementalGp {
    pub fn new(hyper: GpHyper) -> IncrementalGp {
        // Reservation hint only: an unbounded window (UNBOUNDED_HISTORY =
        // usize::MAX) must not translate into a usize::MAX reservation.
        let cap = hyper.max_history.clamp(1, 1024);
        IncrementalGp {
            hyper,
            d: 0,
            committed: 0,
            x: Vec::new(),
            y: Vec::with_capacity(cap),
            l: Vec::with_capacity(packed_len(cap)),
            alpha: Vec::with_capacity(cap),
            alpha_dirty: true,
            kbuf: Vec::with_capacity(cap),
        }
    }

    pub fn hyper(&self) -> GpHyper {
        self.hyper
    }

    /// Change hyperparameters. The factor is kernel-dependent, so this
    /// clears the model; the caller re-pushes its conditioning set.
    pub fn set_hyper(&mut self, hyper: GpHyper) {
        self.hyper = hyper;
        self.clear();
    }

    /// Committed (non-fantasy) observations.
    pub fn len(&self) -> usize {
        self.committed
    }

    pub fn is_empty(&self) -> bool {
        self.committed == 0
    }

    /// Committed + fantasy rows currently factored in.
    pub fn total(&self) -> usize {
        self.y.len()
    }

    pub fn clear(&mut self) {
        self.committed = 0;
        self.x.clear();
        self.y.clear();
        self.l.clear();
        self.alpha.clear();
        self.alpha_dirty = true;
    }

    /// Rank-1 append of one row (O(total²)). Returns false — leaving the
    /// model unchanged — if the extended kernel matrix is not PD (only
    /// possible with zero/negative noise and duplicate points).
    fn append_row(&mut self, xr: &[f64], yv: f64) -> bool {
        let m = self.total();
        if m == 0 {
            self.d = xr.len();
            assert!(self.d > 0, "empty feature vector");
            self.x.reserve(self.hyper.max_history.clamp(1, 1024) * self.d);
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        self.kbuf.clear();
        for i in 0..m {
            let xi = &self.x[i * self.d..(i + 1) * self.d];
            self.kbuf.push(eval_sqdist(self.hyper.kernel, sqdist(xr, xi), &self.hyper));
        }
        let diag = self.hyper.signal_var + self.hyper.noise_var;
        // Split borrows: chol_append_packed mutates l and kbuf only.
        let IncrementalGp { l, kbuf, .. } = self;
        if !chol_append_packed(l, m, kbuf, diag) {
            return false;
        }
        self.x.extend_from_slice(xr);
        self.y.push(yv);
        self.alpha_dirty = true;
        true
    }

    /// Append a committed observation.
    pub fn push(&mut self, xr: &[f64], yv: f64) -> bool {
        debug_assert_eq!(
            self.committed,
            self.total(),
            "push with fantasies in place; retract first"
        );
        if !self.append_row(xr, yv) {
            return false;
        }
        self.committed += 1;
        true
    }

    /// Condition on an in-flight trial (constant liar): identical math to
    /// [`IncrementalGp::push`], but the row is dropped again by
    /// [`IncrementalGp::retract_fantasies`].
    pub fn extend_fantasy(&mut self, xr: &[f64], lie: f64) -> bool {
        self.append_row(xr, lie)
    }

    /// The packed Cholesky rows `from..total`, concatenated — the suffix a
    /// replica needs to catch up after `total - from` appends. Row `m`
    /// contributes `m + 1` entries, so the slice holds
    /// `packed_len(total) - packed_len(from)` values. Appends never modify
    /// earlier factor entries, which is exactly why a suffix transfer is
    /// sound: the replica's prefix is already bit-identical.
    pub fn factor_suffix(&self, from: usize) -> &[f64] {
        assert!(from <= self.total(), "suffix start {from} past factor end");
        &self.l[packed_len(from)..]
    }

    /// Append a committed row whose packed factor row was computed
    /// elsewhere (the authoritative factor of a surrogate service) — the
    /// O(n) import counterpart of the O(n²) [`IncrementalGp::push`].
    /// `lrow` must be the `total() + 1` packed entries of the next factor
    /// row, produced by the same kernel/hyper/row-order as this model.
    /// Returns false (model unchanged) on a non-positive diagonal.
    pub fn import_row(&mut self, xr: &[f64], yv: f64, lrow: &[f64]) -> bool {
        let m = self.total();
        debug_assert_eq!(self.committed, m, "import with fantasies in place; retract first");
        if m == 0 {
            self.d = xr.len();
            assert!(self.d > 0, "empty feature vector");
            self.x.reserve(self.hyper.max_history.clamp(1, 1024) * self.d);
        }
        assert_eq!(xr.len(), self.d, "feature dim mismatch");
        assert_eq!(lrow.len(), m + 1, "factor row length mismatch");
        let diag = lrow[m];
        if !(diag.is_finite() && diag > 0.0) {
            return false;
        }
        self.l.extend_from_slice(lrow);
        self.x.extend_from_slice(xr);
        self.y.push(yv);
        self.committed += 1;
        self.alpha_dirty = true;
        true
    }

    /// Drop all fantasy rows, restoring the exact pre-extend state: the
    /// factor is truncated (appends never modify earlier entries), so no
    /// numerical downdate is involved.
    pub fn retract_fantasies(&mut self) {
        let m = self.committed;
        if self.total() == m {
            return;
        }
        self.x.truncate(m * self.d);
        self.y.truncate(m);
        self.l.truncate(packed_len(m));
        self.alpha_dirty = true;
    }

    /// Replace the targets of every current row (committed + fantasies).
    /// O(1) when unchanged; otherwise α is lazily recomputed on the next
    /// score from the persistent factor (two O(n²) triangular solves).
    pub fn set_targets(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.total(), "target length mismatch");
        if self.y == y {
            return;
        }
        self.y.clear();
        self.y.extend_from_slice(y);
        self.alpha_dirty = true;
    }

    fn refresh_alpha(&mut self) {
        if !self.alpha_dirty {
            return;
        }
        let m = self.total();
        self.alpha.clear();
        self.alpha.extend_from_slice(&self.y);
        solve_lower_packed_inplace(&self.l, m, &mut self.alpha);
        solve_lower_t_packed_inplace(&self.l, m, &mut self.alpha);
        self.alpha_dirty = false;
    }

    /// Score `c` candidates (row-major c×d in `cand`) into `ws`: posterior
    /// mean/std and the SMSego gain `(μ + acq_alpha·σ) − y_best`. Zero
    /// heap allocation once `ws` buffers have grown to shape.
    pub fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        let m = self.total();
        assert!(m > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        self.refresh_alpha();

        ws.panel.clear();
        ws.panel.resize(m * c, 0.0);
        ws.mean.clear();
        ws.mean.resize(c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);

        // Cross-kernel panel: row i holds k(xᵢ, ·) over the whole pool.
        for i in 0..m {
            let xi = &self.x[i * self.d..(i + 1) * self.d];
            let row = &mut ws.panel[i * c..(i + 1) * c];
            for (j, kij) in row.iter_mut().enumerate() {
                let cj = &cand[j * self.d..(j + 1) * self.d];
                *kij = eval_sqdist(self.hyper.kernel, sqdist(xi, cj), &self.hyper);
            }
        }

        // μ = Kcᵀα, accumulated panel-row-wise (ascending i, matching the
        // oracle's per-candidate dot-product order).
        for i in 0..m {
            let a = self.alpha[i];
            let row = &ws.panel[i * c..(i + 1) * c];
            for (mu, kij) in ws.mean.iter_mut().zip(row) {
                *mu += kij * a;
            }
        }

        // V = L⁻¹Kc in one blocked sweep, then σ² = k(x,x) − Σᵢ Vᵢⱼ².
        trsm_lower_packed(&self.l, m, &mut ws.panel, c);
        for i in 0..m {
            let row = &ws.panel[i * c..(i + 1) * c];
            for (acc, v) in ws.std.iter_mut().zip(row) {
                *acc += v * v;
            }
        }
        for j in 0..c {
            let var = self.hyper.signal_var - ws.std[j];
            ws.std[j] = var.max(1e-12).sqrt();
            ws.gain[j] = (ws.mean[j] + acq_alpha * ws.std[j]) - y_best;
        }
    }

    /// Solve `out = (K + σₙ²I)⁻¹ y` against the current factor without
    /// touching model state — the per-objective α of a multi-objective
    /// panel pass. Performs exactly the two triangular solves
    /// [`IncrementalGp::set_targets`] + scoring would perform for the
    /// same targets, in the same order, so a K-objective pass is
    /// bit-equal to K independent single-objective models sharing this
    /// factor.
    pub fn solve_alpha(&self, y: &[f64], out: &mut Vec<f64>) {
        let m = self.total();
        assert_eq!(y.len(), m, "target length mismatch");
        out.clear();
        out.extend_from_slice(y);
        solve_lower_packed_inplace(&self.l, m, out);
        solve_lower_t_packed_inplace(&self.l, m, out);
    }

    /// Score `c` candidates against **K objectives in one blocked panel
    /// pass**: the cross-kernel panel and the variance triangular solve
    /// are computed once (they depend only on X), and each objective
    /// contributes one α solve plus one panel·α accumulation. Mean of
    /// objective `k` lands in `ws.mean_obj[k*c..(k+1)*c]`; the shared
    /// posterior std in `ws.std`; `ws.mean` mirrors the primary
    /// objective (`targets[0]`). `ws.gain` is resized and zeroed — the
    /// caller's acquisition (scalarised or hypervolume gain) fills it.
    ///
    /// `targets` are per-objective target vectors over every current row
    /// (committed + fantasies, standardised by the caller; fantasy rows
    /// carry their per-objective lies). The factor is read, never
    /// modified: K objectives cost K panel accumulations over one
    /// factor, not K refits.
    pub fn score_multi_into(
        &mut self,
        cand: &[f64],
        c: usize,
        targets: &[&[f64]],
        ws: &mut ScoreWorkspace,
    ) {
        let m = self.total();
        assert!(m > 0, "cannot score on an empty model");
        assert_eq!(cand.len(), c * self.d, "candidate shape mismatch");
        let k_obj = targets.len();
        assert!(k_obj > 0, "need at least one objective");
        for t in targets {
            assert_eq!(t.len(), m, "target length mismatch");
        }

        // Per-objective α against the shared factor (no state touched;
        // the same two solves `solve_alpha` performs, into ws scratch so
        // a warmed-up pass allocates nothing).
        ws.alpha_obj.clear();
        ws.alpha_obj.reserve(k_obj * m);
        for t in targets {
            let start = ws.alpha_obj.len();
            ws.alpha_obj.extend_from_slice(t);
            let col = &mut ws.alpha_obj[start..];
            solve_lower_packed_inplace(&self.l, m, col);
            solve_lower_t_packed_inplace(&self.l, m, col);
        }

        ws.n_obj = k_obj;
        ws.panel.clear();
        ws.panel.resize(m * c, 0.0);
        ws.mean_obj.clear();
        ws.mean_obj.resize(k_obj * c, 0.0);
        ws.std.clear();
        ws.std.resize(c, 0.0);
        ws.gain.clear();
        ws.gain.resize(c, 0.0);

        // Cross-kernel panel, built once (identical loop to score_into).
        for i in 0..m {
            let xi = &self.x[i * self.d..(i + 1) * self.d];
            let row = &mut ws.panel[i * c..(i + 1) * c];
            for (j, kij) in row.iter_mut().enumerate() {
                let cj = &cand[j * self.d..(j + 1) * self.d];
                *kij = eval_sqdist(self.hyper.kernel, sqdist(xi, cj), &self.hyper);
            }
        }

        // μ_k = Kcᵀα_k, panel-row-wise per objective (ascending i — the
        // same accumulation order a single-objective pass performs).
        for k in 0..k_obj {
            let alpha = &ws.alpha_obj[k * m..(k + 1) * m];
            let mean = &mut ws.mean_obj[k * c..(k + 1) * c];
            for i in 0..m {
                let a = alpha[i];
                let row = &ws.panel[i * c..(i + 1) * c];
                for (mu, kij) in mean.iter_mut().zip(row) {
                    *mu += kij * a;
                }
            }
        }

        // V = L⁻¹Kc once; σ is objective-independent.
        trsm_lower_packed(&self.l, m, &mut ws.panel, c);
        for i in 0..m {
            let row = &ws.panel[i * c..(i + 1) * c];
            for (acc, v) in ws.std.iter_mut().zip(row) {
                *acc += v * v;
            }
        }
        for j in 0..c {
            let var = self.hyper.signal_var - ws.std[j];
            ws.std[j] = var.max(1e-12).sqrt();
        }

        // Mirror the primary objective into the single-objective slot.
        ws.mean.clear();
        ws.mean.extend_from_slice(&ws.mean_obj[..c]);
    }

    /// Allocating convenience wrapper over [`IncrementalGp::score_into`]
    /// for tests and oracle comparisons.
    pub fn predict(&mut self, cand: &[Vec<f64>]) -> Posterior {
        let mut flat = Vec::with_capacity(cand.len() * self.d);
        for row in cand {
            assert_eq!(row.len(), self.d, "candidate dim mismatch");
            flat.extend_from_slice(row);
        }
        let mut ws = ScoreWorkspace::default();
        self.score_into(&flat, cand.len(), 0.0, 0.0, &mut ws);
        Posterior { mean: ws.mean, std: ws.std }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{KernelKind, NativeGp};
    use crate::util::Rng;

    fn toy(rng: &mut Rng, n: usize, d: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x.iter().map(|p| (5.0 * p[0]).sin() + 0.3 * p[d - 1]).collect();
        (x, y)
    }

    fn build(x: &[Vec<f64>], y: &[f64], hyper: GpHyper) -> IncrementalGp {
        let mut gp = IncrementalGp::new(hyper);
        for (xi, &yi) in x.iter().zip(y) {
            assert!(gp.push(xi, yi), "append failed");
        }
        gp
    }

    #[test]
    fn matches_scratch_oracle_both_kernels() {
        let mut rng = Rng::new(7);
        for kind in KernelKind::all() {
            let hyper = GpHyper { kernel: kind, ..Default::default() };
            let (x, y) = toy(&mut rng, 24, 4);
            let mut inc = build(&x, &y, hyper);
            let oracle = NativeGp::fit(&x, &y, hyper).unwrap();
            let cand: Vec<Vec<f64>> =
                (0..16).map(|_| (0..4).map(|_| rng.f64()).collect()).collect();
            let a = inc.predict(&cand);
            let b = oracle.predict(&cand);
            for j in 0..cand.len() {
                assert!(
                    (a.mean[j] - b.mean[j]).abs() <= 1e-9,
                    "{}: mean {} vs {}",
                    kind.name(),
                    a.mean[j],
                    b.mean[j]
                );
                assert!((a.std[j] - b.std[j]).abs() <= 1e-9);
            }
        }
    }

    #[test]
    fn extend_retract_restores_state_bitwise() {
        let mut rng = Rng::new(8);
        let (x, y) = toy(&mut rng, 10, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand: Vec<Vec<f64>> = (0..8).map(|_| (0..3).map(|_| rng.f64()).collect()).collect();
        let before = gp.predict(&cand);
        let l_before = gp.l.clone();

        for _ in 0..3 {
            let f: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            assert!(gp.extend_fantasy(&f, 0.0));
        }
        assert_eq!(gp.total(), 13);
        assert_eq!(gp.len(), 10);
        gp.retract_fantasies();
        assert_eq!(gp.total(), 10);
        assert_eq!(gp.l.len(), l_before.len());
        for (a, b) in gp.l.iter().zip(&l_before) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let after = gp.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(before.mean[j].to_bits(), after.mean[j].to_bits());
            assert_eq!(before.std[j].to_bits(), after.std[j].to_bits());
        }
    }

    #[test]
    fn set_targets_reuses_factor() {
        let mut rng = Rng::new(9);
        let (x, y) = toy(&mut rng, 12, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand = vec![vec![0.4, 0.6]];
        let _ = gp.predict(&cand);
        // New targets: posterior must equal a scratch fit on (x, y2).
        let y2: Vec<f64> = y.iter().map(|v| v * 2.0 - 1.0).collect();
        gp.set_targets(&y2);
        let a = gp.predict(&cand);
        let b = NativeGp::fit(&x, &y2, GpHyper::default()).unwrap().predict(&cand);
        assert!((a.mean[0] - b.mean[0]).abs() <= 1e-9);
        assert!((a.std[0] - b.std[0]).abs() <= 1e-9);
    }

    #[test]
    fn rejects_non_pd_append_and_stays_usable() {
        let hyper = GpHyper { noise_var: 0.0, ..Default::default() };
        let mut gp = IncrementalGp::new(hyper);
        assert!(gp.push(&[0.5, 0.5], 1.0));
        // Exact duplicate with zero noise: not PD.
        assert!(!gp.push(&[0.5, 0.5], 2.0));
        assert_eq!(gp.len(), 1);
        let p = gp.predict(&[vec![0.5, 0.5]]);
        assert!((p.mean[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn score_gain_formula() {
        let mut rng = Rng::new(10);
        let (x, y) = toy(&mut rng, 6, 2);
        let mut gp = build(&x, &y, GpHyper::default());
        let cand: Vec<f64> = vec![0.2, 0.8, 0.9, 0.1];
        let mut ws = ScoreWorkspace::default();
        gp.score_into(&cand, 2, 1.5, 0.7, &mut ws);
        for j in 0..2 {
            let want = (ws.mean[j] + 1.5 * ws.std[j]) - 0.7;
            assert_eq!(ws.gain[j].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn multi_pass_matches_independent_models_bitwise() {
        // One factor, two target columns: the panel pass must reproduce
        // two independent single-objective models (same X, same hypers)
        // bit for bit — mean per objective, shared std.
        let mut rng = Rng::new(21);
        let (x, y0) = toy(&mut rng, 18, 4);
        let y1: Vec<f64> = x.iter().map(|p| p[1] - 0.4 * p[2]).collect();
        let hyper = GpHyper::default();
        let mut joint = build(&x, &y0, hyper);
        let l_before = joint.l.clone();

        let cand: Vec<f64> = (0..12 * 4).map(|_| rng.f64()).collect();
        let mut ws = ScoreWorkspace::default();
        joint.score_multi_into(&cand, 12, &[&y0, &y1], &mut ws);
        assert_eq!(ws.n_obj, 2);

        for (k, yk) in [&y0, &y1].into_iter().enumerate() {
            let mut solo = build(&x, yk, hyper);
            let mut ws_solo = ScoreWorkspace::default();
            solo.score_into(&cand, 12, 1.5, 0.0, &mut ws_solo);
            for j in 0..12 {
                assert_eq!(
                    ws.mean_obj[k * 12 + j].to_bits(),
                    ws_solo.mean[j].to_bits(),
                    "objective {k} mean diverged at candidate {j}"
                );
                assert_eq!(ws.std[j].to_bits(), ws_solo.std[j].to_bits());
            }
        }
        // Primary mirror and an untouched factor (no refit happened).
        for j in 0..12 {
            assert_eq!(ws.mean[j].to_bits(), ws.mean_obj[j].to_bits());
        }
        assert_eq!(joint.l.len(), l_before.len());
        for (a, b) in joint.l.iter().zip(&l_before) {
            assert_eq!(a.to_bits(), b.to_bits(), "multi pass must not touch the factor");
        }
    }

    #[test]
    fn solve_alpha_matches_installed_targets() {
        let mut rng = Rng::new(22);
        let (x, y) = toy(&mut rng, 9, 3);
        let mut gp = build(&x, &y, GpHyper::default());
        let y2: Vec<f64> = y.iter().map(|v| 1.0 - v).collect();
        let mut out = Vec::new();
        gp.solve_alpha(&y2, &mut out);
        gp.set_targets(&y2);
        gp.refresh_alpha();
        assert_eq!(out.len(), gp.alpha.len());
        for (a, b) in out.iter().zip(&gp.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn factor_suffix_import_matches_push_bitwise() {
        // A replica that imports exported factor rows must be bit-equal to
        // one that recomputed every append itself.
        let mut rng = Rng::new(11);
        let (x, y) = toy(&mut rng, 14, 3);
        let hyper = GpHyper::default();
        let authoritative = build(&x, &y, hyper);

        let split = 9usize;
        let mut replica = build(&x[..split], &y[..split], hyper);
        let suffix = authoritative.factor_suffix(split);
        assert_eq!(
            suffix.len(),
            crate::util::linalg::packed_len(14) - crate::util::linalg::packed_len(split)
        );
        let mut off = 0;
        for (k, (xi, &yi)) in x[split..].iter().zip(&y[split..]).enumerate() {
            let m = split + k;
            assert!(replica.import_row(xi, yi, &suffix[off..off + m + 1]));
            off += m + 1;
        }
        assert_eq!(off, suffix.len());
        assert_eq!(replica.total(), 14);

        let cand: Vec<Vec<f64>> =
            (0..6).map(|_| (0..3).map(|_| rng.f64()).collect()).collect();
        let mut a = authoritative;
        let pa = a.predict(&cand);
        let pb = replica.predict(&cand);
        for j in 0..cand.len() {
            assert_eq!(pa.mean[j].to_bits(), pb.mean[j].to_bits());
            assert_eq!(pa.std[j].to_bits(), pb.std[j].to_bits());
        }
    }

    #[test]
    fn import_rejects_bad_diagonal() {
        let mut gp = IncrementalGp::new(GpHyper::default());
        assert!(gp.push(&[0.2, 0.4], 1.0));
        assert!(!gp.import_row(&[0.6, 0.1], 0.5, &[0.3, 0.0]));
        assert!(!gp.import_row(&[0.6, 0.1], 0.5, &[0.3, f64::NAN]));
        assert_eq!(gp.total(), 1, "rejected import must leave the model unchanged");
    }

    #[test]
    fn clear_then_reuse() {
        let mut gp = IncrementalGp::new(GpHyper::default());
        assert!(gp.push(&[0.1], 0.0));
        gp.clear();
        assert!(gp.is_empty());
        // Dimension can change after clear.
        assert!(gp.push(&[0.1, 0.2, 0.3], 1.0));
        assert_eq!(gp.total(), 1);
    }
}
