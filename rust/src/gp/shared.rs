//! The shared concurrent surrogate: one [`IncrementalGp`] conditioning
//! measurements from *many* producers — the evaluator pool of a single
//! [`TuningSession`](crate::session::TuningSession), or several concurrent
//! sessions on one host (a [`SessionGroup`](crate::session::SessionGroup))
//! — behind a handle that any thread can `tell` into without blocking on
//! the engine's scoring pass.
//!
//! # Why a queue + lock, not just a lock
//!
//! The paper's practicality argument (and the regime "Learning to Optimize
//! Tensor Programs" exploits with its shared cost model) is that surrogate
//! cost amortises across many concurrent measurements. A naive
//! `Mutex<IncrementalGp>` would serialise *tells against asks*: a daemon
//! reporting a measurement would wait out a full candidate-pool scoring
//! pass. Instead the handle splits the two sides:
//!
//! - **tell side** ([`SharedSurrogate::tell`]): producers append `(x, y)`
//!   rows to a small queue behind its own mutex — O(1) critical section,
//!   never blocked by scoring. Any evaluator thread, session driver or
//!   daemon-reporting loop may call it concurrently.
//! - **ask side** ([`SharedSurrogate::lock`]): the BO engine takes the
//!   model lock, *drains* the queue in observation (enqueue) order —
//!   each drained row folds into the persistent Cholesky factor as an
//!   O(n²) rank-1 append — and gets a [`SurrogateGuard`]: exclusive,
//!   read-mostly access to the factored model for the duration of one
//!   proposal batch (sync, constant-liar fantasy extend, blocked scoring).
//!   Tells that arrive *while* the guard is held simply queue up and are
//!   folded in by the next `lock`.
//!
//! Lock order is always model-state → queue (the drain inside `lock`, and
//! [`SharedSurrogate::reset`]); `tell` takes only the queue lock, so the
//! two sides cannot deadlock and tells cannot be starved by asks.
//!
//! Scope note: the handle shares the *posterior*, not engine bookkeeping.
//! Each engine still deduplicates proposals against its own history and
//! conditions constant-liar fantasies for its own in-flight trials only,
//! so two sessions can occasionally measure the same configuration — a
//! duplicate (noisy) observation, which the factor handles fine, not an
//! error.
//!
//! # Numerical contract
//!
//! Draining performs exactly the rank-1 appends a private
//! [`IncrementalGp`] would perform if the same observations were told
//! serially in the same order, so a shared model is *bit-equal* to the
//! serial private-model path given the same observation order — and
//! within ~1e-12 of it under reordering (the GP posterior is permutation
//! invariant in exact arithmetic). `rust/tests/shared_surrogate.rs` pins
//! both to ≤1e-9 under genuine thread interleavings.
//!
//! # Example
//!
//! ```
//! use tftune::gp::{GpHyper, ScoreWorkspace, SharedSurrogate};
//!
//! let shared = SharedSurrogate::new(GpHyper::default());
//! // Producers (evaluator threads, daemons) tell without blocking:
//! let handle = shared.clone();
//! std::thread::spawn(move || handle.tell(vec![0.2, 0.7], 1.0)).join().unwrap();
//! shared.tell(vec![0.8, 0.1], -0.5);
//!
//! // The ask side drains the queue and scores through one guard:
//! let mut g = shared.lock();
//! assert_eq!(g.len(), 2);
//! let idx = g.conditioning_set();
//! assert!(g.sync(&idx));
//! g.set_targets(&[1.0, -0.5]);
//! let mut ws = ScoreWorkspace::default();
//! g.score_into(&[0.5, 0.5], 1, 1.5, 1.0, &mut ws);
//! assert!(ws.std[0] > 0.0);
//! ```

use std::sync::{Arc, Mutex, MutexGuard};

use super::incremental::{IncrementalGp, ScoreWorkspace};
use super::kernel::GpHyper;

/// Model state behind the ask-side lock: the canonical observation store
/// plus the persistent factor over (a windowed subset of) it.
struct SharedState {
    /// Hyperparameters every conditioning pass uses. Changing them
    /// invalidates the factor ([`SurrogateGuard::ensure_hyper`]).
    hyper: GpHyper,
    /// All drained observations, in drain (= enqueue) order. This is the
    /// canonical history the conditioning window selects from.
    obs_x: Vec<Vec<f64>>,
    obs_y: Vec<f64>,
    /// The persistent factored model.
    model: IncrementalGp,
    /// Indices into `obs_x` currently factored into `model`, in factor
    /// row order — decides between rank-1 append and rebuild on sync.
    factored: Vec<usize>,
    /// Eagerly fold drained rows into the factor (default). Engines that
    /// never score through the factor (HLO artifact, scratch reference —
    /// `Surrogate::use_engine_incremental()` false) disable this so
    /// drains stay O(1) bookkeeping.
    eager: bool,
    /// Spare row buffer swapped with the queue on drain, so the queue
    /// keeps its capacity and warmed-up tells never allocate.
    drain_buf: Vec<(Vec<f64>, f64)>,
}

impl SharedState {
    /// Fold one drained observation into the store, eagerly rank-1
    /// appending to the factor while it is still the full windowed prefix
    /// of the history (the cheap common case; anything else is repaired by
    /// the next [`SurrogateGuard::sync`]).
    fn drain_one(&mut self, x: Vec<f64>, y: f64) {
        let i = self.obs_x.len();
        if self.eager && i + 1 <= self.hyper.max_history && self.factored.len() == i {
            if self.model.push(&x, 0.0) {
                self.factored.push(i);
            } else {
                self.model.clear();
                self.factored.clear();
            }
        }
        self.obs_x.push(x);
        self.obs_y.push(y);
    }
}

struct Inner {
    /// Pending `(x, y)` appends, in tell order. Its own mutex so the tell
    /// side never contends with a scoring pass.
    queue: Mutex<Vec<(Vec<f64>, f64)>>,
    state: Mutex<SharedState>,
}

/// A cloneable handle to one concurrently-shared surrogate model (module
/// docs). Cloning is cheap (`Arc`); every clone addresses the same model.
pub struct SharedSurrogate {
    inner: Arc<Inner>,
}

impl Clone for SharedSurrogate {
    fn clone(&self) -> SharedSurrogate {
        SharedSurrogate { inner: Arc::clone(&self.inner) }
    }
}

impl std::fmt::Debug for SharedSurrogate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedSurrogate").finish_non_exhaustive()
    }
}

impl SharedSurrogate {
    /// A fresh, empty shared model conditioned with `hyper`.
    pub fn new(hyper: GpHyper) -> SharedSurrogate {
        SharedSurrogate {
            inner: Arc::new(Inner {
                queue: Mutex::new(Vec::new()),
                state: Mutex::new(SharedState {
                    hyper,
                    obs_x: Vec::new(),
                    obs_y: Vec::new(),
                    model: IncrementalGp::new(hyper),
                    factored: Vec::new(),
                    eager: true,
                    drain_buf: Vec::new(),
                }),
            }),
        }
    }

    /// Enqueue one observation (`x` in the unit cube, `y` the raw
    /// objective). Callable from any thread; never blocks on a scoring
    /// pass — the row is folded into the factor, in enqueue order, by the
    /// next [`SharedSurrogate::lock`].
    pub fn tell(&self, x: Vec<f64>, y: f64) {
        self.inner.queue.lock().unwrap().push((x, y));
    }

    /// Observations told but not yet drained into the model.
    pub fn pending(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Observations already drained into the canonical store. The next
    /// [`SharedSurrogate::lock`] may observe more (pending tells drain).
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().obs_x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drained + pending observations — the count the model will condition
    /// on once the queue is next drained.
    pub fn total_observations(&self) -> usize {
        // Lock order: state before queue (same as the drain in `lock`).
        let state = self.inner.state.lock().unwrap();
        let pending = self.inner.queue.lock().unwrap().len();
        state.obs_x.len() + pending
    }

    /// The hyperparameters the shared model currently conditions with.
    pub fn hyper(&self) -> GpHyper {
        self.inner.state.lock().unwrap().hyper
    }

    /// Switch hyperparameters, invalidating the factor (rebuilt by the
    /// next sync). Affects every engine sharing this handle.
    pub fn set_hyper(&self, hyper: GpHyper) {
        self.lock().ensure_hyper(hyper);
    }

    /// Enable/disable eager factoring on drain (default on). Turn it off
    /// when no attached engine scores through the factor — e.g. the HLO
    /// artifact or scratch-refit surrogate paths, which read only the
    /// observation store — so every drained row costs O(1), not an O(n²)
    /// rank-1 append. [`SurrogateGuard::sync`] still builds the factor on
    /// demand if someone asks for it.
    pub fn set_eager_factoring(&self, on: bool) {
        self.inner.state.lock().unwrap().eager = on;
    }

    /// Drop all observations (queued and drained) and clear the factor,
    /// keeping the hyperparameters — reuse one handle across runs.
    pub fn reset(&self) {
        let mut state = self.inner.state.lock().unwrap();
        self.inner.queue.lock().unwrap().clear();
        state.obs_x.clear();
        state.obs_y.clear();
        state.model.clear();
        state.factored.clear();
    }

    /// Take the ask-side lock: drain every pending tell into the factor
    /// (in enqueue order) and return exclusive access to the synced model.
    /// Concurrent `tell`s keep landing in the queue while the guard is
    /// held; they are folded in by the next `lock`.
    pub fn lock(&self) -> SurrogateGuard<'_> {
        let mut state = self.inner.state.lock().unwrap();
        // Defensive: a guard dropped mid-proposal (panic) may have left
        // fantasy rows; the factor must hold committed rows only before
        // new observations are appended.
        state.model.retract_fantasies();
        // Swap the queue with the spare buffer instead of mem::take, so
        // the queue keeps its capacity and tells stay allocation-free
        // once warmed up.
        let mut pending = std::mem::take(&mut state.drain_buf);
        std::mem::swap(&mut pending, &mut *self.inner.queue.lock().unwrap());
        for (x, y) in pending.drain(..) {
            state.drain_one(x, y);
        }
        state.drain_buf = pending;
        SurrogateGuard { state }
    }
}

/// Exclusive, drained view of the shared model for one proposal batch.
///
/// The guard exposes the canonical observation store (for conditioning-set
/// selection and target standardisation) and the incremental model's
/// sync / fantasy / scoring operations. Fantasy rows extended through the
/// guard are automatically retracted when it drops, so the factor between
/// asks always holds committed observations only.
pub struct SurrogateGuard<'a> {
    state: MutexGuard<'a, SharedState>,
}

impl SurrogateGuard<'_> {
    /// Observations in the canonical store (drain order).
    pub fn len(&self) -> usize {
        self.state.obs_x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.obs_x.is_empty()
    }

    /// Unit-cube coordinates of observation `i` (drain order).
    pub fn x(&self, i: usize) -> &[f64] {
        &self.state.obs_x[i]
    }

    /// Raw objective value of observation `i` (drain order).
    pub fn y(&self, i: usize) -> f64 {
        self.state.obs_y[i]
    }

    pub fn hyper(&self) -> GpHyper {
        self.state.hyper
    }

    /// Make the shared model condition with `hyper`; on change the factor
    /// is invalidated and rebuilt by the next [`SurrogateGuard::sync`].
    pub fn ensure_hyper(&mut self, hyper: GpHyper) {
        if self.state.hyper != hyper {
            self.state.hyper = hyper;
            self.state.model.set_hyper(hyper);
            self.state.factored.clear();
        }
    }

    /// The conditioning set over the canonical store: the full history if
    /// it fits the window, else the best window/4 observations plus the
    /// most recent remainder (ascending index order).
    pub fn conditioning_set(&self) -> Vec<usize> {
        let n = self.state.obs_y.len();
        let window = self.state.hyper.max_history;
        if n <= window {
            return (0..n).collect();
        }
        let keep_best = window / 4;
        let mut by_value: Vec<usize> = (0..n).collect();
        // total_cmp keeps the sort panic-free (and deterministic) even if
        // an evaluator ever reports a NaN measurement.
        let obs_y = &self.state.obs_y;
        by_value.sort_by(|&a, &b| obs_y[b].total_cmp(&obs_y[a]));
        let mut chosen: Vec<usize> = by_value[..keep_best].to_vec();
        for i in (0..n).rev() {
            if chosen.len() >= window {
                break;
            }
            if !chosen.contains(&i) {
                chosen.push(i);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    /// Grow (or rebuild) the factor to cover exactly the observations in
    /// `idx`, in order: rank-1 appends while `idx` extends the factored
    /// prefix, full rebuild on any reshape. Returns false — factor
    /// cleared — if the kernel matrix is not positive definite.
    pub fn sync(&mut self, idx: &[usize]) -> bool {
        let st = &mut *self.state;
        let keep =
            st.factored.len() <= idx.len() && st.factored.iter().zip(idx).all(|(a, b)| a == b);
        if !keep {
            st.model.clear();
            st.factored.clear();
        }
        let start = st.factored.len();
        for &i in &idx[start..] {
            if !st.model.push(&st.obs_x[i], 0.0) {
                st.model.clear();
                st.factored.clear();
                return false;
            }
            st.factored.push(i);
        }
        true
    }

    /// Replace the targets of every factored row (see
    /// [`IncrementalGp::set_targets`]). Length must equal
    /// [`SurrogateGuard::total`].
    pub fn set_targets(&mut self, y: &[f64]) {
        self.state.model.set_targets(y);
    }

    /// Committed + fantasy rows currently factored in.
    pub fn total(&self) -> usize {
        self.state.model.total()
    }

    /// Condition on an in-flight trial (constant liar). Retracted
    /// automatically when the guard drops.
    pub fn extend_fantasy(&mut self, x: &[f64], lie: f64) -> bool {
        self.state.model.extend_fantasy(x, lie)
    }

    /// Drop fantasy rows now (also happens automatically on guard drop).
    pub fn retract_fantasies(&mut self) {
        self.state.model.retract_fantasies();
    }

    /// Blocked scoring over the factored model (see
    /// [`IncrementalGp::score_into`]).
    pub fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        self.state.model.score_into(cand, c, acq_alpha, y_best, ws);
    }
}

impl Drop for SurrogateGuard<'_> {
    fn drop(&mut self) {
        // The factor between asks holds committed observations only;
        // fantasies are strictly per-proposal-batch state.
        self.state.model.retract_fantasies();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::NativeGp;
    use crate::util::Rng;

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
        (0..n)
            .map(|_| {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                let y = (4.0 * x[0]).sin() + 0.2 * x[d - 1];
                (x, y)
            })
            .collect()
    }

    #[test]
    fn tell_queues_and_lock_drains_in_order() {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(1);
        let obs = rows(&mut rng, 5, 3);
        for (x, y) in &obs {
            shared.tell(x.clone(), *y);
        }
        assert_eq!(shared.pending(), 5);
        assert_eq!(shared.len(), 0);
        assert_eq!(shared.total_observations(), 5);
        let g = shared.lock();
        assert_eq!(g.len(), 5);
        for (i, (x, y)) in obs.iter().enumerate() {
            assert_eq!(g.x(i), &x[..]);
            assert_eq!(g.y(i).to_bits(), y.to_bits());
        }
        drop(g);
        assert_eq!(shared.pending(), 0);
        assert_eq!(shared.len(), 5);
    }

    #[test]
    fn drained_model_matches_private_serial_model() {
        let hyper = GpHyper::default();
        let shared = SharedSurrogate::new(hyper);
        let mut rng = Rng::new(2);
        let obs = rows(&mut rng, 20, 4);
        // Tell in two waves with a lock (drain) in between: the factor
        // must be identical to one serial private model either way.
        for (x, y) in &obs[..9] {
            shared.tell(x.clone(), *y);
        }
        drop(shared.lock());
        for (x, y) in &obs[9..] {
            shared.tell(x.clone(), *y);
        }
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert_eq!(idx, (0..20).collect::<Vec<_>>());
        assert!(g.sync(&idx));
        let y_raw: Vec<f64> = (0..20).map(|i| g.y(i)).collect();
        g.set_targets(&y_raw);

        let cand: Vec<f64> = (0..8).map(|_| rng.f64()).collect();
        let mut ws = ScoreWorkspace::default();
        g.score_into(&cand, 2, 1.5, 0.5, &mut ws);

        let x: Vec<Vec<f64>> = obs.iter().map(|(x, _)| x.clone()).collect();
        let oracle = NativeGp::fit(&x, &y_raw, hyper).unwrap();
        let cand_rows: Vec<Vec<f64>> = cand.chunks(4).map(|c| c.to_vec()).collect();
        let post = oracle.predict(&cand_rows);
        for j in 0..2 {
            assert!((ws.mean[j] - post.mean[j]).abs() <= 1e-9);
            assert!((ws.std[j] - post.std[j]).abs() <= 1e-9);
        }
    }

    #[test]
    fn guard_drop_retracts_fantasies() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.1, 0.2], 0.5);
        shared.tell(vec![0.9, 0.8], -0.5);
        {
            let mut g = shared.lock();
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            assert!(g.extend_fantasy(&[0.5, 0.5], 0.0));
            assert_eq!(g.total(), 3);
        } // dropped without explicit retract
        let g = shared.lock();
        assert_eq!(g.total(), 2, "fantasy survived the guard");
    }

    #[test]
    fn reset_clears_queue_and_store() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.3], 1.0);
        drop(shared.lock());
        shared.tell(vec![0.6], 2.0);
        shared.reset();
        assert_eq!(shared.pending(), 0);
        assert_eq!(shared.len(), 0);
        assert_eq!(shared.total_observations(), 0);
        // Usable after reset (dimension may change).
        shared.tell(vec![0.1, 0.9], 3.0);
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 1);
    }

    #[test]
    fn set_hyper_invalidates_and_rebuilds() {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(3);
        for (x, y) in rows(&mut rng, 6, 2) {
            shared.tell(x, y);
        }
        drop(shared.lock()); // drain + eager factor
        let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
        shared.set_hyper(new);
        assert_eq!(shared.hyper(), new);
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx), "rebuild under new hypers failed");
        assert_eq!(g.total(), 6);
    }

    #[test]
    fn eager_factoring_can_be_disabled() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.set_eager_factoring(false);
        shared.tell(vec![0.1, 0.2], 1.0);
        shared.tell(vec![0.9, 0.5], 2.0);
        let mut g = shared.lock();
        assert_eq!(g.len(), 2, "store still records everything");
        assert_eq!(g.total(), 0, "no eager appends while disabled");
        // The factor is still available on demand.
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 2);
    }

    #[test]
    fn handles_address_one_model() {
        let a = SharedSurrogate::new(GpHyper::default());
        let b = a.clone();
        a.tell(vec![0.2], 1.0);
        b.tell(vec![0.8], 2.0);
        assert_eq!(a.total_observations(), 2);
        let g = b.lock();
        assert_eq!(g.len(), 2);
    }
}
