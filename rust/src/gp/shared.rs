//! The shared concurrent surrogate: one [`IncrementalGp`] conditioning
//! measurements from *many* producers — the evaluator pool of a single
//! [`TuningSession`](crate::session::TuningSession), or several concurrent
//! sessions on one host (a [`SessionGroup`](crate::session::SessionGroup))
//! — behind a handle that any thread can `tell` into without blocking on
//! the engine's scoring pass.
//!
//! # Why a queue + lock, not just a lock
//!
//! The paper's practicality argument (and the regime "Learning to Optimize
//! Tensor Programs" exploits with its shared cost model) is that surrogate
//! cost amortises across many concurrent measurements. A naive
//! `Mutex<IncrementalGp>` would serialise *tells against asks*: a daemon
//! reporting a measurement would wait out a full candidate-pool scoring
//! pass. Instead the handle splits the two sides:
//!
//! - **tell side** ([`SharedSurrogate::tell`]): producers append `(x, y)`
//!   rows to a small queue behind its own mutex — O(1) critical section,
//!   never blocked by scoring. Any evaluator thread, session driver or
//!   daemon-reporting loop may call it concurrently.
//! - **ask side** ([`SharedSurrogate::lock`]): the BO engine takes the
//!   model lock, *drains* the queue in observation (enqueue) order —
//!   each drained row folds into the persistent Cholesky factor as an
//!   O(n²) rank-1 append — and gets a [`SurrogateGuard`]: exclusive,
//!   read-mostly access to the factored model for the duration of one
//!   proposal batch (sync, constant-liar fantasy extend, blocked scoring).
//!   Tells that arrive *while* the guard is held simply queue up and are
//!   folded in by the next `lock`.
//!
//! Lock order is always model-state → queue (the drain inside `lock`, and
//! [`SharedSurrogate::reset`]); `tell` takes only the queue lock, so the
//! two sides cannot deadlock and tells cannot be starved by asks.
//!
//! Scope note: the handle shares the *posterior*, not engine bookkeeping.
//! Each engine still deduplicates proposals against its own history and
//! conditions constant-liar fantasies for its own in-flight trials only,
//! so two sessions can occasionally measure the same configuration — a
//! duplicate (noisy) observation, which the factor handles fine, not an
//! error.
//!
//! # The handle contract ([`SurrogateHandle`])
//!
//! The BO engine borrows the model through the [`SurrogateHandle`] trait,
//! not through this type directly. Two implementations share it:
//! `SharedSurrogate` (this module — in-process), and
//! [`RemoteSurrogate`](super::replica::RemoteSurrogate) (a replica of a
//! factor served over TCP by a surrogate service — `server`). The
//! contract both uphold: `tell` never blocks on a scoring pass, `lock`
//! drains every earlier tell in canonical observation order before
//! scoring, and fantasies extended through the guard never outlive it.
//!
//! # Cross-process pieces
//!
//! Three affordances exist purely so a served factor can be replicated:
//!
//! - [`SurrogateDelta`] / [`SharedSurrogate::export_delta`] /
//!   [`SharedSurrogate::import_delta`] — the catch-up unit. A delta
//!   carries the observation rows a replica is missing plus, when the
//!   authoritative factor covers exactly the store prefix, the packed
//!   Cholesky *suffix rows* for them — so the replica catches up with an
//!   O(Δn·n) import instead of re-factoring, and bit-identically to the
//!   authority.
//! - **ambient fantasies** — sibling *processes'* in-flight trials
//!   (constant-liar lease points served back by the surrogate service).
//!   The engine reads them via [`SurrogateGuard::ambient_point`] and
//!   conditions on them with [`SurrogateGuard::extend_fantasy_untracked`],
//!   which keeps them out of this process's own published lease.
//! - **the lease hook** — when set (only by `RemoteSurrogate`), every
//!   guard drop reports the batch's own fantasy points so the replica can
//!   publish them as a lease on the service. The hook runs *after* the
//!   model lock is released (it performs a network round trip).
//!
//! # Numerical contract
//!
//! Draining performs exactly the rank-1 appends a private
//! [`IncrementalGp`] would perform if the same observations were told
//! serially in the same order, so a shared model is *bit-equal* to the
//! serial private-model path given the same observation order — and
//! within ~1e-12 of it under reordering (the GP posterior is permutation
//! invariant in exact arithmetic). `rust/tests/shared_surrogate.rs` pins
//! both to ≤1e-9 under genuine thread interleavings;
//! `rust/tests/surrogate_service.rs` pins the replicated-factor path over
//! real loopback TCP to the same bound.
//!
//! # Example
//!
//! ```
//! use tftune::gp::{GpHyper, ScoreWorkspace, SharedSurrogate};
//!
//! let shared = SharedSurrogate::new(GpHyper::default());
//! // Producers (evaluator threads, daemons) tell without blocking:
//! let handle = shared.clone();
//! std::thread::spawn(move || handle.tell(vec![0.2, 0.7], 1.0)).join().unwrap();
//! shared.tell(vec![0.8, 0.1], -0.5);
//!
//! // The ask side drains the queue and scores through one guard:
//! let mut g = shared.lock();
//! assert_eq!(g.len(), 2);
//! let idx = g.conditioning_set();
//! assert!(g.sync(&idx));
//! g.set_targets(&[1.0, -0.5]);
//! let mut ws = ScoreWorkspace::default();
//! g.score_into(&[0.5, 0.5], 1, 1.5, 1.0, &mut ws);
//! assert!(ws.std[0] > 0.0);
//! ```

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use super::incremental::{IncrementalGp, ScoreTier, ScoreWorkspace};
use super::kernel::{GpHyper, UNBOUNDED_HISTORY};
use super::sharded::ShardedGp;
use crate::obs::{Event, EventSource};
use crate::util::linalg::{packed_len, BlockSpec};

/// Callback a replica installs to publish the guard's own fantasy points
/// as a cross-process lease when the guard drops (module docs).
pub(crate) type LeaseHook = Box<dyn FnMut(&[(Vec<f64>, f64)]) + Send>;

/// Callback a replica installs to write in-guard hyper changes
/// ([`SurrogateGuard::ensure_hyper`]) through to the surrogate service,
/// so sibling replicas converge on one hyper instead of each selecting
/// locally. Runs after the model lock is released (network round trip).
pub(crate) type HyperHook = Box<dyn FnMut(GpHyper) + Send>;

/// One state mutation of the canonical store, as seen by the durability
/// journal (`persist`): a stored observation row or an adopted hyper
/// change. Borrowed views — the journal runs synchronously *under the
/// model-state lock*, at the exact point the mutation lands, so the
/// write-ahead log records mutations in true store order.
pub(crate) enum JournalEvent<'a> {
    /// A row was appended to the canonical store (post dimension check —
    /// dropped rows are never journaled).
    Row { x: &'a [f64], y: f64, extras: &'a [f64] },
    /// The model switched hyperparameters.
    Hyper(GpHyper),
}

/// The durability journal: invoked under the model-state lock for every
/// store mutation. Must be cheap and non-blocking (buffered append — the
/// fsync cadence is the journal owner's business).
pub(crate) type Journal = Box<dyn FnMut(JournalEvent<'_>) + Send>;

/// The handle contract the BO engine conditions its surrogate through.
///
/// Implemented by [`SharedSurrogate`] (one factor per host process) and
/// [`RemoteSurrogate`](super::replica::RemoteSurrogate) (a replica of a
/// factor served over TCP), so `BayesOpt::with_shared_surrogate` accepts
/// either and the in-process and cross-process paths stay one stack.
///
/// Contract: [`SurrogateHandle::tell`] never blocks on a concurrent
/// scoring pass; [`SurrogateHandle::lock`] drains every tell issued
/// before it, in canonical observation order, and returns exclusive
/// access to the synced model; fantasies extended through the returned
/// guard are retracted when the guard drops (for a remote handle the
/// service additionally expires the published lease if the process
/// disconnects without retracting).
pub trait SurrogateHandle: Send + Sync {
    /// Enqueue one observation (`x` in the unit cube, `y` raw objective).
    fn tell(&self, x: Vec<f64>, y: f64);

    /// Enqueue one observation carrying K objective columns (`ys[0]` is
    /// the primary objective, later entries the declared secondary
    /// columns in maximisation orientation; NaN marks a column this
    /// trial could not measure). Same non-blocking contract as
    /// [`SurrogateHandle::tell`]; an empty `ys` is dropped with a
    /// warning.
    fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>);

    /// Drain pending tells and take the ask-side lock (module docs).
    fn lock(&self) -> SurrogateGuard<'_>;

    /// The hyperparameters the model currently conditions with.
    fn hyper(&self) -> GpHyper;

    /// Switch hyperparameters, invalidating the factor. Write-through:
    /// every engine sharing the underlying model adopts them.
    fn set_hyper(&self, hyper: GpHyper);

    /// Enable/disable eager factoring on drain
    /// (see [`SharedSurrogate::set_eager_factoring`]).
    fn set_eager_factoring(&self, on: bool);

    /// Observations in the canonical store this handle can see.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Observations the model will condition on once pending tells land.
    fn total_observations(&self) -> usize;

    /// Cheap clone addressing the same model.
    fn clone_handle(&self) -> Box<dyn SurrogateHandle>;
}

/// One replication unit of a shared factor: the observation rows a
/// replica is missing and — when the authoritative factor covers exactly
/// the store prefix — their packed Cholesky suffix rows, so catch-up is
/// an O(Δn·n) verbatim import instead of an O(Δn·n²) re-factor. Carries
/// the authority's hypers (replicas adopt them) and, over the wire, the
/// sibling processes' in-flight lease points (constant-liar fantasies).
#[derive(Debug, Clone, PartialEq)]
pub struct SurrogateDelta {
    /// First row index the delta covers (the replica's current length).
    pub from_n: usize,
    /// Authoritative store length after the delta.
    pub total_n: usize,
    /// Hypers the authoritative factor conditions with.
    pub hyper: GpHyper,
    /// `(x, y)` observation rows `from_n..total_n`, canonical order
    /// (`y` is the primary objective).
    pub rows: Vec<(Vec<f64>, f64)>,
    /// Secondary objective columns per row, aligned with `rows` (empty
    /// inner vector = single-objective row; NaN = declared column the
    /// trial did not carry). May be empty entirely when no row has
    /// extras — protocol-v2 peers always decode it that way.
    pub extras: Vec<Vec<f64>>,
    /// Packed factor rows `from_n..total_n` concatenated
    /// (`packed_len(total_n) - packed_len(from_n)` values), present iff
    /// the authoritative factor is exactly the store prefix.
    pub factor: Option<Vec<f64>>,
    /// Sibling processes' in-flight points `(x, lie)` — served back so a
    /// replica's engine can condition on them as ambient fantasies.
    pub leases: Vec<(Vec<f64>, f64)>,
}

/// The factored model behind a [`SharedSurrogate`]: either the exact
/// [`IncrementalGp`] (the default — one flat O(n²) factor) or the
/// sharded scaling tier ([`ShardedGp`] — locally-exact shards with
/// O(cap²) tells). Every guard operation forwards through this enum, so
/// the drain / sync / fantasy / scoring plumbing is engine-agnostic and
/// the two tiers cannot drift apart structurally.
pub(crate) enum GpEngine {
    Exact(IncrementalGp),
    Sharded(ShardedGp),
}

impl GpEngine {
    fn push(&mut self, xr: &[f64], yv: f64) -> bool {
        match self {
            GpEngine::Exact(g) => g.push(xr, yv),
            GpEngine::Sharded(g) => g.push(xr, yv),
        }
    }

    fn clear(&mut self) {
        match self {
            GpEngine::Exact(g) => g.clear(),
            GpEngine::Sharded(g) => g.clear(),
        }
    }

    fn set_hyper(&mut self, hyper: GpHyper) {
        match self {
            GpEngine::Exact(g) => g.set_hyper(hyper),
            GpEngine::Sharded(g) => g.set_hyper(hyper),
        }
    }

    fn retract_fantasies(&mut self) {
        match self {
            GpEngine::Exact(g) => g.retract_fantasies(),
            GpEngine::Sharded(g) => g.retract_fantasies(),
        }
    }

    fn set_targets(&mut self, y: &[f64]) {
        match self {
            GpEngine::Exact(g) => g.set_targets(y),
            GpEngine::Sharded(g) => g.set_targets(y),
        }
    }

    fn total(&self) -> usize {
        match self {
            GpEngine::Exact(g) => g.total(),
            GpEngine::Sharded(g) => g.total(),
        }
    }

    fn extend_fantasy(&mut self, xr: &[f64], lie: f64) -> bool {
        match self {
            GpEngine::Exact(g) => g.extend_fantasy(xr, lie),
            GpEngine::Sharded(g) => g.extend_fantasy(xr, lie),
        }
    }

    fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        match self {
            GpEngine::Exact(g) => g.score_into(cand, c, acq_alpha, y_best, ws),
            GpEngine::Sharded(g) => g.score_into(cand, c, acq_alpha, y_best, ws),
        }
    }

    fn score_multi_into(
        &mut self,
        cand: &[f64],
        c: usize,
        targets: &[&[f64]],
        ws: &mut ScoreWorkspace,
    ) {
        match self {
            GpEngine::Exact(g) => g.score_multi_into(cand, c, targets, ws),
            GpEngine::Sharded(g) => g.score_multi_into(cand, c, targets, ws),
        }
    }

    fn score_threads(&self) -> usize {
        match self {
            GpEngine::Exact(g) => g.score_threads(),
            GpEngine::Sharded(g) => g.score_threads(),
        }
    }

    fn set_score_threads(&mut self, threads: usize) {
        match self {
            GpEngine::Exact(g) => g.set_score_threads(threads),
            GpEngine::Sharded(g) => g.set_score_threads(threads),
        }
    }

    fn score_tier(&self) -> ScoreTier {
        match self {
            GpEngine::Exact(g) => g.score_tier(),
            GpEngine::Sharded(g) => g.score_tier(),
        }
    }

    fn set_score_tier(&mut self, tier: ScoreTier) {
        match self {
            GpEngine::Exact(g) => g.set_score_tier(tier),
            GpEngine::Sharded(g) => g.set_score_tier(tier),
        }
    }

    fn set_block_spec(&mut self, blocks: BlockSpec) {
        match self {
            GpEngine::Exact(g) => g.set_block_spec(blocks),
            GpEngine::Sharded(g) => g.set_block_spec(blocks),
        }
    }

    /// The packed factor suffix a replica delta rides on. Only the flat
    /// exact engine has one global packed factor; a sharded authority
    /// exports rows-only deltas (replicas re-factor locally — the cost
    /// cap is a per-daemon property, not a wire contract).
    fn factor_suffix(&self, from: usize) -> Option<&[f64]> {
        match self {
            GpEngine::Exact(g) => Some(g.factor_suffix(from)),
            GpEngine::Sharded(_) => None,
        }
    }

    /// Append a row whose packed factor row was computed by an exact
    /// authority. The sharded tier has no flat factor to splice into, so
    /// it ignores `lrow` and recomputes the append locally (same rows,
    /// same order — only the cross-process bit-parity shortcut is lost).
    fn import_row(&mut self, xr: &[f64], yv: f64, lrow: &[f64]) -> bool {
        match self {
            GpEngine::Exact(g) => g.import_row(xr, yv, lrow),
            GpEngine::Sharded(g) => g.push(xr, yv),
        }
    }
}

/// Model state behind the ask-side lock: the canonical observation store
/// plus the persistent factor over (a windowed subset of) it.
struct SharedState {
    /// Hyperparameters every conditioning pass uses. Changing them
    /// invalidates the factor ([`SurrogateGuard::ensure_hyper`]).
    hyper: GpHyper,
    /// All drained observations, in drain (= enqueue) order. This is the
    /// canonical history the conditioning window selects from.
    obs_x: Vec<Vec<f64>>,
    obs_y: Vec<f64>,
    /// Secondary objective columns per observation, aligned with
    /// `obs_x` (empty = single-objective row; NaN = degraded column).
    obs_extra: Vec<Vec<f64>>,
    /// The persistent factored model (exact or sharded tier).
    model: GpEngine,
    /// Indices into `obs_x` currently factored into `model`, in factor
    /// row order — decides between rank-1 append and rebuild on sync.
    factored: Vec<usize>,
    /// Eagerly fold drained rows into the factor (default). Engines that
    /// never score through the factor (HLO artifact, scratch reference —
    /// `Surrogate::use_engine_incremental()` false) disable this so
    /// drains stay O(1) bookkeeping.
    eager: bool,
    /// Spare row buffer swapped with the queue on drain, so the queue
    /// keeps its capacity and warmed-up tells never allocate.
    drain_buf: Vec<(Vec<f64>, f64, Vec<f64>)>,
    /// Sibling processes' in-flight `(x, lie)` points, refreshed by
    /// [`SharedSurrogate::import_delta`]. Always empty on a purely local
    /// handle.
    ambient: Vec<(Vec<f64>, f64)>,
    /// Durability journal (`persist` installs it on the *authoritative*
    /// handle only — mirrors replicate a factor that is already journaled
    /// at its authority). Lives behind the state mutex so journal order
    /// is store-mutation order by construction.
    journal: Option<Journal>,
}

impl SharedState {
    /// Dimension of the canonical store (fixed by its first row).
    fn dim(&self) -> Option<usize> {
        self.obs_x.first().map(Vec::len)
    }

    /// Fold one drained observation into the store, eagerly rank-1
    /// appending to the factor while it is still the full windowed prefix
    /// of the history (the cheap common case; anything else is repaired by
    /// the next [`SurrogateGuard::sync`]).
    ///
    /// Rows whose dimension disagrees with the store are *dropped with a
    /// warning*, not asserted on: on a surrogate service the queue is fed
    /// by the network (a tuner attached with the wrong search space must
    /// degrade the one bad producer, not panic the fleet's daemon).
    fn drain_one(&mut self, x: Vec<f64>, y: f64, extra: Vec<f64>) {
        if x.is_empty() || self.dim().map_or(false, |d| d != x.len()) {
            eprintln!(
                "tftune: dropping observation with dimension {} (store dimension {:?}) — \
                 one shared surrogate serves exactly one search space",
                x.len(),
                self.dim()
            );
            return;
        }
        let i = self.obs_x.len();
        if self.eager && i + 1 <= self.hyper.max_history && self.factored.len() == i {
            if self.model.push(&x, 0.0) {
                self.factored.push(i);
            } else {
                self.model.clear();
                self.factored.clear();
            }
        }
        if let Some(journal) = self.journal.as_mut() {
            journal(JournalEvent::Row { x: &x, y, extras: &extra });
        }
        self.obs_x.push(x);
        self.obs_y.push(y);
        self.obs_extra.push(extra);
    }
}

struct Inner {
    /// Pending `(x, y, extras)` appends, in tell order. Its own mutex so
    /// the tell side never contends with a scoring pass. `extras` is the
    /// secondary objective columns (empty = single-objective tell, so a
    /// plain `tell` still allocates nothing beyond the row).
    queue: Mutex<Vec<(Vec<f64>, f64, Vec<f64>)>>,
    state: Mutex<SharedState>,
    /// Replica lease publication hook (module docs). Its own mutex — the
    /// guard invokes it *after* releasing the model lock.
    lease_hook: Mutex<Option<LeaseHook>>,
    /// Replica hyper write-through hook: invoked (after the model lock is
    /// released) when a guard changed hypers via `ensure_hyper`, so a
    /// served factor's siblings converge on one hyper.
    hyper_hook: Mutex<Option<HyperHook>>,
    /// Observability source (`tell` enqueue depth, drain timing, factor
    /// geometry — see [`crate::obs`]). Write-once so the tell hot path
    /// reads it lock-free; unset (the default) costs one pointer load.
    events: OnceLock<EventSource>,
}

/// A cloneable handle to one concurrently-shared surrogate model (module
/// docs). Cloning is cheap (`Arc`); every clone addresses the same model.
pub struct SharedSurrogate {
    inner: Arc<Inner>,
}

impl Clone for SharedSurrogate {
    fn clone(&self) -> SharedSurrogate {
        SharedSurrogate { inner: Arc::clone(&self.inner) }
    }
}

impl std::fmt::Debug for SharedSurrogate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedSurrogate").finish_non_exhaustive()
    }
}

impl SharedSurrogate {
    /// A fresh, empty shared model conditioned with `hyper`.
    pub fn new(hyper: GpHyper) -> SharedSurrogate {
        SharedSurrogate {
            inner: Arc::new(Inner {
                queue: Mutex::new(Vec::new()),
                state: Mutex::new(SharedState {
                    hyper,
                    obs_x: Vec::new(),
                    obs_y: Vec::new(),
                    obs_extra: Vec::new(),
                    model: GpEngine::Exact(IncrementalGp::new(hyper)),
                    factored: Vec::new(),
                    eager: true,
                    drain_buf: Vec::new(),
                    ambient: Vec::new(),
                    journal: None,
                }),
                lease_hook: Mutex::new(None),
                hyper_hook: Mutex::new(None),
                events: OnceLock::new(),
            }),
        }
    }

    /// A fresh, empty shared model on the **sharded scaling tier**
    /// ([`ShardedGp`]): locally-exact shards of at most `shard_cap` rows
    /// under a KD router, `blend_k`-expert gPoE blending at ask time, so
    /// a tell costs O(cap²) no matter how long the campaign runs. The
    /// conditioning window is forced to unbounded — windowing exists to
    /// cap the exact engine's O(n²)/O(n³) costs, which is precisely what
    /// the shards already bound; the full history stays conditioned.
    /// An attached `BayesOpt` adopts the unbounded window through the
    /// usual `with_shared_surrogate` hyper adoption.
    ///
    /// With `shard_cap >= n` exactly one shard ever exists and every
    /// call delegates verbatim to the inner exact engine — bit-identical
    /// to [`SharedSurrogate::new`] (pinned by
    /// `rust/tests/sharded_surrogate.rs`).
    pub fn new_sharded(mut hyper: GpHyper, shard_cap: usize, blend_k: usize) -> SharedSurrogate {
        hyper.max_history = UNBOUNDED_HISTORY;
        SharedSurrogate {
            inner: Arc::new(Inner {
                queue: Mutex::new(Vec::new()),
                state: Mutex::new(SharedState {
                    hyper,
                    obs_x: Vec::new(),
                    obs_y: Vec::new(),
                    obs_extra: Vec::new(),
                    model: GpEngine::Sharded(ShardedGp::new(hyper, shard_cap, blend_k)),
                    factored: Vec::new(),
                    eager: true,
                    drain_buf: Vec::new(),
                    ambient: Vec::new(),
                    journal: None,
                }),
                lease_hook: Mutex::new(None),
                hyper_hook: Mutex::new(None),
                events: OnceLock::new(),
            }),
        }
    }

    /// Whether this handle's model is on the sharded scaling tier.
    pub fn is_sharded(&self) -> bool {
        matches!(self.inner.state.lock().unwrap().model, GpEngine::Sharded(_))
    }

    /// Shard count of a sharded-tier model (1 until the first split;
    /// `None` on the exact tier) — observability for the fleet daemon
    /// and the scaling tests.
    pub fn num_shards(&self) -> Option<usize> {
        match &self.inner.state.lock().unwrap().model {
            GpEngine::Exact(_) => None,
            GpEngine::Sharded(g) => Some(g.num_shards()),
        }
    }

    /// Flip this handle's model to the sharded tier in place, re-homing
    /// every stored observation into shards. No-op if already sharded.
    /// The conditioning window is lifted to unbounded (journaled, so
    /// recovery replays the same decision); the factor is rebuilt by
    /// re-pushing the store in canonical order with placeholder targets
    /// (targets are re-standardised by every ask anyway). The fleet
    /// daemon calls this when a space's history crosses
    /// `--max-rows-per-space`.
    pub fn convert_to_sharded(&self, shard_cap: usize, blend_k: usize) {
        // Drain queued tells and retract stray fantasies first, so the
        // rebuilt model sees the full store.
        drop(self.lock());
        let mut st = self.inner.state.lock().unwrap();
        if matches!(st.model, GpEngine::Sharded(_)) {
            return;
        }
        if st.hyper.max_history != UNBOUNDED_HISTORY {
            st.hyper.max_history = UNBOUNDED_HISTORY;
            let hyper = st.hyper;
            if let Some(journal) = st.journal.as_mut() {
                journal(JournalEvent::Hyper(hyper));
            }
        }
        let mut sharded = ShardedGp::new(st.hyper, shard_cap, blend_k);
        sharded.set_score_threads(st.model.score_threads());
        sharded.set_score_tier(st.model.score_tier());
        st.factored.clear();
        let mut ok = true;
        for i in 0..st.obs_x.len() {
            if !sharded.push(&st.obs_x[i], 0.0) {
                ok = false;
                break;
            }
        }
        if ok {
            st.factored.extend(0..st.obs_x.len());
        } else {
            // Non-PD during rebuild: start empty, the next guard sync
            // reconditions from the store.
            sharded.clear();
        }
        st.model = GpEngine::Sharded(sharded);
    }

    /// Enqueue one observation (`x` in the unit cube, `y` the raw
    /// objective). Callable from any thread; never blocks on a scoring
    /// pass — the row is folded into the factor, in enqueue order, by the
    /// next [`SharedSurrogate::lock`].
    pub fn tell(&self, x: Vec<f64>, y: f64) {
        let pending = {
            let mut q = self.inner.queue.lock().unwrap();
            q.push((x, y, Vec::new()));
            q.len()
        };
        if let Some(src) = self.inner.events.get() {
            src.emit(Event::SurrogateTell { pending });
        }
    }

    /// Enqueue one observation carrying K objective columns (`ys[0]`
    /// primary, the rest secondary — maximisation orientation, NaN for a
    /// column the trial could not measure). Non-blocking like
    /// [`SharedSurrogate::tell`]; an empty `ys` is dropped with a
    /// warning rather than panicking a producer thread.
    pub fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>) {
        let Some((&y, extra)) = ys.split_first() else {
            eprintln!("tftune: dropping observation with no objective columns");
            return;
        };
        let extra = extra.to_vec();
        let pending = {
            let mut q = self.inner.queue.lock().unwrap();
            q.push((x, y, extra));
            q.len()
        };
        if let Some(src) = self.inner.events.get() {
            src.emit(Event::SurrogateTell { pending });
        }
    }

    /// Point this handle's emissions at an observability source (see
    /// [`crate::obs`]): every `tell` reports the queue depth; every
    /// [`SharedSurrogate::lock`] reports what the drain folded in and
    /// the resulting factor geometry. Write-once — the first caller
    /// wins, later calls are ignored — so the tell path never takes a
    /// lock to find it. Emissions are non-blocking and near-free until
    /// a sink attaches to the bus.
    pub fn set_event_source(&self, src: EventSource) {
        let _ = self.inner.events.set(src);
    }

    /// Observations told but not yet drained into the model.
    pub fn pending(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Observations already drained into the canonical store. The next
    /// [`SharedSurrogate::lock`] may observe more (pending tells drain).
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().obs_x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimension of the rows the canonical store holds — `None` until the
    /// first observation drains. One shared surrogate serves exactly one
    /// search space; the fleet daemon uses this to refuse a conflicting
    /// `hello` instead of silently dropping its rows later.
    pub fn dim(&self) -> Option<usize> {
        self.inner.state.lock().unwrap().dim()
    }

    /// Drained + pending observations — the count the model will condition
    /// on once the queue is next drained.
    pub fn total_observations(&self) -> usize {
        // Lock order: state before queue (same as the drain in `lock`).
        let state = self.inner.state.lock().unwrap();
        let pending = self.inner.queue.lock().unwrap().len();
        state.obs_x.len() + pending
    }

    /// The hyperparameters the shared model currently conditions with.
    pub fn hyper(&self) -> GpHyper {
        self.inner.state.lock().unwrap().hyper
    }

    /// Switch hyperparameters, invalidating the factor (rebuilt by the
    /// next sync). Affects every engine sharing this handle.
    pub fn set_hyper(&self, hyper: GpHyper) {
        self.lock().ensure_hyper(hyper);
    }

    /// Enable/disable eager factoring on drain (default on). Turn it off
    /// when no attached engine scores through the factor — e.g. the HLO
    /// artifact or scratch-refit surrogate paths, which read only the
    /// observation store — so every drained row costs O(1), not an O(n²)
    /// rank-1 append. [`SurrogateGuard::sync`] still builds the factor on
    /// demand if someone asks for it.
    pub fn set_eager_factoring(&self, on: bool) {
        self.inner.state.lock().unwrap().eager = on;
    }

    /// Drop all observations (queued and drained) and clear the factor,
    /// keeping the hyperparameters — reuse one handle across runs.
    pub fn reset(&self) {
        let mut state = self.inner.state.lock().unwrap();
        self.inner.queue.lock().unwrap().clear();
        state.obs_x.clear();
        state.obs_y.clear();
        state.obs_extra.clear();
        state.model.clear();
        state.factored.clear();
        state.ambient.clear();
    }

    /// Install the replica lease hook (module docs). The hook receives,
    /// on every guard drop, the `(x, lie)` fantasy points the batch
    /// extended through [`SurrogateGuard::extend_fantasy`] — i.e. this
    /// process's own in-flight trials — and runs with the model lock
    /// released.
    pub(crate) fn set_lease_hook(
        &self,
        hook: impl FnMut(&[(Vec<f64>, f64)]) + Send + 'static,
    ) {
        *self.inner.lease_hook.lock().unwrap() = Some(Box::new(hook));
    }

    /// Install the hyper write-through hook: invoked with the new hypers
    /// whenever a guard changes them via [`SurrogateGuard::ensure_hyper`]
    /// (e.g. in-guard lengthscale selection), after the model lock is
    /// released. A replica uses this to publish the change to the
    /// surrogate service so sibling replicas converge on one hyper.
    pub(crate) fn set_hyper_hook(&self, hook: impl FnMut(GpHyper) + Send + 'static) {
        *self.inner.hyper_hook.lock().unwrap() = Some(Box::new(hook));
    }

    /// Install the durability journal (`persist`): invoked synchronously
    /// under the model-state lock for every store mutation — a stored
    /// observation row or an adopted hyper change — so the write-ahead
    /// log records mutations in exact store order. Install on the
    /// *authoritative* handle only; a replica mirror replicates a factor
    /// whose mutations are already journaled at the authority.
    pub(crate) fn set_journal(
        &self,
        journal: impl FnMut(JournalEvent<'_>) + Send + 'static,
    ) {
        self.inner.state.lock().unwrap().journal = Some(Box::new(journal));
    }

    /// Export the catch-up delta for a replica at `from_n` rows: drains
    /// pending tells first, so the delta reflects every tell received.
    /// `None` if the replica claims more rows than the store holds.
    /// The factor suffix rides along iff the factor covers exactly the
    /// store prefix (eager factoring within the conditioning window —
    /// the service's steady state). `leases` is left empty; the serving
    /// layer fills in sibling lease points.
    pub fn export_delta(&self, from_n: usize) -> Option<SurrogateDelta> {
        drop(self.lock()); // drain queued tells; retract stray fantasies
        let st = self.inner.state.lock().unwrap();
        let n = st.obs_x.len();
        if from_n > n {
            return None;
        }
        let rows: Vec<(Vec<f64>, f64)> =
            (from_n..n).map(|i| (st.obs_x[i].clone(), st.obs_y[i])).collect();
        let extras: Vec<Vec<f64>> = (from_n..n).map(|i| st.obs_extra[i].clone()).collect();
        let prefix =
            st.factored.len() == n && st.factored.iter().enumerate().all(|(i, &j)| i == j);
        let factor =
            if prefix { st.model.factor_suffix(from_n).map(<[f64]>::to_vec) } else { None };
        Some(SurrogateDelta {
            from_n,
            total_n: n,
            hyper: st.hyper,
            rows,
            extras,
            factor,
            leases: Vec::new(),
        })
    }

    /// Apply a catch-up delta exported by the authoritative factor. The
    /// store must sit exactly at `delta.from_n` rows (the replica always
    /// requests its own length); hypers are adopted on mismatch. When the
    /// delta carries factor rows and the local factor is the store prefix,
    /// they are imported verbatim — O(Δn·n), bit-identical to the
    /// authority; otherwise rows land through the ordinary drain path and
    /// the factor is rebuilt on the next sync. Sibling lease points
    /// replace the ambient-fantasy set. Returns false (nothing applied)
    /// on a length mismatch.
    pub fn import_delta(&self, delta: &SurrogateDelta) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        st.model.retract_fantasies();
        if st.obs_x.len() != delta.from_n {
            return false;
        }
        // Shape sanity on wire-decoded counts (also keeps packed_len from
        // overflowing on garbage).
        if delta.total_n < delta.from_n || delta.total_n > (1 << 30) {
            return false;
        }
        // Dimension sanity on wire-decoded rows: one model, one space.
        let dim = st.dim().or_else(|| delta.rows.first().map(|(x, _)| x.len()));
        if let Some(d) = dim {
            if d == 0 || delta.rows.iter().any(|(x, _)| x.len() != d) {
                return false;
            }
        }
        // Extras ride per-row: either absent entirely (v2 peer) or one
        // (possibly empty) column vector per row.
        if !delta.extras.is_empty() && delta.extras.len() != delta.rows.len() {
            return false;
        }
        let extra_of = |k: usize| delta.extras.get(k).cloned().unwrap_or_default();
        if st.hyper != delta.hyper {
            let hyper = delta.hyper;
            st.hyper = hyper;
            st.model.set_hyper(hyper);
            st.factored.clear();
            if let Some(journal) = st.journal.as_mut() {
                journal(JournalEvent::Hyper(hyper));
            }
        }
        let expected = packed_len(delta.total_n) - packed_len(delta.from_n);
        let prefix = st.factored.len() == delta.from_n
            && st.factored.iter().enumerate().all(|(i, &j)| i == j);
        match &delta.factor {
            Some(suffix)
                if prefix
                    && suffix.len() == expected
                    && delta.rows.len() == delta.total_n - delta.from_n =>
            {
                // Verbatim import. A rejected row (malformed wire data)
                // drops the factor and stores the remaining rows plain —
                // the next guard sync rebuilds locally.
                let mut importing = true;
                let mut off = 0;
                for (k, (x, y)) in delta.rows.iter().enumerate() {
                    let m = delta.from_n + k;
                    let row = &suffix[off..off + m + 1];
                    off += m + 1;
                    let i = st.obs_x.len();
                    if importing {
                        if st.model.import_row(x, *y, row) {
                            st.factored.push(i);
                        } else {
                            st.model.clear();
                            st.factored.clear();
                            importing = false;
                        }
                    }
                    let extra = extra_of(k);
                    if let Some(journal) = st.journal.as_mut() {
                        journal(JournalEvent::Row { x, y: *y, extras: &extra });
                    }
                    st.obs_x.push(x.clone());
                    st.obs_y.push(*y);
                    st.obs_extra.push(extra);
                }
            }
            _ => {
                for (k, (x, y)) in delta.rows.iter().enumerate() {
                    st.drain_one(x.clone(), *y, extra_of(k));
                }
            }
        }
        st.ambient.clear();
        st.ambient.extend(delta.leases.iter().cloned());
        true
    }

    /// Take the ask-side lock: drain every pending tell into the factor
    /// (in enqueue order) and return exclusive access to the synced model.
    /// Concurrent `tell`s keep landing in the queue while the guard is
    /// held; they are folded in by the next `lock`.
    pub fn lock(&self) -> SurrogateGuard<'_> {
        // Read the hook flags *before* taking the model lock: the hook
        // mutexes sit above conn → model-state in the replica's lock
        // order, so holding model-state while acquiring them could cycle.
        let log_lease = self.inner.lease_hook.lock().unwrap().is_some();
        let log_hyper = self.inner.hyper_hook.lock().unwrap().is_some();
        // Drain timing for the observability plane: wall time from lock
        // acquisition through the queue fold — the "surrogate lock"
        // column of the critical-path report. Gated on an attached sink
        // so the uninstrumented path never reads the clock.
        let events = self.inner.events.get().filter(|s| s.enabled());
        let t0 = events.map(|_| Instant::now());
        let mut state = self.inner.state.lock().unwrap();
        // Defensive: a guard dropped mid-proposal (panic) may have left
        // fantasy rows; the factor must hold committed rows only before
        // new observations are appended.
        state.model.retract_fantasies();
        // Swap the queue with the spare buffer instead of mem::take, so
        // the queue keeps its capacity and tells stay allocation-free
        // once warmed up.
        let mut pending = std::mem::take(&mut state.drain_buf);
        std::mem::swap(&mut pending, &mut *self.inner.queue.lock().unwrap());
        let drained = pending.len();
        for (x, y, extra) in pending.drain(..) {
            state.drain_one(x, y, extra);
        }
        state.drain_buf = pending;
        if let (Some(src), Some(t0)) = (events, t0) {
            src.emit(Event::SurrogateDrain {
                drained,
                total: state.obs_x.len(),
                wait_ns: t0.elapsed().as_nanos() as u64,
            });
            src.emit(Event::FactorSize {
                rows: state.factored.len(),
                entries: packed_len(state.factored.len()),
            });
        }
        SurrogateGuard {
            state: Some(state),
            hook: &self.inner.lease_hook,
            log_lease,
            own_log: Vec::new(),
            hyper_hook: &self.inner.hyper_hook,
            log_hyper,
            hyper_changed: None,
        }
    }
}

impl SurrogateHandle for SharedSurrogate {
    fn tell(&self, x: Vec<f64>, y: f64) {
        SharedSurrogate::tell(self, x, y)
    }

    fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>) {
        SharedSurrogate::tell_multi(self, x, ys)
    }

    fn lock(&self) -> SurrogateGuard<'_> {
        SharedSurrogate::lock(self)
    }

    fn hyper(&self) -> GpHyper {
        SharedSurrogate::hyper(self)
    }

    fn set_hyper(&self, hyper: GpHyper) {
        SharedSurrogate::set_hyper(self, hyper)
    }

    fn set_eager_factoring(&self, on: bool) {
        SharedSurrogate::set_eager_factoring(self, on)
    }

    fn len(&self) -> usize {
        SharedSurrogate::len(self)
    }

    fn total_observations(&self) -> usize {
        SharedSurrogate::total_observations(self)
    }

    fn clone_handle(&self) -> Box<dyn SurrogateHandle> {
        Box::new(self.clone())
    }
}

/// Boxed handles forward the contract, so a handle returned by
/// `BayesOpt::surrogate_handle` can be attached to further engines
/// without knowing which implementation sits behind it.
impl SurrogateHandle for Box<dyn SurrogateHandle> {
    fn tell(&self, x: Vec<f64>, y: f64) {
        (**self).tell(x, y)
    }

    fn tell_multi(&self, x: Vec<f64>, ys: Vec<f64>) {
        (**self).tell_multi(x, ys)
    }

    fn lock(&self) -> SurrogateGuard<'_> {
        (**self).lock()
    }

    fn hyper(&self) -> GpHyper {
        (**self).hyper()
    }

    fn set_hyper(&self, hyper: GpHyper) {
        (**self).set_hyper(hyper)
    }

    fn set_eager_factoring(&self, on: bool) {
        (**self).set_eager_factoring(on)
    }

    fn len(&self) -> usize {
        (**self).len()
    }

    fn total_observations(&self) -> usize {
        (**self).total_observations()
    }

    fn clone_handle(&self) -> Box<dyn SurrogateHandle> {
        (**self).clone_handle()
    }
}

/// Exclusive, drained view of the shared model for one proposal batch.
///
/// The guard exposes the canonical observation store (for conditioning-set
/// selection and target standardisation) and the incremental model's
/// sync / fantasy / scoring operations. Fantasy rows extended through the
/// guard are automatically retracted when it drops, so the factor between
/// asks always holds committed observations only. On a replica handle the
/// drop additionally publishes the batch's own fantasy points as a
/// cross-process lease (after releasing the model lock).
pub struct SurrogateGuard<'a> {
    /// `Some` for the guard's whole visible lifetime; taken in `drop` so
    /// the model lock is released before the lease hook's network call.
    state: Option<MutexGuard<'a, SharedState>>,
    hook: &'a Mutex<Option<LeaseHook>>,
    /// Whether to record own fantasy points for the hook (hook installed).
    log_lease: bool,
    /// Own fantasy points extended during this batch (tracked only when
    /// `log_lease`).
    own_log: Vec<(Vec<f64>, f64)>,
    hyper_hook: &'a Mutex<Option<HyperHook>>,
    /// Whether to record in-guard hyper changes (hook installed).
    log_hyper: bool,
    /// The hypers an in-guard `ensure_hyper` switched to, published on
    /// drop (last change wins within one batch).
    hyper_changed: Option<GpHyper>,
}

impl SurrogateGuard<'_> {
    fn st(&self) -> &SharedState {
        self.state.as_ref().expect("guard state present until drop")
    }

    fn st_mut(&mut self) -> &mut SharedState {
        self.state.as_mut().expect("guard state present until drop")
    }

    /// Observations in the canonical store (drain order).
    pub fn len(&self) -> usize {
        self.st().obs_x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.st().obs_x.is_empty()
    }

    /// Unit-cube coordinates of observation `i` (drain order).
    pub fn x(&self, i: usize) -> &[f64] {
        &self.st().obs_x[i]
    }

    /// Raw objective value of observation `i` (drain order).
    pub fn y(&self, i: usize) -> f64 {
        self.st().obs_y[i]
    }

    /// Secondary objective columns of observation `i` (maximisation
    /// orientation, declared order minus the primary). Empty for a
    /// single-objective row; NaN marks a declared column that row's
    /// trial could not measure — consumers degrade that row, never the
    /// factor (the factor depends only on X).
    pub fn y_extras(&self, i: usize) -> &[f64] {
        &self.st().obs_extra[i]
    }

    pub fn hyper(&self) -> GpHyper {
        self.st().hyper
    }

    /// Make the shared model condition with `hyper`; on change the factor
    /// is invalidated and rebuilt by the next [`SurrogateGuard::sync`].
    /// On a replica handle the change is additionally written through to
    /// the surrogate service when the guard drops, so sibling replicas
    /// converge on the same hypers instead of each selecting locally.
    pub fn ensure_hyper(&mut self, hyper: GpHyper) {
        let log_hyper = self.log_hyper;
        let st = self.st_mut();
        if st.hyper != hyper {
            st.hyper = hyper;
            st.model.set_hyper(hyper);
            st.factored.clear();
            if let Some(journal) = st.journal.as_mut() {
                journal(JournalEvent::Hyper(hyper));
            }
            if log_hyper {
                self.hyper_changed = Some(hyper);
            }
        }
    }

    /// Sibling processes' in-flight points currently leased (empty on a
    /// purely local handle).
    pub fn ambient_len(&self) -> usize {
        self.st().ambient.len()
    }

    /// The `k`-th ambient `(x, lie)` point (cloned: callers extend it into
    /// the factor while the guard stays mutably borrowed).
    pub fn ambient_point(&self, k: usize) -> (Vec<f64>, f64) {
        let (x, lie) = &self.st().ambient[k];
        (x.clone(), *lie)
    }

    /// The conditioning set over the canonical store: the full history if
    /// it fits the window, else the best window/4 observations plus the
    /// most recent remainder (ascending index order).
    pub fn conditioning_set(&self) -> Vec<usize> {
        let st = self.st();
        let n = st.obs_y.len();
        let window = st.hyper.max_history;
        if n <= window {
            return (0..n).collect();
        }
        let keep_best = window / 4;
        let mut by_value: Vec<usize> = (0..n).collect();
        // total_cmp keeps the sort panic-free (and deterministic) even if
        // an evaluator ever reports a NaN measurement.
        let obs_y = &st.obs_y;
        by_value.sort_by(|&a, &b| obs_y[b].total_cmp(&obs_y[a]));
        let mut chosen: Vec<usize> = by_value[..keep_best].to_vec();
        for i in (0..n).rev() {
            if chosen.len() >= window {
                break;
            }
            if !chosen.contains(&i) {
                chosen.push(i);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    /// Grow (or rebuild) the factor to cover exactly the observations in
    /// `idx`, in order: rank-1 appends while `idx` extends the factored
    /// prefix, full rebuild on any reshape. Returns false — factor
    /// cleared — if the kernel matrix is not positive definite.
    pub fn sync(&mut self, idx: &[usize]) -> bool {
        let st = self.st_mut();
        let keep =
            st.factored.len() <= idx.len() && st.factored.iter().zip(idx).all(|(a, b)| a == b);
        if !keep {
            st.model.clear();
            st.factored.clear();
        }
        let start = st.factored.len();
        for &i in &idx[start..] {
            if !st.model.push(&st.obs_x[i], 0.0) {
                st.model.clear();
                st.factored.clear();
                return false;
            }
            st.factored.push(i);
        }
        true
    }

    /// Replace the targets of every factored row (see
    /// [`IncrementalGp::set_targets`]). Length must equal
    /// [`SurrogateGuard::total`].
    pub fn set_targets(&mut self, y: &[f64]) {
        self.st_mut().model.set_targets(y);
    }

    /// Committed + fantasy rows currently factored in.
    pub fn total(&self) -> usize {
        self.st().model.total()
    }

    /// Does `x` fit the store's dimension? Wire-sourced fantasy points
    /// (sibling leases) must be shape-checked before touching the factor
    /// — a mismatch is a refusal, not a panic.
    fn fantasy_dim_ok(&self, x: &[f64]) -> bool {
        !x.is_empty() && self.st().dim().map_or(true, |d| d == x.len())
    }

    /// Condition on an in-flight trial (constant liar). Retracted
    /// automatically when the guard drops, and — on a replica handle —
    /// published as part of this process's lease. Returns false (factor
    /// untouched) for a point whose dimension disagrees with the store.
    pub fn extend_fantasy(&mut self, x: &[f64], lie: f64) -> bool {
        if !self.fantasy_dim_ok(x) {
            return false;
        }
        let ok = self.st_mut().model.extend_fantasy(x, lie);
        if ok && self.log_lease {
            self.own_log.push((x.to_vec(), lie));
        }
        ok
    }

    /// Condition on a fantasy that is *not* this process's own in-flight
    /// trial (sibling lease points — [`SurrogateGuard::ambient_point`]).
    /// Identical math to [`SurrogateGuard::extend_fantasy`] but excluded
    /// from the published lease, so leases never echo back and forth.
    pub fn extend_fantasy_untracked(&mut self, x: &[f64], lie: f64) -> bool {
        if !self.fantasy_dim_ok(x) {
            return false;
        }
        self.st_mut().model.extend_fantasy(x, lie)
    }

    /// Drop fantasy rows now (also happens automatically on guard drop).
    pub fn retract_fantasies(&mut self) {
        self.st_mut().model.retract_fantasies();
    }

    /// Blocked scoring over the factored model (see
    /// [`IncrementalGp::score_into`]).
    pub fn score_into(
        &mut self,
        cand: &[f64],
        c: usize,
        acq_alpha: f64,
        y_best: f64,
        ws: &mut ScoreWorkspace,
    ) {
        self.st_mut().model.score_into(cand, c, acq_alpha, y_best, ws);
    }

    /// K-objective blocked scoring over the factored model: one panel
    /// pass, K target columns (see [`IncrementalGp::score_multi_into`]).
    pub fn score_multi_into(
        &mut self,
        cand: &[f64],
        c: usize,
        targets: &[&[f64]],
        ws: &mut ScoreWorkspace,
    ) {
        self.st_mut().model.score_multi_into(cand, c, targets, ws);
    }

    /// Scoring worker-thread count of the shared model's engine.
    pub fn score_threads(&self) -> usize {
        self.st().model.score_threads()
    }

    /// Set the scoring worker-thread count (clamped to ≥ 1; bit-identical
    /// results for every count — see
    /// [`IncrementalGp::set_score_threads`]). Engine configuration, not
    /// model state: it never travels in a [`SurrogateDelta`], so each
    /// process sharing a served factor picks its own parallelism.
    pub fn set_score_threads(&mut self, threads: usize) {
        self.st_mut().model.set_score_threads(threads);
    }

    /// Scoring arithmetic tier of the shared model's engine.
    pub fn score_tier(&self) -> ScoreTier {
        self.st().model.score_tier()
    }

    /// Select the scoring tier (see [`ScoreTier`]). Like the thread
    /// count, this is per-process engine configuration — the factor and
    /// everything replicated stays f64 regardless.
    pub fn set_score_tier(&mut self, tier: ScoreTier) {
        self.st_mut().model.set_score_tier(tier);
    }

    /// Set the cache-blocking geometry of the scoring kernels (bitwise
    /// output-invariant — see [`IncrementalGp::set_block_spec`]).
    pub fn set_block_spec(&mut self, blocks: crate::util::linalg::BlockSpec) {
        self.st_mut().model.set_block_spec(blocks);
    }
}

impl Drop for SurrogateGuard<'_> {
    fn drop(&mut self) {
        // The factor between asks holds committed observations only;
        // fantasies are strictly per-proposal-batch state.
        if let Some(state) = self.state.as_mut() {
            state.model.retract_fantasies();
        }
        // Release the model lock *before* running the hooks: both
        // perform a network round trip, and a concurrent replica sync
        // acquires connection → model-state in that order.
        self.state = None;
        if self.log_hyper {
            if let Some(hyper) = self.hyper_changed.take() {
                if let Some(hook) = self.hyper_hook.lock().unwrap().as_mut() {
                    hook(hyper);
                }
            }
        }
        if !self.log_lease {
            return;
        }
        if let Some(hook) = self.hook.lock().unwrap().as_mut() {
            hook(&self.own_log);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::NativeGp;
    use crate::util::Rng;

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Vec<(Vec<f64>, f64)> {
        (0..n)
            .map(|_| {
                let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
                let y = (4.0 * x[0]).sin() + 0.2 * x[d - 1];
                (x, y)
            })
            .collect()
    }

    #[test]
    fn tell_queues_and_lock_drains_in_order() {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(1);
        let obs = rows(&mut rng, 5, 3);
        for (x, y) in &obs {
            shared.tell(x.clone(), *y);
        }
        assert_eq!(shared.pending(), 5);
        assert_eq!(shared.len(), 0);
        assert_eq!(shared.total_observations(), 5);
        let g = shared.lock();
        assert_eq!(g.len(), 5);
        for (i, (x, y)) in obs.iter().enumerate() {
            assert_eq!(g.x(i), &x[..]);
            assert_eq!(g.y(i).to_bits(), y.to_bits());
        }
        drop(g);
        assert_eq!(shared.pending(), 0);
        assert_eq!(shared.len(), 5);
    }

    #[test]
    fn drained_model_matches_private_serial_model() {
        let hyper = GpHyper::default();
        let shared = SharedSurrogate::new(hyper);
        let mut rng = Rng::new(2);
        let obs = rows(&mut rng, 20, 4);
        // Tell in two waves with a lock (drain) in between: the factor
        // must be identical to one serial private model either way.
        for (x, y) in &obs[..9] {
            shared.tell(x.clone(), *y);
        }
        drop(shared.lock());
        for (x, y) in &obs[9..] {
            shared.tell(x.clone(), *y);
        }
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert_eq!(idx, (0..20).collect::<Vec<_>>());
        assert!(g.sync(&idx));
        let y_raw: Vec<f64> = (0..20).map(|i| g.y(i)).collect();
        g.set_targets(&y_raw);

        let cand: Vec<f64> = (0..8).map(|_| rng.f64()).collect();
        let mut ws = ScoreWorkspace::default();
        g.score_into(&cand, 2, 1.5, 0.5, &mut ws);

        let x: Vec<Vec<f64>> = obs.iter().map(|(x, _)| x.clone()).collect();
        let oracle = NativeGp::fit(&x, &y_raw, hyper).unwrap();
        let cand_rows: Vec<Vec<f64>> = cand.chunks(4).map(|c| c.to_vec()).collect();
        let post = oracle.predict(&cand_rows);
        for j in 0..2 {
            assert!((ws.mean[j] - post.mean[j]).abs() <= 1e-9);
            assert!((ws.std[j] - post.std[j]).abs() <= 1e-9);
        }
    }

    #[test]
    fn guard_drop_retracts_fantasies() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.1, 0.2], 0.5);
        shared.tell(vec![0.9, 0.8], -0.5);
        {
            let mut g = shared.lock();
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            assert!(g.extend_fantasy(&[0.5, 0.5], 0.0));
            assert_eq!(g.total(), 3);
        } // dropped without explicit retract
        let g = shared.lock();
        assert_eq!(g.total(), 2, "fantasy survived the guard");
    }

    #[test]
    fn reset_clears_queue_and_store() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.3], 1.0);
        drop(shared.lock());
        shared.tell(vec![0.6], 2.0);
        shared.reset();
        assert_eq!(shared.pending(), 0);
        assert_eq!(shared.len(), 0);
        assert_eq!(shared.total_observations(), 0);
        // Usable after reset (dimension may change).
        shared.tell(vec![0.1, 0.9], 3.0);
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 1);
    }

    #[test]
    fn set_hyper_invalidates_and_rebuilds() {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(3);
        for (x, y) in rows(&mut rng, 6, 2) {
            shared.tell(x, y);
        }
        drop(shared.lock()); // drain + eager factor
        let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
        shared.set_hyper(new);
        assert_eq!(shared.hyper(), new);
        let mut g = shared.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx), "rebuild under new hypers failed");
        assert_eq!(g.total(), 6);
    }

    #[test]
    fn eager_factoring_can_be_disabled() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.set_eager_factoring(false);
        shared.tell(vec![0.1, 0.2], 1.0);
        shared.tell(vec![0.9, 0.5], 2.0);
        let mut g = shared.lock();
        assert_eq!(g.len(), 2, "store still records everything");
        assert_eq!(g.total(), 0, "no eager appends while disabled");
        // The factor is still available on demand.
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 2);
    }

    #[test]
    fn handles_address_one_model() {
        let a = SharedSurrogate::new(GpHyper::default());
        let b = a.clone();
        a.tell(vec![0.2], 1.0);
        b.tell(vec![0.8], 2.0);
        assert_eq!(a.total_observations(), 2);
        let g = b.lock();
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn delta_round_trip_is_bitwise_and_suffix_sized() {
        let hyper = GpHyper::default();
        let mut rng = Rng::new(5);
        let obs = rows(&mut rng, 24, 4);

        let authority = SharedSurrogate::new(hyper);
        for (x, y) in &obs[..20] {
            authority.tell(x.clone(), *y);
        }
        let replica = SharedSurrogate::new(hyper);
        let full = authority.export_delta(0).unwrap();
        assert_eq!(full.total_n, 20);
        assert_eq!(
            full.factor.as_ref().unwrap().len(),
            packed_len(20),
            "full export carries the whole packed factor"
        );
        assert!(replica.import_delta(&full));
        assert_eq!(replica.len(), 20);

        // Δn = 4 catch-up: only the suffix rows travel.
        for (x, y) in &obs[20..] {
            authority.tell(x.clone(), *y);
        }
        let delta = authority.export_delta(20).unwrap();
        assert_eq!(delta.rows.len(), 4);
        assert_eq!(
            delta.factor.as_ref().unwrap().len(),
            packed_len(24) - packed_len(20),
            "catch-up export carries only the factor suffix"
        );
        // A replica ahead of its request is rejected; a stale delta too.
        assert!(authority.export_delta(25).is_none());
        assert!(!replica.import_delta(&SurrogateDelta { from_n: 3, ..delta.clone() }));
        assert!(replica.import_delta(&delta));

        // Identical store and factor ⇒ bitwise-identical posterior.
        let cand: Vec<f64> = (0..2 * 4).map(|_| rng.f64()).collect();
        let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
        for (h, ws) in [(&authority, &mut wa), (&replica, &mut wb)] {
            let mut g = h.lock();
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            let y: Vec<f64> = idx.iter().map(|&i| g.y(i)).collect();
            g.set_targets(&y);
            g.score_into(&cand, 2, 1.5, 0.0, ws);
        }
        for j in 0..2 {
            assert_eq!(wa.mean[j].to_bits(), wb.mean[j].to_bits());
            assert_eq!(wa.std[j].to_bits(), wb.std[j].to_bits());
        }
    }

    #[test]
    fn delta_without_factor_still_replicates_through_drain() {
        // Eager factoring off on the authority: the export carries rows
        // only and the replica recomputes — same store, same posterior
        // after a local sync.
        let hyper = GpHyper::default();
        let mut rng = Rng::new(6);
        let authority = SharedSurrogate::new(hyper);
        authority.set_eager_factoring(false);
        for (x, y) in rows(&mut rng, 10, 3) {
            authority.tell(x, y);
        }
        let delta = authority.export_delta(0).unwrap();
        assert!(delta.factor.is_none(), "no factor without eager factoring");
        let replica = SharedSurrogate::new(hyper);
        assert!(replica.import_delta(&delta));
        assert_eq!(replica.len(), 10);
        let mut g = replica.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 10);
    }

    #[test]
    fn hyper_mismatch_delta_adopts_and_rebuilds() {
        let authority = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(7);
        for (x, y) in rows(&mut rng, 6, 2) {
            authority.tell(x, y);
        }
        let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
        authority.set_hyper(new);
        let replica = SharedSurrogate::new(GpHyper::default());
        let delta = authority.export_delta(0).unwrap();
        assert_eq!(delta.hyper, new);
        assert!(replica.import_delta(&delta));
        assert_eq!(replica.hyper(), new, "replica adopts the authority's hypers");
        let mut g = replica.lock();
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 6);
    }

    #[test]
    fn ambient_points_surface_and_extend_untracked() {
        let replica = SharedSurrogate::new(GpHyper::default());
        replica.tell(vec![0.2, 0.2], 1.0);
        drop(replica.lock());
        let delta = SurrogateDelta {
            from_n: 1,
            total_n: 1,
            hyper: GpHyper::default(),
            rows: Vec::new(),
            extras: Vec::new(),
            factor: Some(Vec::new()),
            leases: vec![(vec![0.7, 0.7], 0.0)],
        };
        assert!(replica.import_delta(&delta));
        let mut g = replica.lock();
        assert_eq!(g.ambient_len(), 1);
        let (x, lie) = g.ambient_point(0);
        assert_eq!(x, vec![0.7, 0.7]);
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert!(g.extend_fantasy_untracked(&x, lie));
        assert_eq!(g.total(), 2, "ambient point conditioned as a fantasy");
        drop(g);
        let g = replica.lock();
        assert_eq!(g.total(), 1, "ambient fantasy retracted with the guard");
    }

    #[test]
    fn mismatched_dimension_rows_are_dropped_not_fatal() {
        // The drain queue of a surrogate service is fed by the network:
        // a tuner attached with the wrong search space must degrade
        // itself, not panic the daemon (and poison the fleet's mutex).
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.2, 0.4], 1.0);
        shared.tell(vec![0.1, 0.2, 0.3], 2.0); // wrong space: dropped
        shared.tell(vec![], 3.0); // empty: dropped
        shared.tell(vec![0.6, 0.8], 4.0);
        let mut g = shared.lock();
        assert_eq!(g.len(), 2, "mismatched rows must be dropped, not stored");
        assert!(!g.extend_fantasy(&[0.5], 0.0), "mismatched fantasy refused");
        assert!(!g.extend_fantasy_untracked(&[], 0.0));
        let idx = g.conditioning_set();
        assert!(g.sync(&idx));
        assert_eq!(g.total(), 2, "the factor holds only well-shaped rows");
    }

    #[test]
    fn tell_multi_columns_survive_drain_and_delta() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell_multi(vec![0.2, 0.4], vec![1.0, -7.5]);
        shared.tell(vec![0.6, 0.8], 2.0); // single-objective row mixes in
        shared.tell_multi(vec![0.1, 0.9], vec![3.0, f64::NAN]); // degraded column
        shared.tell_multi(vec![0.5, 0.5], Vec::new()); // no columns: dropped
        let g = shared.lock();
        assert_eq!(g.len(), 3);
        assert_eq!(g.y(0), 1.0);
        assert_eq!(g.y_extras(0), &[-7.5]);
        assert!(g.y_extras(1).is_empty());
        assert!(g.y_extras(2)[0].is_nan());
        drop(g);

        // Columns replicate through the delta plane.
        let delta = shared.export_delta(0).unwrap();
        assert_eq!(delta.extras.len(), 3);
        assert_eq!(delta.extras[0], vec![-7.5]);
        assert!(delta.extras[1].is_empty());
        let replica = SharedSurrogate::new(GpHyper::default());
        assert!(replica.import_delta(&delta));
        let g = replica.lock();
        assert_eq!(g.len(), 3);
        assert_eq!(g.y_extras(0), &[-7.5]);
        assert!(g.y_extras(2)[0].is_nan());
    }

    #[test]
    fn v2_delta_without_extras_imports_single_objective() {
        // A delta from a protocol-v2 authority has no extras vector at
        // all; every imported row is single-objective.
        let authority = SharedSurrogate::new(GpHyper::default());
        authority.tell(vec![0.3, 0.3], 1.0);
        authority.tell(vec![0.7, 0.7], 2.0);
        let mut delta = authority.export_delta(0).unwrap();
        delta.extras = Vec::new();
        let replica = SharedSurrogate::new(GpHyper::default());
        assert!(replica.import_delta(&delta));
        let g = replica.lock();
        assert_eq!(g.len(), 2);
        assert!(g.y_extras(0).is_empty());
        assert!(g.y_extras(1).is_empty());
        drop(g);
        // Misaligned extras are rejected outright.
        let authority2 = SharedSurrogate::new(GpHyper::default());
        authority2.tell(vec![0.1, 0.1], 0.5);
        let mut bad = authority2.export_delta(0).unwrap();
        bad.extras = vec![vec![1.0], vec![2.0]];
        let replica2 = SharedSurrogate::new(GpHyper::default());
        assert!(!replica2.import_delta(&bad));
    }

    #[test]
    fn hyper_hook_fires_once_per_changed_batch() {
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.2, 0.2], 1.0);
        let published = Arc::new(Mutex::new(Vec::new()));
        let p2 = Arc::clone(&published);
        shared.set_hyper_hook(move |h| p2.lock().unwrap().push(h));
        // A guard that never touches hypers publishes nothing.
        drop(shared.lock());
        assert!(published.lock().unwrap().is_empty());
        // An in-guard change publishes exactly once, after the drop.
        let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
        {
            let mut g = shared.lock();
            g.ensure_hyper(new);
            g.ensure_hyper(new); // unchanged: no second record
            assert!(published.lock().unwrap().is_empty(), "hook ran under the lock");
        }
        assert_eq!(*published.lock().unwrap(), vec![new]);
        // set_hyper goes through a guard, so it publishes too.
        let newer = GpHyper { lengthscale: 0.8, ..GpHyper::default() };
        shared.set_hyper(newer);
        assert_eq!(*published.lock().unwrap(), vec![new, newer]);
    }

    #[test]
    fn lease_hook_reports_own_fantasies_only() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let shared = SharedSurrogate::new(GpHyper::default());
        shared.tell(vec![0.1, 0.1], 0.0);
        drop(shared.lock());
        let published = Arc::new(Mutex::new(Vec::new()));
        let calls = Arc::new(AtomicUsize::new(0));
        let (p2, c2) = (Arc::clone(&published), Arc::clone(&calls));
        shared.set_lease_hook(move |points| {
            c2.fetch_add(1, Ordering::SeqCst);
            *p2.lock().unwrap() = points.to_vec();
        });
        {
            let mut g = shared.lock();
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            assert!(g.extend_fantasy(&[0.5, 0.5], 0.0));
            assert!(g.extend_fantasy_untracked(&[0.9, 0.9], 0.0));
        }
        assert_eq!(calls.load(Ordering::SeqCst), 1, "hook fires once per guard drop");
        let got = published.lock().unwrap().clone();
        assert_eq!(got.len(), 1, "untracked fantasies stay out of the lease");
        assert_eq!(got[0].0, vec![0.5, 0.5]);
        // A fantasy-free batch publishes an empty lease (retract signal).
        drop(shared.lock());
        assert_eq!(calls.load(Ordering::SeqCst), 2);
        assert!(published.lock().unwrap().is_empty());
    }
}
