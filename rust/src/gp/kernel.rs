//! Covariance kernels and hyperparameters for the surrogate subsystem.
//!
//! Every surrogate implementation — the incremental engine model
//! ([`super::incremental`]), the exact oracle ([`super::native`]) and the
//! AOT HLO artifact (`runtime::GpSurrogate`) — is parameterised by the
//! same [`GpHyper`] value, so kernel choice, lengthscale and the
//! conditioning-window size can never silently disagree between paths.
//!
//! Kernels are isotropic (functions of squared distance only), exposed
//! two ways: a [`Kernel`] trait object for extensibility, and the
//! enum-dispatched [`eval_sqdist`] used on hot paths (no vtable call).

use crate::util::linalg::{chol_packed, packed_idx, solve_lower_packed_inplace, sqdist};

/// Conditioning-window bound shared with the AOT artifact: the HLO graph
/// is compiled for exactly this many (padded/masked) history slots — see
/// `N_PAD` in `python/compile/model.py`. Native paths default to the same
/// window so the artifact and oracle stay interchangeable.
pub const ARTIFACT_MAX_HISTORY: usize = 64;

/// Sentinel for [`GpHyper::max_history`] meaning "no conditioning window":
/// the surrogate conditions on the full history. Native paths only — the
/// AOT artifact's compiled shape contract (`n_pad`) rejects it. Set via
/// `BayesOpt::with_history_window(None)`.
pub const UNBOUNDED_HISTORY: usize = usize::MAX;

/// Which covariance kernel the surrogate uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Squared-exponential: `sv * exp(-d² / 2ℓ²)`. The only kernel the
    /// AOT HLO artifact implements (L1 Pallas RBF kernel).
    Rbf,
    /// Matérn-5/2: `sv * (1 + s + s²/3) * exp(-s)`, `s = √5·d/ℓ`. Native
    /// paths only; rougher sample paths than RBF.
    Matern52,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Rbf => "rbf",
            KernelKind::Matern52 => "matern52",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_lowercase().as_str() {
            "rbf" | "se" | "squared-exponential" | "gaussian" => Some(KernelKind::Rbf),
            "matern52" | "matern-5/2" | "matern" | "m52" => Some(KernelKind::Matern52),
            _ => None,
        }
    }

    /// Trait-object view (for generic code; hot paths use [`eval_sqdist`]).
    pub fn kernel(self) -> &'static dyn Kernel {
        match self {
            KernelKind::Rbf => &RbfKernel,
            KernelKind::Matern52 => &Matern52Kernel,
        }
    }

    pub fn all() -> [KernelKind; 2] {
        [KernelKind::Rbf, KernelKind::Matern52]
    }
}

/// GP hyperparameters (fixed per tuning run, as in the paper).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpHyper {
    /// Lengthscale in normalised [0,1] input space.
    pub lengthscale: f64,
    /// Signal variance (y is standardised, so ~1).
    pub signal_var: f64,
    /// Observation noise variance.
    pub noise_var: f64,
    /// Covariance kernel.
    pub kernel: KernelKind,
    /// Most recent/best history points the surrogate conditions on.
    ///
    /// The window exists **only for AOT N_PAD parity on the artifact
    /// path**: the compiled HLO graph has exactly `n_pad` (padded/masked)
    /// history slots, so every surrogate path defaults to the same
    /// [`ARTIFACT_MAX_HISTORY`] bound to stay interchangeable with it —
    /// `runtime::GpSurrogate` rejects hypers whose window exceeds its
    /// compiled `n_pad`. It is *not* a cost cap: with O(n²) rank-1
    /// appends ([`super::IncrementalGp`]) the native path no longer needs
    /// a window for fit-cost reasons, and native-only runs may lift it
    /// entirely by setting [`UNBOUNDED_HISTORY`]
    /// (`BayesOpt::with_history_window(None)`).
    pub max_history: usize,
}

impl Default for GpHyper {
    fn default() -> Self {
        // noise_var matches the AOT artifact's conditioning floor (the
        // graph clamps nv to >= 1e-3 — see python/compile/model.py), so
        // the native oracle and the HLO path solve the same system.
        GpHyper {
            lengthscale: 0.2,
            signal_var: 1.0,
            noise_var: 1e-3,
            kernel: KernelKind::Rbf,
            max_history: ARTIFACT_MAX_HISTORY,
        }
    }
}

/// An isotropic covariance function.
pub trait Kernel {
    /// Covariance as a function of *squared* euclidean distance.
    fn from_sqdist(&self, d2: f64, h: &GpHyper) -> f64;

    /// Covariance between two points.
    fn eval(&self, a: &[f64], b: &[f64], h: &GpHyper) -> f64 {
        self.from_sqdist(sqdist(a, b), h)
    }

    /// `k(x, x)` — the prior variance at any point.
    fn diag(&self, h: &GpHyper) -> f64 {
        h.signal_var
    }

    fn name(&self) -> &'static str;
}

/// Squared-exponential kernel.
pub struct RbfKernel;

impl Kernel for RbfKernel {
    #[inline]
    fn from_sqdist(&self, d2: f64, h: &GpHyper) -> f64 {
        h.signal_var * (-0.5 * d2 / (h.lengthscale * h.lengthscale)).exp()
    }

    fn name(&self) -> &'static str {
        KernelKind::Rbf.name()
    }
}

/// Matérn-5/2 kernel.
pub struct Matern52Kernel;

impl Kernel for Matern52Kernel {
    #[inline]
    fn from_sqdist(&self, d2: f64, h: &GpHyper) -> f64 {
        let s = (5.0 * d2.max(0.0)).sqrt() / h.lengthscale;
        h.signal_var * (1.0 + s + s * s / 3.0) * (-s).exp()
    }

    fn name(&self) -> &'static str {
        KernelKind::Matern52.name()
    }
}

/// Enum-dispatched kernel evaluation from squared distance — the form the
/// hot paths use so the compiler can inline per-kind (no vtable).
#[inline]
pub fn eval_sqdist(kind: KernelKind, d2: f64, h: &GpHyper) -> f64 {
    match kind {
        KernelKind::Rbf => RbfKernel.from_sqdist(d2, h),
        KernelKind::Matern52 => Matern52Kernel.from_sqdist(d2, h),
    }
}

/// f32 twin of [`eval_sqdist`] for the fast scoring tier
/// (`gp::ScoreTier::F32`): the same closed forms evaluated in f32
/// arithmetic over downcast hyperparameters. Acquisition *ranking* only —
/// the f64 path stays the pinned oracle.
#[inline]
pub fn eval_sqdist_f32(kind: KernelKind, d2: f32, h: &GpHyper) -> f32 {
    let sv = h.signal_var as f32;
    let ls = h.lengthscale as f32;
    match kind {
        KernelKind::Rbf => sv * (-0.5 * d2 / (ls * ls)).exp(),
        KernelKind::Matern52 => {
            let s = (5.0 * d2.max(0.0)).sqrt() / ls;
            sv * (1.0 + s + s * s / 3.0) * (-s).exp()
        }
    }
}

// ---------------------------------------------------------------------------
// Lengthscale selection by log marginal likelihood.
// ---------------------------------------------------------------------------

/// Candidate lengthscales for [`select_lengthscale`] (unit-cube inputs, so
/// this brackets "almost white" to "almost linear").
pub const LENGTHSCALE_GRID: [f64; 6] = [0.05, 0.1, 0.2, 0.3, 0.5, 0.8];

/// Log marginal likelihood `log p(y | X, hyper)` of the exact GP:
/// `-½ yᵀK⁻¹y − Σᵢ log Lᵢᵢ − (n/2) log 2π`. `None` if the kernel matrix
/// is not positive definite or the data is empty.
pub fn log_marginal_likelihood(x: &[Vec<f64>], y: &[f64], hyper: &GpHyper) -> Option<f64> {
    let n = x.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let mut l = vec![0.0; n * (n + 1) / 2];
    for i in 0..n {
        for j in 0..=i {
            let mut v = eval_sqdist(hyper.kernel, sqdist(&x[i], &x[j]), hyper);
            if i == j {
                v += hyper.noise_var;
            }
            l[packed_idx(i, j)] = v;
        }
    }
    if !chol_packed(&mut l, n) {
        return None;
    }
    // yᵀK⁻¹y = ‖L⁻¹y‖², so a single forward solve suffices.
    let mut a = y.to_vec();
    solve_lower_packed_inplace(&l, n, &mut a);
    let quad: f64 = a.iter().map(|v| v * v).sum();
    let logdet: f64 = (0..n).map(|i| l[packed_idx(i, i)].ln()).sum();
    Some(-0.5 * quad - logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln())
}

/// Pick the [`LENGTHSCALE_GRID`] lengthscale maximising the log marginal
/// likelihood on `(x, y)`, holding every other hyperparameter fixed.
/// Returns `base` unchanged if no grid point yields a PD kernel matrix.
pub fn select_lengthscale(x: &[Vec<f64>], y: &[f64], base: GpHyper) -> GpHyper {
    let mut best = base;
    let mut best_lml = f64::NEG_INFINITY;
    for &ls in &LENGTHSCALE_GRID {
        let h = GpHyper { lengthscale: ls, ..base };
        if let Some(v) = log_marginal_likelihood(x, y, &h) {
            if v > best_lml {
                best_lml = v;
                best = h;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_matches_closed_form() {
        let h = GpHyper { lengthscale: 0.5, signal_var: 2.0, ..Default::default() };
        let a = [0.0, 0.0];
        let b = [0.3, 0.0];
        let want = 2.0 * f64::exp(-0.5 * 0.09 / 0.25);
        assert!((RbfKernel.eval(&a, &b, &h) - want).abs() < 1e-15);
        assert!((eval_sqdist(KernelKind::Rbf, 0.09, &h) - want).abs() < 1e-15);
    }

    #[test]
    fn matern_matches_closed_form() {
        let h = GpHyper { lengthscale: 0.4, signal_var: 1.5, ..Default::default() };
        let r: f64 = 0.25;
        let s = 5.0f64.sqrt() * r / 0.4;
        let want = 1.5 * (1.0 + s + s * s / 3.0) * (-s).exp();
        assert!((eval_sqdist(KernelKind::Matern52, r * r, &h) - want).abs() < 1e-12);
    }

    #[test]
    fn kernels_peak_at_zero_and_decay() {
        for kind in KernelKind::all() {
            let h = GpHyper::default();
            let at0 = eval_sqdist(kind, 0.0, &h);
            assert!((at0 - h.signal_var).abs() < 1e-15, "{}: k(0)={at0}", kind.name());
            assert!((kind.kernel().diag(&h) - h.signal_var).abs() < 1e-15);
            let mut prev = at0;
            for i in 1..20 {
                let d = i as f64 * 0.1;
                let v = eval_sqdist(kind, d * d, &h);
                assert!(v < prev, "{} not decreasing at d={d}", kind.name());
                assert!(v > 0.0);
                prev = v;
            }
        }
    }

    #[test]
    fn f32_eval_tracks_f64_closely() {
        let h = GpHyper { lengthscale: 0.3, signal_var: 1.2, ..Default::default() };
        for kind in KernelKind::all() {
            for i in 0..30 {
                let d2 = i as f64 * 0.07;
                let a = eval_sqdist(kind, d2, &h);
                let b = eval_sqdist_f32(kind, d2 as f32, &h) as f64;
                assert!((a - b).abs() < 1e-5, "{} at d2={d2}: {a} vs {b}", kind.name());
            }
        }
    }

    #[test]
    fn kind_parse_round_trip() {
        for kind in KernelKind::all() {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("matern-5/2"), Some(KernelKind::Matern52));
        assert_eq!(KernelKind::parse("nope"), None);
    }

    #[test]
    fn lml_prefers_the_generating_lengthscale_regime() {
        // Smooth, slowly-varying data: a long lengthscale must beat the
        // near-white 0.05 one.
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64 / 19.0]).collect();
        let y: Vec<f64> = x.iter().map(|p| p[0] - 0.5).collect();
        let h = GpHyper { noise_var: 1e-2, ..Default::default() };
        let smooth = log_marginal_likelihood(&x, &y, &GpHyper { lengthscale: 0.8, ..h }).unwrap();
        let rough = log_marginal_likelihood(&x, &y, &GpHyper { lengthscale: 0.05, ..h }).unwrap();
        assert!(smooth > rough, "smooth {smooth} vs rough {rough}");
    }

    #[test]
    fn select_lengthscale_is_argmax_over_grid() {
        let x: Vec<Vec<f64>> = (0..15).map(|i| vec![(i as f64 * 0.618) % 1.0]).collect();
        let y: Vec<f64> = x.iter().map(|p| (4.0 * p[0]).sin()).collect();
        for kind in KernelKind::all() {
            let base = GpHyper { kernel: kind, ..Default::default() };
            let picked = select_lengthscale(&x, &y, base);
            assert!(LENGTHSCALE_GRID.contains(&picked.lengthscale));
            let best = log_marginal_likelihood(&x, &y, &picked).unwrap();
            for &ls in &LENGTHSCALE_GRID {
                let v = log_marginal_likelihood(&x, &y, &GpHyper { lengthscale: ls, ..base })
                    .unwrap();
                assert!(v <= best + 1e-12, "{}: ls {ls} beats selected", kind.name());
            }
        }
    }

    #[test]
    fn select_lengthscale_preserves_other_hypers() {
        let x = vec![vec![0.1], vec![0.9]];
        let y = vec![0.0, 1.0];
        let base = GpHyper {
            signal_var: 3.0,
            noise_var: 0.2,
            kernel: KernelKind::Matern52,
            max_history: 32,
            ..Default::default()
        };
        let picked = select_lengthscale(&x, &y, base);
        assert_eq!(picked.signal_var, 3.0);
        assert_eq!(picked.noise_var, 0.2);
        assert_eq!(picked.kernel, KernelKind::Matern52);
        assert_eq!(picked.max_history, 32);
    }
}
