//! Gaussian-process surrogate + acquisition for the BO engine.
//!
//! Production path: the AOT-compiled HLO artifact (`runtime::GpArtifact`),
//! with the L1 Pallas RBF kernel inside. Oracle/fallback path: the exact
//! native implementation in `native`. Both implement `Surrogate`, so the
//! BO engine is generic over them and the two are cross-checked in
//! integration tests.

pub mod native;

pub use native::{GpHyper, NativeGp, Posterior};

/// A surrogate model the BO engine can query.
pub trait Surrogate {
    /// Fit on normalised inputs/standardised outputs and return the
    /// posterior (mean, std) plus SMSego gain at each candidate.
    ///
    /// `y_best` and `acq_alpha` parameterise the acquisition:
    /// gain = (mu + alpha * std) - y_best.
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> anyhow::Result<Scores>;
}

/// Posterior + acquisition at candidate points.
#[derive(Debug, Clone)]
pub struct Scores {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub gain: Vec<f64>,
}

/// Surrogate backed by the exact native GP.
#[derive(Default)]
pub struct NativeSurrogate;

impl Surrogate for NativeSurrogate {
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> anyhow::Result<Scores> {
        let gp = NativeGp::fit(x, y, hyper)
            .ok_or_else(|| anyhow::anyhow!("kernel matrix not positive definite"))?;
        let post = gp.predict(cand);
        let gain = post
            .mean
            .iter()
            .zip(&post.std)
            .map(|(m, s)| (m + acq_alpha * s) - y_best)
            .collect();
        Ok(Scores { mean: post.mean, std: post.std, gain })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_surrogate_scores() {
        let x = vec![vec![0.1, 0.1], vec![0.9, 0.9]];
        let y = vec![0.0, 1.0];
        let cand = vec![vec![0.9, 0.88], vec![0.5, 0.5]];
        let mut s = NativeSurrogate;
        let scores = s.fit_score(&x, &y, &cand, GpHyper::default(), 1.0, 1.0).unwrap();
        assert_eq!(scores.gain.len(), 2);
        // near the best observed point: mean ~1, low std
        assert!(scores.mean[0] > 0.7);
        // acquisition math
        for i in 0..2 {
            let want = scores.mean[i] + scores.std[i] - 1.0;
            assert!((scores.gain[i] - want).abs() < 1e-12);
        }
    }
}
