//! The surrogate subsystem: kernels, the incremental engine model, the
//! shared concurrent handle, the exact oracle, and the surrogate
//! abstraction the BO engine scores through.
//!
//! Five roles, five homes:
//!
//! - [`kernel`] — covariance kernels (RBF, Matérn-5/2) behind the
//!   [`Kernel`] trait, the shared [`GpHyper`] hyperparameter bundle
//!   (kernel kind, lengthscale, noise, **conditioning window**), and
//!   log-marginal-likelihood lengthscale selection. Every surrogate path
//!   is parameterised by the same `GpHyper`, so the native and artifact
//!   stacks cannot silently disagree on kernel or window.
//! - [`incremental`] — [`IncrementalGp`], the persistent model the BO
//!   engine keeps across the run: O(n²) rank-1 Cholesky append per
//!   `tell`, exact extend/retract for constant-liar fantasies per `ask`,
//!   and a blocked, optionally multi-threaded scoring engine over the
//!   candidate pool (cache-tiled kernels, a [`ScoreTier::F32`] fast
//!   ranking tier, and buffers that never grow once warmed up).
//! - [`shared`] — [`SharedSurrogate`], the concurrent handle that lets
//!   many producers (an evaluator pool, several sessions, remote-daemon
//!   reporting loops) condition **one** incremental factor: tells enqueue
//!   without blocking, the next ask drains them in observation order and
//!   scores through an exclusive [`SurrogateGuard`]. The handle contract
//!   is the [`SurrogateHandle`] trait, and [`SurrogateDelta`] is the
//!   unit a served factor is replicated by.
//! - [`replica`] — [`RemoteSurrogate`], the same handle contract against
//!   a factor *served over TCP* by a surrogate service (`server` hosts
//!   the authoritative [`SharedSurrogate`]): separate tuner processes —
//!   or hosts — condition one model, with constant-liar leases standing
//!   in for cross-process fantasies.
//! - [`native`] — [`NativeGp`], the exact from-scratch solve. It is the
//!   *correctness oracle*: the incremental model reproduces it bit-for-bit
//!   (pinned by `rust/tests/surrogate_incremental.rs`) and the AOT HLO
//!   artifact is validated against it (`rust/tests/artifact_gp.rs`).
//! - [`sharded`] — [`ShardedGp`], the scaling tier: the observation
//!   history partitioned into locally-exact shards (each one an
//!   [`IncrementalGp`]) under a leaf-capacity KD router, blended
//!   product-of-experts style at ask time, so a tell costs O(cap²)
//!   regardless of total n. A single-shard configuration delegates
//!   verbatim and is bit-identical to the exact engine.
//! - `runtime::gp` — the AOT-compiled HLO artifact (L2 JAX graph with the
//!   L1 Pallas RBF kernel) executed via PJRT; the production scoring path
//!   when artifacts are built.
//!
//! The [`Surrogate`] trait is the engine-facing seam. Implementations
//! that refit in one fused call (the HLO artifact) expose `fit_score`;
//! implementations backed by the native stack opt into the engine's
//! incremental session via [`Surrogate::use_engine_incremental`], in
//! which case the engine conditions the persistent [`IncrementalGp`]
//! borrowed through its [`SharedSurrogate`] handle (same `GpHyper`) and
//! `fit_score` is bypassed on the hot path.

pub mod incremental;
pub mod kernel;
pub mod native;
pub mod replica;
pub mod shared;
pub mod sharded;

pub use crate::util::linalg::BlockSpec;
pub use incremental::{IncrementalGp, ScoreTier, ScoreWorkspace};
pub use kernel::{
    eval_sqdist, select_lengthscale, GpHyper, Kernel, KernelKind, ARTIFACT_MAX_HISTORY,
    LENGTHSCALE_GRID, UNBOUNDED_HISTORY,
};
pub use native::{NativeGp, Posterior};
pub use replica::RemoteSurrogate;
pub use shared::{SharedSurrogate, SurrogateDelta, SurrogateGuard, SurrogateHandle};
pub use sharded::{ShardedGp, DEFAULT_BLEND_K, DEFAULT_SHARD_CAP};

/// A surrogate model the BO engine can query.
pub trait Surrogate {
    /// Fit on normalised inputs/standardised outputs and return the
    /// posterior (mean, std) plus SMSego gain at each candidate.
    ///
    /// `y_best` and `acq_alpha` parameterise the acquisition:
    /// gain = (mu + alpha * std) - y_best.
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> anyhow::Result<Scores>;

    /// Whether the BO engine should bypass `fit_score` and drive its own
    /// persistent [`IncrementalGp`] (built from the same [`GpHyper`] it
    /// would pass here). True for the native stack, where refitting from
    /// scratch every ask wastes O(n³); false for the AOT artifact, whose
    /// compiled graph performs the whole fit+score in one fused call.
    fn use_engine_incremental(&self) -> bool {
        false
    }
}

/// Posterior + acquisition at candidate points.
#[derive(Debug, Clone)]
pub struct Scores {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub gain: Vec<f64>,
}

fn native_fit_score(
    x: &[Vec<f64>],
    y: &[f64],
    cand: &[Vec<f64>],
    hyper: GpHyper,
    acq_alpha: f64,
    y_best: f64,
) -> anyhow::Result<Scores> {
    let gp = NativeGp::fit(x, y, hyper)
        .ok_or_else(|| anyhow::anyhow!("kernel matrix not positive definite"))?;
    let post = gp.predict(cand);
    let gain = post
        .mean
        .iter()
        .zip(&post.std)
        .map(|(m, s)| (m + acq_alpha * s) - y_best)
        .collect();
    Ok(Scores { mean: post.mean, std: post.std, gain })
}

/// Surrogate backed by the native GP stack. The engine runs this through
/// its incremental session; `fit_score` remains available as the exact
/// scratch-refit entry point (benches, oracle comparisons).
#[derive(Default)]
pub struct NativeSurrogate;

impl Surrogate for NativeSurrogate {
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> anyhow::Result<Scores> {
        native_fit_score(x, y, cand, hyper, acq_alpha, y_best)
    }

    fn use_engine_incremental(&self) -> bool {
        true
    }
}

/// The pre-refactor reference path: same math as [`NativeSurrogate`] but
/// opting *out* of the engine's incremental session, so every ask refits
/// the exact GP from scratch through `fit_score`. Exists for the
/// serial-trajectory equivalence test (incremental and scratch engines
/// must propose identical configurations) and as a debugging fallback.
#[derive(Default)]
pub struct ExactRefitSurrogate;

impl Surrogate for ExactRefitSurrogate {
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> anyhow::Result<Scores> {
        native_fit_score(x, y, cand, hyper, acq_alpha, y_best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_surrogate_scores() {
        let x = vec![vec![0.1, 0.1], vec![0.9, 0.9]];
        let y = vec![0.0, 1.0];
        let cand = vec![vec![0.9, 0.88], vec![0.5, 0.5]];
        let mut s = NativeSurrogate;
        let scores = s.fit_score(&x, &y, &cand, GpHyper::default(), 1.0, 1.0).unwrap();
        assert_eq!(scores.gain.len(), 2);
        // near the best observed point: mean ~1, low std
        assert!(scores.mean[0] > 0.7);
        // acquisition math
        for i in 0..2 {
            let want = scores.mean[i] + scores.std[i] - 1.0;
            assert!((scores.gain[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn exact_refit_matches_native_surrogate_bitwise() {
        let x = vec![vec![0.2, 0.3], vec![0.7, 0.6], vec![0.4, 0.9]];
        let y = vec![0.1, 0.9, -0.4];
        let cand = vec![vec![0.5, 0.5], vec![0.1, 0.8]];
        let a = NativeSurrogate.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 0.9).unwrap();
        let b =
            ExactRefitSurrogate.fit_score(&x, &y, &cand, GpHyper::default(), 1.5, 0.9).unwrap();
        for i in 0..cand.len() {
            assert_eq!(a.mean[i].to_bits(), b.mean[i].to_bits());
            assert_eq!(a.std[i].to_bits(), b.std[i].to_bits());
            assert_eq!(a.gain[i].to_bits(), b.gain[i].to_bits());
        }
    }

    #[test]
    fn incremental_opt_in_flags() {
        assert!(NativeSurrogate.use_engine_incremental());
        assert!(!ExactRefitSurrogate.use_engine_incremental());
    }
}
