//! Evaluation history — the paper's "data acquisition module" (Fig. 4) —
//! plus [`Measurement`], the structured result of evaluating one trial.
//!
//! Every algorithm engine consumes and extends the same global history of
//! `(configuration, measurement)` records; the figure harnesses read it
//! back to produce tuning curves (Fig. 5), pairplots (Fig. 7) and the
//! range-coverage table (Table 2). Histories persist as JSONL so long
//! sweeps can resume and the paper artifacts are regenerable from disk.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::evaluator::Objective;
use crate::space::{Config, SearchSpace};
use crate::util::{Json, Rng};

/// The structured outcome of measuring one trial on a system under test.
///
/// This replaces the bare `f64` the propose/observe API passed around: a
/// measurement knows what its value means ([`Objective`]), what it cost to
/// obtain (wall-clock seconds — the quantity a parallel `TuningSession`
/// balances across evaluators), and can carry optional numeric metadata
/// (e.g. per-op timings from a profiling target).
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    /// Objective value (higher is better; the engines maximise this).
    pub value: f64,
    /// What `value` measures.
    pub objective: Objective,
    /// Wall-clock cost of obtaining the measurement, in seconds.
    pub cost_s: f64,
    /// Optional named metadata (per-op timings, counters, ...).
    pub metadata: Vec<(String, f64)>,
}

impl Measurement {
    pub fn new(value: f64) -> Measurement {
        Measurement {
            value,
            objective: Objective::default(),
            cost_s: 0.0,
            metadata: Vec::new(),
        }
    }

    pub fn with_objective(mut self, objective: Objective) -> Measurement {
        self.objective = objective;
        self
    }

    pub fn with_cost_s(mut self, cost_s: f64) -> Measurement {
        self.cost_s = cost_s;
        self
    }

    pub fn with_metadata(mut self, key: &str, value: f64) -> Measurement {
        self.metadata.push((key.to_string(), value));
        self
    }

    pub fn is_finite(&self) -> bool {
        self.value.is_finite()
    }
}

/// One recorded evaluation: a configuration and its measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    pub config: Config,
    pub value: f64,
    /// Which tuning iteration produced this point (0-based, completion
    /// order — under a parallel session this is the order results arrived).
    pub iteration: usize,
    /// Engine-assigned trial id (equals `iteration` for serial runs).
    pub trial_id: u64,
    /// Wall-clock cost of the measurement in seconds (0 when unknown).
    pub cost_s: f64,
    /// Declared objective vector in **maximisation orientation**
    /// (`ObjectiveSet::extract` order: primary first, `:min` columns
    /// negated). Empty for single-objective records; a NaN entry marks a
    /// declared column the measurement did not carry (that record never
    /// enters the Pareto front).
    pub objectives: Vec<f64>,
}

impl Evaluation {
    /// Encode this record as one JSONL line (no trailing newline) — the
    /// unit [`History::to_jsonl`] concatenates, and the unit a streaming
    /// session journal (`tune --state-dir`) appends per completed trial
    /// so an interrupted run can resume from disk.
    pub fn to_json_line(&self, space: &SearchSpace) -> String {
        let mut pairs = vec![
            ("iteration", Json::from(self.iteration)),
            ("trial", Json::from(self.trial_id as i64)),
            ("config", space.config_to_json(&self.config)),
            ("value", Json::from(self.value)),
            ("cost_s", Json::from(self.cost_s)),
        ];
        if !self.objectives.is_empty() {
            // NaN (a declared-but-missing column) is not valid JSON;
            // encode it as null and decode null back to NaN.
            pairs.push((
                "objectives",
                Json::Arr(
                    self.objectives
                        .iter()
                        .map(|&v| if v.is_finite() { Json::from(v) } else { Json::Null })
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs).to_string()
    }
}

/// Append-only evaluation history.
#[derive(Debug, Clone, Default)]
pub struct History {
    evals: Vec<Evaluation>,
}

impl History {
    pub fn new() -> History {
        History { evals: Vec::new() }
    }

    pub fn push(&mut self, config: Config, value: f64) {
        let iteration = self.evals.len();
        self.evals.push(Evaluation {
            config,
            value,
            iteration,
            trial_id: iteration as u64,
            cost_s: 0.0,
            objectives: Vec::new(),
        });
    }

    /// Record a completed trial with its full measurement.
    pub fn push_trial(&mut self, trial_id: u64, config: Config, m: &Measurement) {
        self.push_trial_multi(trial_id, config, m, Vec::new());
    }

    /// Record a completed trial together with its extracted K-objective
    /// vector (see [`crate::objectives::ObjectiveSet::extract`]; pass an
    /// empty vector for single-objective runs).
    pub fn push_trial_multi(
        &mut self,
        trial_id: u64,
        config: Config,
        m: &Measurement,
        objectives: Vec<f64>,
    ) {
        let iteration = self.evals.len();
        self.evals.push(Evaluation {
            config,
            value: m.value,
            iteration,
            trial_id,
            cost_s: m.cost_s,
            objectives,
        });
    }

    /// Total wall-clock measurement cost recorded so far (seconds).
    pub fn total_cost_s(&self) -> f64 {
        self.evals.iter().map(|e| e.cost_s).sum()
    }

    pub fn len(&self) -> usize {
        self.evals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Evaluation> {
        self.evals.iter()
    }

    pub fn last(&self) -> Option<&Evaluation> {
        self.evals.last()
    }

    /// Best evaluation so far (max objective). None when empty.
    pub fn best(&self) -> Option<&Evaluation> {
        self.evals
            .iter()
            .max_by(|a, b| a.value.partial_cmp(&b.value).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// The `n` best evaluations, best first (for GA parent selection).
    pub fn top_n(&self, n: usize) -> Vec<&Evaluation> {
        let mut sorted: Vec<&Evaluation> = self.evals.iter().collect();
        sorted.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap_or(std::cmp::Ordering::Equal));
        sorted.truncate(n);
        sorted
    }

    /// Raw objective series in evaluation order (Fig. 5 plots this).
    pub fn values(&self) -> Vec<f64> {
        self.evals.iter().map(|e| e.value).collect()
    }

    /// Monotone best-so-far curve.
    pub fn best_curve(&self) -> Vec<f64> {
        crate::util::stats::best_so_far(&self.values())
    }

    /// Has this exact configuration been measured already?
    pub fn seen(&self, config: &[i64]) -> bool {
        self.evals.iter().any(|e| e.config == config)
    }

    // -- multi-objective views ----------------------------------------------

    /// The objective vector of each evaluation, in evaluation order:
    /// the recorded K-vector when present, else the single-objective
    /// `[value]`. All maximisation orientation.
    pub fn objective_points(&self) -> Vec<Vec<f64>> {
        self.evals
            .iter()
            .map(|e| {
                if e.objectives.is_empty() {
                    vec![e.value]
                } else {
                    e.objectives.clone()
                }
            })
            .collect()
    }

    /// The non-dominated front over the recorded objective vectors
    /// (maximisation; records with a NaN column never enter). For
    /// single-objective histories this degenerates to the best record.
    pub fn pareto_front(&self) -> Vec<&Evaluation> {
        let points = self.objective_points();
        crate::objectives::pareto_front_indices(&points)
            .into_iter()
            .map(|i| &self.evals[i])
            .collect()
    }

    /// Dominated hypervolume of the history's non-dominated front with
    /// respect to `ref_point` (maximisation orientation; see
    /// [`crate::objectives::hypervolume`]). Monotone non-decreasing as
    /// evaluations are appended.
    pub fn hypervolume(&self, ref_point: &[f64]) -> f64 {
        crate::objectives::hypervolume(&self.objective_points(), ref_point)
    }

    /// [`History::hypervolume`] with the reference point derived from the
    /// history itself: the per-column minimum over all finite objective
    /// vectors, pushed out by `margin`
    /// (see [`crate::objectives::hv_reference`]). Deterministic in the
    /// recorded points, so a history replayed bit-identically from an
    /// event stream reproduces this value bit-identically — the contract
    /// the observability plane's `hypervolume` events rely on. None when
    /// no finite objective vector exists yet.
    pub fn hypervolume_auto(&self, margin: f64) -> Option<f64> {
        let points = self.objective_points();
        let k = points.iter().map(|p| p.len()).max()?;
        let r = crate::objectives::hv_reference(&points, k, margin)?;
        Some(crate::objectives::hypervolume(&points, &r))
    }

    /// Per-parameter sampled (min, max) over all evaluations — Table 2's
    /// raw material. None when empty.
    pub fn sampled_ranges(&self, dim: usize) -> Option<Vec<(i64, i64)>> {
        if self.evals.is_empty() {
            return None;
        }
        let mut ranges = vec![(i64::MAX, i64::MIN); dim];
        for e in &self.evals {
            assert_eq!(e.config.len(), dim, "inconsistent config dims in history");
            for (r, &v) in ranges.iter_mut().zip(&e.config) {
                r.0 = r.0.min(v);
                r.1 = r.1.max(v);
            }
        }
        Some(ranges)
    }

    /// Table 2's percentage: sampled span / tunable span per parameter.
    pub fn sampled_range_pct(&self, space: &SearchSpace) -> Option<Vec<f64>> {
        let ranges = self.sampled_ranges(space.dim())?;
        Some(
            space
                .params
                .iter()
                .zip(&ranges)
                .map(|(p, &(lo, hi))| {
                    if p.max == p.min {
                        100.0
                    } else {
                        100.0 * (hi - lo) as f64 / (p.max - p.min) as f64
                    }
                })
                .collect(),
        )
    }

    // -- persistence --------------------------------------------------------

    pub fn to_jsonl(&self, space: &SearchSpace) -> String {
        let mut out = String::new();
        for e in &self.evals {
            out.push_str(&e.to_json_line(space));
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str, space: &SearchSpace) -> Result<History, String> {
        let mut h = History::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = crate::util::json::parse(line)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let cfg = space
                .config_from_json(j.req("config").map_err(|e| e.to_string())?)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let value = j
                .get("value")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("line {}: missing value", lineno + 1))?;
            // Trial id and cost are optional for pre-ask/tell histories.
            let trial_id = j
                .get("trial")
                .and_then(Json::as_i64)
                .map(|t| t as u64)
                .unwrap_or(h.len() as u64);
            let cost_s = j.get("cost_s").and_then(Json::as_f64).unwrap_or(0.0);
            let objectives: Vec<f64> = match j.get("objectives").and_then(Json::as_arr) {
                Some(arr) => arr.iter().map(|v| v.as_f64().unwrap_or(f64::NAN)).collect(),
                None => Vec::new(),
            };
            let m = Measurement::new(value).with_cost_s(cost_s);
            h.push_trial_multi(trial_id, cfg, &m, objectives);
        }
        Ok(h)
    }

    pub fn save(&self, path: &Path, space: &SearchSpace) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl(space).as_bytes())
    }

    pub fn load(path: &Path, space: &SearchSpace) -> std::io::Result<History> {
        let f = std::fs::File::open(path)?;
        let mut text = String::new();
        for line in std::io::BufReader::new(f).lines() {
            text.push_str(&line?);
            text.push('\n');
        }
        History::from_jsonl(&text, space)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Convenience: seeded random history (used by tests and benches).
pub fn random_history(space: &SearchSpace, n: usize, seed: u64) -> History {
    let mut rng = Rng::new(seed);
    let mut h = History::new();
    for _ in 0..n {
        let cfg = space.random(&mut rng);
        let v = rng.range_f64(10.0, 500.0);
        h.push(cfg, v);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn best_and_curve() {
        let s = space();
        let mut h = History::new();
        let mut rng = Rng::new(1);
        for v in [3.0, 1.0, 7.0, 5.0] {
            let cfg = s.random(&mut rng);
            h.push(cfg, v);
        }
        assert_eq!(h.best().unwrap().value, 7.0);
        assert_eq!(h.best_curve(), vec![3.0, 3.0, 7.0, 7.0]);
        assert_eq!(h.best().unwrap().iteration, 2);
    }

    #[test]
    fn top_n_sorted_desc() {
        let s = space();
        let mut h = History::new();
        let mut rng = Rng::new(2);
        for v in [3.0, 9.0, 1.0, 7.0] {
            h.push(s.random(&mut rng), v);
        }
        let top = h.top_n(2);
        assert_eq!(top[0].value, 9.0);
        assert_eq!(top[1].value, 7.0);
    }

    #[test]
    fn sampled_ranges_track_extremes() {
        let s = space();
        let mut h = History::new();
        h.push(vec![1, 10, 64, 0, 5], 1.0);
        h.push(vec![4, 30, 512, 200, 50], 2.0);
        let r = h.sampled_ranges(5).unwrap();
        assert_eq!(r[0], (1, 4));
        assert_eq!(r[3], (0, 200));
        let pct = h.sampled_range_pct(&s).unwrap();
        assert!((pct[0] - 100.0).abs() < 1e-9); // inter_op covered 1..4 fully
        assert!((pct[3] - 100.0).abs() < 1e-9); // blocktime 0..200 fully
        assert!(pct[1] < 50.0); // intra 10..30 of 1..56
    }

    #[test]
    fn jsonl_round_trip() {
        let s = space();
        let h = random_history(&s, 23, 7);
        let text = h.to_jsonl(&s);
        let h2 = History::from_jsonl(&text, &s).unwrap();
        assert_eq!(h.evals, h2.evals);
    }

    #[test]
    fn jsonl_rejects_bad_lines() {
        let s = space();
        assert!(History::from_jsonl("{not json}\n", &s).is_err());
        assert!(History::from_jsonl(r#"{"value": 1}"#, &s).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let s = space();
        let h = random_history(&s, 11, 3);
        let dir = std::env::temp_dir().join("tftune_test_hist");
        let path = dir.join("h.jsonl");
        h.save(&path, &s).unwrap();
        let h2 = History::load(&path, &s).unwrap();
        assert_eq!(h.evals, h2.evals);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_best_curve_monotone_and_bounded() {
        let s = space();
        prop::check("best curve monotone", 100, |rng| {
            let n = 1 + rng.index(40);
            let mut h = History::new();
            for _ in 0..n {
                h.push(s.random(rng), rng.range_f64(-5.0, 5.0));
            }
            let curve = h.best_curve();
            assert_eq!(curve.len(), n);
            for w in curve.windows(2) {
                assert!(w[1] >= w[0]);
            }
            assert_eq!(*curve.last().unwrap(), h.best().unwrap().value);
        });
    }

    #[test]
    fn trial_ids_and_cost_round_trip_jsonl() {
        let s = space();
        let mut rng = Rng::new(5);
        let mut h = History::new();
        // out-of-order completion: trial ids do not match iteration order
        for (id, v) in [(3u64, 1.0), (0, 4.0), (2, 2.0)] {
            let m = Measurement::new(v).with_cost_s(0.25 * v);
            h.push_trial(id, s.random(&mut rng), &m);
        }
        assert_eq!(h.iter().map(|e| e.trial_id).collect::<Vec<_>>(), vec![3, 0, 2]);
        assert!((h.total_cost_s() - 0.25 * 7.0).abs() < 1e-12);
        let h2 = History::from_jsonl(&h.to_jsonl(&s), &s).unwrap();
        assert_eq!(h.evals, h2.evals);
    }

    #[test]
    fn legacy_jsonl_lines_still_load() {
        let s = space();
        let mut rng = Rng::new(6);
        let cfg = s.random(&mut rng);
        let line = format!(
            r#"{{"iteration":0,"config":{},"value":12.5}}"#,
            s.config_to_json(&cfg)
        );
        let h = History::from_jsonl(&line, &s).unwrap();
        assert_eq!(h.last().unwrap().trial_id, 0);
        assert_eq!(h.last().unwrap().cost_s, 0.0);
        assert_eq!(h.last().unwrap().value, 12.5);
    }

    #[test]
    fn pareto_front_and_hypervolume_views() {
        let s = space();
        let mut rng = Rng::new(9);
        let mut h = History::new();
        // (value, p99-negated) pairs: (5,-1) and (1,-0.1) trade off;
        // (2,-2) is dominated; the NaN row is degraded and never fronts.
        for (id, obj) in [
            (0u64, vec![5.0, -1.0]),
            (1, vec![1.0, -0.1]),
            (2, vec![2.0, -2.0]),
            (3, vec![4.0, f64::NAN]),
        ] {
            let m = Measurement::new(obj[0]);
            h.push_trial_multi(id, s.random(&mut rng), &m, obj);
        }
        let front: Vec<u64> = h.pareto_front().iter().map(|e| e.trial_id).collect();
        assert_eq!(front, vec![0, 1]);
        // HV against (0, -3): rects 5*2 + extra strip 0*... hand compute:
        // (5,-1) gives 5*2=10; (1,-0.1) adds 1*(−0.1−(−1))=0.9 → 10.9.
        let hv = h.hypervolume(&[0.0, -3.0]);
        assert!((hv - 10.9).abs() < 1e-12, "hv {hv}");
    }

    #[test]
    fn hypervolume_auto_matches_explicit_reference() {
        let s = space();
        let mut rng = Rng::new(10);
        let mut h = History::new();
        assert!(h.hypervolume_auto(0.5).is_none(), "empty history has no HV");
        for (id, obj) in [(0u64, vec![5.0, -1.0]), (1, vec![1.0, -0.1]), (2, vec![2.0, -2.0])] {
            let m = Measurement::new(obj[0]);
            h.push_trial_multi(id, s.random(&mut rng), &m, obj);
        }
        // Reference = per-column min − margin = (1−0.5, −2−0.5) = (0.5, −2.5).
        let want = h.hypervolume(&[0.5, -2.5]);
        let got = h.hypervolume_auto(0.5).unwrap();
        assert_eq!(got.to_bits(), want.to_bits());
        // Replaying the same records through a fresh history reproduces it
        // bit-identically — the observability plane's replay contract.
        let h2 = History::from_jsonl(&h.to_jsonl(&s), &s).unwrap();
        assert_eq!(h2.hypervolume_auto(0.5).unwrap().to_bits(), got.to_bits());
    }

    #[test]
    fn single_objective_front_is_the_best_record() {
        let s = space();
        let h = random_history(&s, 12, 4);
        let front = h.pareto_front();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].iteration, h.best().unwrap().iteration);
    }

    #[test]
    fn objectives_round_trip_jsonl_with_nan_as_null() {
        let s = space();
        let mut rng = Rng::new(11);
        let mut h = History::new();
        h.push_trial_multi(
            0,
            s.random(&mut rng),
            &Measurement::new(3.0),
            vec![3.0, -0.5],
        );
        h.push_trial_multi(
            1,
            s.random(&mut rng),
            &Measurement::new(1.0),
            vec![1.0, f64::NAN],
        );
        let text = h.to_jsonl(&s);
        assert!(text.contains("null"), "NaN column must encode as null: {text}");
        let h2 = History::from_jsonl(&text, &s).unwrap();
        assert_eq!(h2.len(), 2);
        let a: Vec<Vec<u64>> = h
            .iter()
            .map(|e| e.objectives.iter().map(|v| v.to_bits()).collect())
            .collect();
        let b: Vec<Vec<u64>> = h2
            .iter()
            .map(|e| e.objectives.iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(a, b, "objective vectors must survive the round trip bitwise");
    }

    #[test]
    fn seen_detects_duplicates() {
        let mut h = History::new();
        let cfg = vec![1, 10, 64, 0, 5];
        assert!(!h.seen(&cfg));
        h.push(cfg.clone(), 1.0);
        assert!(h.seen(&cfg));
        assert!(!h.seen(&[2, 10, 64, 0, 5]));
    }
}
