//! Evaluation history — the paper's "data acquisition module" (Fig. 4).
//!
//! Every algorithm engine consumes and extends the same global history of
//! `(configuration, throughput)` measurements; the figure harnesses read it
//! back to produce tuning curves (Fig. 5), pairplots (Fig. 7) and the
//! range-coverage table (Table 2). Histories persist as JSONL so long
//! sweeps can resume and the paper artifacts are regenerable from disk.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::space::{Config, SearchSpace};
use crate::util::{Json, Rng};

/// One measurement: a configuration and its objective value
/// (examples/second; higher is better).
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    pub config: Config,
    pub value: f64,
    /// Which tuning iteration produced this point (0-based).
    pub iteration: usize,
}

/// Append-only evaluation history.
#[derive(Debug, Clone, Default)]
pub struct History {
    evals: Vec<Evaluation>,
}

impl History {
    pub fn new() -> History {
        History { evals: Vec::new() }
    }

    pub fn push(&mut self, config: Config, value: f64) {
        let iteration = self.evals.len();
        self.evals.push(Evaluation { config, value, iteration });
    }

    pub fn len(&self) -> usize {
        self.evals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Evaluation> {
        self.evals.iter()
    }

    pub fn last(&self) -> Option<&Evaluation> {
        self.evals.last()
    }

    /// Best evaluation so far (max objective). None when empty.
    pub fn best(&self) -> Option<&Evaluation> {
        self.evals
            .iter()
            .max_by(|a, b| a.value.partial_cmp(&b.value).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// The `n` best evaluations, best first (for GA parent selection).
    pub fn top_n(&self, n: usize) -> Vec<&Evaluation> {
        let mut sorted: Vec<&Evaluation> = self.evals.iter().collect();
        sorted.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap_or(std::cmp::Ordering::Equal));
        sorted.truncate(n);
        sorted
    }

    /// Raw objective series in evaluation order (Fig. 5 plots this).
    pub fn values(&self) -> Vec<f64> {
        self.evals.iter().map(|e| e.value).collect()
    }

    /// Monotone best-so-far curve.
    pub fn best_curve(&self) -> Vec<f64> {
        crate::util::stats::best_so_far(&self.values())
    }

    /// Has this exact configuration been measured already?
    pub fn seen(&self, config: &[i64]) -> bool {
        self.evals.iter().any(|e| e.config == config)
    }

    /// Per-parameter sampled (min, max) over all evaluations — Table 2's
    /// raw material. None when empty.
    pub fn sampled_ranges(&self, dim: usize) -> Option<Vec<(i64, i64)>> {
        if self.evals.is_empty() {
            return None;
        }
        let mut ranges = vec![(i64::MAX, i64::MIN); dim];
        for e in &self.evals {
            assert_eq!(e.config.len(), dim, "inconsistent config dims in history");
            for (r, &v) in ranges.iter_mut().zip(&e.config) {
                r.0 = r.0.min(v);
                r.1 = r.1.max(v);
            }
        }
        Some(ranges)
    }

    /// Table 2's percentage: sampled span / tunable span per parameter.
    pub fn sampled_range_pct(&self, space: &SearchSpace) -> Option<Vec<f64>> {
        let ranges = self.sampled_ranges(space.dim())?;
        Some(
            space
                .params
                .iter()
                .zip(&ranges)
                .map(|(p, &(lo, hi))| {
                    if p.max == p.min {
                        100.0
                    } else {
                        100.0 * (hi - lo) as f64 / (p.max - p.min) as f64
                    }
                })
                .collect(),
        )
    }

    // -- persistence --------------------------------------------------------

    pub fn to_jsonl(&self, space: &SearchSpace) -> String {
        let mut out = String::new();
        for e in &self.evals {
            let line = Json::obj(vec![
                ("iteration", Json::from(e.iteration)),
                ("config", space.config_to_json(&e.config)),
                ("value", Json::from(e.value)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str, space: &SearchSpace) -> Result<History, String> {
        let mut h = History::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = crate::util::json::parse(line)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let cfg = space
                .config_from_json(j.req("config").map_err(|e| e.to_string())?)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let value = j
                .get("value")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("line {}: missing value", lineno + 1))?;
            h.push(cfg, value);
        }
        Ok(h)
    }

    pub fn save(&self, path: &Path, space: &SearchSpace) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl(space).as_bytes())
    }

    pub fn load(path: &Path, space: &SearchSpace) -> std::io::Result<History> {
        let f = std::fs::File::open(path)?;
        let mut text = String::new();
        for line in std::io::BufReader::new(f).lines() {
            text.push_str(&line?);
            text.push('\n');
        }
        History::from_jsonl(&text, space)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// Convenience: seeded random history (used by tests and benches).
pub fn random_history(space: &SearchSpace, n: usize, seed: u64) -> History {
    let mut rng = Rng::new(seed);
    let mut h = History::new();
    for _ in 0..n {
        let cfg = space.random(&mut rng);
        let v = rng.range_f64(10.0, 500.0);
        h.push(cfg, v);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn best_and_curve() {
        let s = space();
        let mut h = History::new();
        let mut rng = Rng::new(1);
        for v in [3.0, 1.0, 7.0, 5.0] {
            let cfg = s.random(&mut rng);
            h.push(cfg, v);
        }
        assert_eq!(h.best().unwrap().value, 7.0);
        assert_eq!(h.best_curve(), vec![3.0, 3.0, 7.0, 7.0]);
        assert_eq!(h.best().unwrap().iteration, 2);
    }

    #[test]
    fn top_n_sorted_desc() {
        let s = space();
        let mut h = History::new();
        let mut rng = Rng::new(2);
        for v in [3.0, 9.0, 1.0, 7.0] {
            h.push(s.random(&mut rng), v);
        }
        let top = h.top_n(2);
        assert_eq!(top[0].value, 9.0);
        assert_eq!(top[1].value, 7.0);
    }

    #[test]
    fn sampled_ranges_track_extremes() {
        let s = space();
        let mut h = History::new();
        h.push(vec![1, 10, 64, 0, 5], 1.0);
        h.push(vec![4, 30, 512, 200, 50], 2.0);
        let r = h.sampled_ranges(5).unwrap();
        assert_eq!(r[0], (1, 4));
        assert_eq!(r[3], (0, 200));
        let pct = h.sampled_range_pct(&s).unwrap();
        assert!((pct[0] - 100.0).abs() < 1e-9); // inter_op covered 1..4 fully
        assert!((pct[3] - 100.0).abs() < 1e-9); // blocktime 0..200 fully
        assert!(pct[1] < 50.0); // intra 10..30 of 1..56
    }

    #[test]
    fn jsonl_round_trip() {
        let s = space();
        let h = random_history(&s, 23, 7);
        let text = h.to_jsonl(&s);
        let h2 = History::from_jsonl(&text, &s).unwrap();
        assert_eq!(h.evals, h2.evals);
    }

    #[test]
    fn jsonl_rejects_bad_lines() {
        let s = space();
        assert!(History::from_jsonl("{not json}\n", &s).is_err());
        assert!(History::from_jsonl(r#"{"value": 1}"#, &s).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let s = space();
        let h = random_history(&s, 11, 3);
        let dir = std::env::temp_dir().join("tftune_test_hist");
        let path = dir.join("h.jsonl");
        h.save(&path, &s).unwrap();
        let h2 = History::load(&path, &s).unwrap();
        assert_eq!(h.evals, h2.evals);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_best_curve_monotone_and_bounded() {
        let s = space();
        prop::check("best curve monotone", 100, |rng| {
            let n = 1 + rng.index(40);
            let mut h = History::new();
            for _ in 0..n {
                h.push(s.random(rng), rng.range_f64(-5.0, 5.0));
            }
            let curve = h.best_curve();
            assert_eq!(curve.len(), n);
            for w in curve.windows(2) {
                assert!(w[1] >= w[0]);
            }
            assert_eq!(*curve.last().unwrap(), h.best().unwrap().value);
        });
    }

    #[test]
    fn seen_detects_duplicates() {
        let mut h = History::new();
        let cfg = vec![1, 10, 64, 0, 5];
        assert!(!h.seen(&cfg));
        h.push(cfg.clone(), 1.0);
        assert!(h.seen(&cfg));
        assert!(!h.seen(&[2, 10, 64, 0, 5]));
    }
}
