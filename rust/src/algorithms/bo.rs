//! Bayesian-optimization engine (paper §2.2): Gaussian-process surrogate
//! + SMSego-style acquisition, built on the incremental surrogate
//! subsystem (`crate::gp`).
//!
//! Per iteration:
//!   1. normalise the history to the unit cube, standardise y,
//!   2. generate a candidate set (global uniform samples + local Gaussian
//!      perturbations of the incumbent — the explore/exploit mix),
//!   3. score every candidate's optimistic gain (mu + alpha*sigma) - y_best,
//!   4. propose the highest-gain unseen candidate.
//!
//! Step 3 is the numeric hot path. With the native stack the engine
//! conditions a persistent incremental model that it *borrows* rather than
//! owns, through the [`SurrogateHandle`] contract. In the default
//! (private) case the engine is the handle's only user and behaviour is
//! identical to owning the model; attach a handle shared with other
//! engines ([`BayesOpt::with_shared_surrogate`]) and every `tell` from
//! every session lands in **one** factor — the whole-host surrogate the
//! paper's amortisation argument wants (see `gp::shared` for the
//! concurrency contract). The handle may equally be a
//! [`crate::gp::RemoteSurrogate`]: a replica of a factor *served over
//! TCP*, so separate tuner processes (or hosts) condition one model —
//! the engine code is identical, and sibling processes' in-flight trials
//! arrive as leased *ambient fantasies* the batch conditions on alongside
//! its own. Each `tell` enqueues its observation (never blocking a
//! concurrent scoring pass); each `ask` drains the queue in observation
//! order as O(n²) rank-1 Cholesky appends, conditions on in-flight trials
//! by *extending* the factor with constant-liar fantasies, and scores the
//! candidate pool through one blocked cross-kernel panel + multi-RHS
//! triangular solve over reused buffers ([`ScoreWorkspace`]) that never
//! grow once warmed up. The pass is the *scoring engine* of
//! `gp::incremental`: [`BayesOpt::with_score_threads`] partitions the
//! pool over worker threads (bit-identical results for any count) and
//! [`BayesOpt::with_score_tier`] opts ranking into the f32 fast tier.
//!
//! Batched asks are *fantasy-batched*: `ask(n)` takes the model lock
//! once, extends the factor with each picked configuration as it is
//! issued, scores the n candidate pools against the growing factor, and
//! retracts all fantasies together when the guard drops — one
//! extend/retract cycle per batch instead of one per proposal, so the
//! per-proposal critical section a shared handle serialises stays short.
//!
//! Surrogates that refit in one fused call still go through
//! [`Surrogate::fit_score`]: the production HLO artifact (L2 JAX graph +
//! L1 Pallas RBF kernel, via PJRT — `runtime::GpSurrogate`) and the
//! scratch-refit reference path (`ExactRefitSurrogate`). Python is never
//! on this path. Both routes consume the same [`GpHyper`] (kernel,
//! lengthscale, conditioning window), so they stay interchangeable.
//!
//! **Multi-objective acquisition** ([`BayesOpt::with_objectives`]): the
//! settings this system tunes trade throughput against tail latency, so
//! the engine can optimise a declared
//! [`ObjectiveSet`](crate::objectives::ObjectiveSet) — primary `value`
//! plus named `Measurement::metadata` columns. The factor depends only
//! on X, so K objectives cost **K target columns over one factor**: one
//! blocked panel pass emits per-objective means and the shared posterior
//! std (`IncrementalGp::score_multi_into`), and the acquisition is a
//! weighted scalarisation or an SMSego-style hypervolume gain over the
//! non-dominated front. Trials missing a declared column degrade to
//! their measured columns with a warning; the shared factor is never
//! poisoned.

use super::{Trial, TrialBook, TrialId, Tuner};
use crate::gp::{
    select_lengthscale, GpHyper, KernelKind, NativeSurrogate, ScoreTier, ScoreWorkspace,
    SharedSurrogate, Surrogate, SurrogateGuard, SurrogateHandle, UNBOUNDED_HISTORY,
};
use crate::history::Measurement;
use crate::objectives::{self, ObjectiveSet, Scalarization};
use crate::space::{Config, SearchSpace};
use crate::util::{stats, Rng};

/// Initial Latin-hypercube design size.
pub const INIT_DESIGN: usize = 8;
/// Candidates scored per iteration (matches the AOT artifact's C_CAND).
pub const CANDIDATES: usize = 512;
/// Fraction of candidates drawn globally (rest perturb the incumbent).
const GLOBAL_FRAC: f64 = 0.75;
/// Stddev (unit-cube) of local perturbations.
const LOCAL_SIGMA: f64 = 0.08;
/// Acquisition optimism (alpha in (mu + alpha*sigma) - y_best).
pub const ACQ_ALPHA: f64 = 1.5;

/// Batch-invariant proposal context (see [`BayesOpt`]'s ask): the store
/// is frozen while the model guard is held, so the conditioning set, the
/// acquisition baseline and the incumbent are computed once per batch.
struct BatchCtx {
    /// Conditioning set: indices into the shared observation store.
    idx: Vec<usize>,
    /// Best standardised objective over the conditioning set.
    y_best: f64,
    /// Unit-cube coordinates of the best observation (local-perturbation
    /// centre for candidate generation).
    incumbent: Vec<f64>,
    /// Multi-objective per-batch context (None in single-objective mode).
    mo: Option<MoBatch>,
}

/// The declared objectives + acquisition of a multi-objective engine
/// ([`BayesOpt::with_objectives`]).
struct MultiObjective {
    set: ObjectiveSet,
    scalarize: Scalarization,
}

/// Per-batch multi-objective state: per-objective acquisition baselines
/// and the non-dominated front (standardised, maximisation) SMSego
/// measures hypervolume gain against.
struct MoBatch {
    /// Best standardised value per objective over rows that measured it.
    y_best: Vec<f64>,
    /// Non-dominated front over fully-measured conditioning rows.
    front: Vec<Vec<f64>>,
    /// Hypervolume reference point (below every front point).
    ref_point: Vec<f64>,
    /// HV(front): the SMSego gain baseline, computed once per batch.
    hv_front: f64,
}

pub struct BayesOpt<S: Surrogate = NativeSurrogate> {
    space: SearchSpace,
    rng: Rng,
    surrogate: S,
    /// Kernel + lengthscale + noise + conditioning window, shared by every
    /// surrogate path (incremental, scratch oracle, HLO artifact). Kept in
    /// lock-step with the shared handle's hypers.
    hyper: GpHyper,
    /// Acquisition optimism (ablatable; default ACQ_ALPHA).
    acq_alpha: f64,
    /// Candidate-pool size per iteration (ablatable; default CANDIDATES).
    n_candidates: usize,
    /// Re-select the lengthscale by log marginal likelihood as history
    /// grows (off by default: the paper fixes hypers per run).
    tune_lengthscale: bool,
    /// History size at which the lengthscale was last selected.
    ls_selected_at: usize,
    /// Initial design not yet proposed.
    pending_init: Vec<Config>,
    /// Configurations this engine has settled, in tell order. Proposal
    /// dedup only — the observation store itself lives in `shared`.
    observed: Vec<Config>,
    /// Open trials. Pending configurations are conditioned into the GP as
    /// constant-liar fantasies (at the standardised mean) so a batch of
    /// `ask`ed trials spreads out instead of collapsing onto one point.
    book: TrialBook,
    /// Handle to the persistent incremental model (native stack only),
    /// behind the [`SurrogateHandle`] contract. Private by default;
    /// [`BayesOpt::with_shared_surrogate`] attaches a handle shared with
    /// other engines/sessions — in-process ([`SharedSurrogate`]) or a
    /// replica of a served factor ([`crate::gp::RemoteSurrogate`]).
    shared: Box<dyn SurrogateHandle>,
    /// Reusable scoring buffers (zero-allocation hot path).
    ws: ScoreWorkspace,
    /// Flattened candidate pool (n_candidates × dim), reused per ask.
    cand_flat: Vec<f64>,
    /// Reusable raw/standardised conditioning targets.
    y_raw: Vec<f64>,
    y_std: Vec<f64>,
    /// Multi-objective mode (None = the classic single-objective engine,
    /// byte-identical behaviour): declared set + scalarisation.
    multi: Option<MultiObjective>,
    /// Per-objective standardised targets over the conditioning set
    /// (multi mode; column 0 mirrors `y_std`).
    y_std_obj: Vec<Vec<f64>>,
    /// Scratch: targets padded with per-fantasy lies to the factor's
    /// current row count, one column per objective.
    y_pad_obj: Vec<Vec<f64>>,
    /// Scratch: the K-element optimistic point of the candidate being
    /// scored (multi mode), reused across proposals.
    mo_opt: Vec<f64>,
    /// Scoring-engine worker threads, pushed to the shared model at each
    /// batch (default 1 = serial; results bit-identical for any count).
    score_threads: usize,
    /// Scoring-engine arithmetic tier (default f64 — the pinned oracle).
    score_tier: ScoreTier,
}

impl BayesOpt<NativeSurrogate> {
    /// BO with the native surrogate stack (persistent incremental GP).
    pub fn new(space: SearchSpace, seed: u64) -> BayesOpt<NativeSurrogate> {
        BayesOpt::with_surrogate(space, seed, NativeSurrogate)
    }
}

impl<S: Surrogate> BayesOpt<S> {
    /// BO with an explicit surrogate (e.g. `runtime::GpSurrogate` for the
    /// AOT/PJRT path, or `ExactRefitSurrogate` for the scratch reference).
    pub fn with_surrogate(space: SearchSpace, seed: u64, surrogate: S) -> BayesOpt<S> {
        let mut rng = Rng::new(seed);
        let mut pending_init = space.latin_hypercube(INIT_DESIGN, &mut rng);
        pending_init.reverse(); // pop from back in LHS order
        let hyper = GpHyper::default();
        let shared: Box<dyn SurrogateHandle> = Box::new(SharedSurrogate::new(hyper));
        if !surrogate.use_engine_incremental() {
            // Fused-refit surrogates (HLO artifact, scratch reference)
            // never score through the factor — keep drains O(1).
            shared.set_eager_factoring(false);
        }
        BayesOpt {
            space,
            rng,
            surrogate,
            hyper,
            acq_alpha: ACQ_ALPHA,
            n_candidates: CANDIDATES,
            tune_lengthscale: false,
            ls_selected_at: 0,
            pending_init,
            observed: Vec::new(),
            book: TrialBook::new(),
            shared,
            ws: ScoreWorkspace::default(),
            cand_flat: Vec::new(),
            y_raw: Vec::new(),
            y_std: Vec::new(),
            multi: None,
            y_std_obj: Vec::new(),
            y_pad_obj: Vec::new(),
            mo_opt: Vec::new(),
            score_threads: 1,
            score_tier: ScoreTier::F64,
        }
    }

    /// Condition this engine on a surrogate shared with other engines or
    /// sessions (one factor per search space — see `gp::shared`): an
    /// in-process [`SharedSurrogate`] or a [`crate::gp::RemoteSurrogate`]
    /// replica of a served factor — any [`SurrogateHandle`]. The engine
    /// adopts the handle's hyperparameters, so attach the handle *before*
    /// kernel/window overrides and before any tuning starts.
    ///
    /// This is also how the **sharded scaling tier** attaches: a handle
    /// from [`SharedSurrogate::new_sharded`] routes every sync / fantasy
    /// / scoring call into `gp::sharded`'s KD-partitioned ensemble, and
    /// the unbounded conditioning window it carries is adopted here —
    /// the engine itself is tier-agnostic.
    ///
    /// An incremental engine turns eager factoring on for the whole
    /// handle (it scores through the factor); a fused-refit engine
    /// leaves the handle's setting alone, since siblings may still need
    /// the factor — if *no* attached engine is incremental, disable it
    /// via [`SharedSurrogate::set_eager_factoring`].
    pub fn with_shared_surrogate(
        mut self,
        handle: impl SurrogateHandle + 'static,
    ) -> BayesOpt<S> {
        assert!(
            self.observed.is_empty() && self.book.open_len() == 0,
            "attach the shared surrogate before tuning starts"
        );
        assert!(
            self.hyper == GpHyper::default(),
            "attach the shared surrogate before kernel/window overrides \
             (the engine adopts the handle hypers, discarding earlier ones)"
        );
        if self.surrogate.use_engine_incremental() {
            handle.set_eager_factoring(true);
        }
        self.hyper = handle.hyper();
        self.shared = Box::new(handle);
        self
    }

    /// A cloneable handle to the surrogate this engine conditions —
    /// attach it to further engines via [`BayesOpt::with_shared_surrogate`].
    pub fn surrogate_handle(&self) -> Box<dyn SurrogateHandle> {
        self.shared.clone_handle()
    }

    /// Switch the engine to **multi-objective acquisition** over the
    /// declared objective set: tells extract the K objective columns
    /// from each [`Measurement`] (primary `value` + named metadata
    /// columns, `:min` columns negated so everything maximises) into the
    /// shared store, and every ask scores all K objectives in **one
    /// blocked panel pass over one factor** — K target columns, not K
    /// refits (`IncrementalGp::score_multi_into`). The acquisition is
    /// either a fixed weighted scalarisation of the per-objective
    /// optimistic gains or the SMSego-style hypervolume gain of the
    /// optimistic candidate point over the non-dominated front.
    ///
    /// A trial whose measurement is missing a declared column (or
    /// carries NaN) degrades to the columns it does measure, with a
    /// warning — the factor depends only on X and is never poisoned.
    ///
    /// Native incremental surrogate only (the AOT HLO artifact's fused
    /// graph is single-objective); panics on a fused-refit surrogate or
    /// a weight-count mismatch — `TuneConfig` validates both with
    /// proper errors first.
    pub fn with_objectives(mut self, set: ObjectiveSet, scalarize: Scalarization) -> BayesOpt<S> {
        assert!(
            self.surrogate.use_engine_incremental(),
            "multi-objective acquisition requires the native incremental surrogate"
        );
        let scalarize = scalarize
            .resolve(set.k())
            .unwrap_or_else(|e| panic!("scalarisation/objective mismatch: {e}"));
        self.multi = Some(MultiObjective { set, scalarize });
        self
    }

    /// The declared objective set (None = single-objective engine).
    pub fn objective_set(&self) -> Option<&ObjectiveSet> {
        self.multi.as_ref().map(|m| &m.set)
    }

    /// Override the acquisition optimism (ablation A2).
    pub fn with_acq_alpha(mut self, alpha: f64) -> BayesOpt<S> {
        assert!(alpha >= 0.0, "acquisition alpha must be non-negative");
        self.acq_alpha = alpha;
        self
    }

    /// Override the candidate-pool size (ablation A3). Capped at the AOT
    /// artifact's C_CAND when the HLO surrogate is used.
    pub fn with_candidates(mut self, n: usize) -> BayesOpt<S> {
        assert!(n > 0, "need at least one candidate");
        self.n_candidates = n.min(CANDIDATES);
        self
    }

    /// Worker threads the scoring engine partitions each candidate pool
    /// over (default 1 = serial). A purely wall-clock knob: the pool is
    /// split into fixed contiguous candidate blocks — a pure function of
    /// (pool size, thread count) — so results are **bit-identical** for
    /// every count ([`crate::gp::IncrementalGp::set_score_threads`]).
    /// Native incremental surrogate only; fused-refit paths ignore it.
    pub fn with_score_threads(mut self, threads: usize) -> BayesOpt<S> {
        assert!(threads >= 1, "scoring needs at least one thread");
        self.score_threads = threads;
        self
    }

    /// Scoring arithmetic tier (default [`ScoreTier::F64`], the pinned
    /// oracle). [`ScoreTier::F32`] ranks candidates at single precision —
    /// faster panels for acquisition ranking only; everything the model
    /// *learns* (factor, targets, appends) stays f64 regardless.
    pub fn with_score_tier(mut self, tier: ScoreTier) -> BayesOpt<S> {
        self.score_tier = tier;
        self
    }

    /// The scoring-engine worker-thread count this engine pushes at each
    /// batch.
    pub fn score_threads(&self) -> usize {
        self.score_threads
    }

    /// The scoring tier this engine pushes at each batch.
    pub fn score_tier(&self) -> ScoreTier {
        self.score_tier
    }

    /// Capacities of every per-ask scratch buffer — the probe behind the
    /// no-per-ask-heap-growth test (`rust/tests/scoring_engine.rs`): once
    /// the engine has seen a workload's shapes, repeated asks must leave
    /// all of these unchanged (the candidate pool refills through a
    /// capacity-preserving clear, the scoring workspace reuses its
    /// buffers).
    pub fn scratch_capacities(&self) -> Vec<usize> {
        let mut caps = vec![
            self.cand_flat.capacity(),
            self.y_raw.capacity(),
            self.y_std.capacity(),
            self.mo_opt.capacity(),
            self.y_std_obj.capacity(),
            self.y_pad_obj.capacity(),
        ];
        caps.extend(self.y_std_obj.iter().map(Vec::capacity));
        caps.extend(self.y_pad_obj.iter().map(Vec::capacity));
        caps.extend(self.ws.heap_capacities());
        caps
    }

    /// Covariance kernel for the surrogate (native stack; the HLO artifact
    /// is RBF-only and rejects other kinds).
    pub fn with_kernel(mut self, kind: KernelKind) -> BayesOpt<S> {
        self.hyper.kernel = kind;
        self.shared.set_hyper(self.hyper);
        self
    }

    /// Override the surrogate conditioning window; `None` lifts it
    /// entirely ([`UNBOUNDED_HISTORY`] — native paths only, since the
    /// window exists for AOT N_PAD parity and `runtime::GpSurrogate`
    /// enforces its compiled bound at score time).
    pub fn with_history_window(mut self, window: impl Into<Option<usize>>) -> BayesOpt<S> {
        let w = window.into().unwrap_or(UNBOUNDED_HISTORY);
        assert!(w > 0, "history window must be positive");
        self.hyper.max_history = w;
        self.shared.set_hyper(self.hyper);
        self
    }

    /// Re-select the lengthscale over [`crate::gp::LENGTHSCALE_GRID`] by
    /// log marginal likelihood whenever the history reaches a power-of-two
    /// size (rebuilds the incremental factor on change).
    pub fn with_lengthscale_selection(mut self) -> BayesOpt<S> {
        self.tune_lengthscale = true;
        self
    }

    /// The hypers every surrogate path is currently driven by.
    pub fn hyper(&self) -> GpHyper {
        self.hyper
    }

    /// Fill `cand_flat` with the explore/exploit candidate mix; returns
    /// the number of rows. No allocation once the buffer has warmed up.
    fn gen_candidates(&mut self, incumbent: &[f64]) -> usize {
        let dim = self.space.dim();
        let n_global = (self.n_candidates as f64 * GLOBAL_FRAC) as usize;
        self.cand_flat.clear();
        self.cand_flat.reserve(self.n_candidates * dim);
        for _ in 0..n_global * dim {
            let v = self.rng.f64();
            self.cand_flat.push(v);
        }
        for _ in n_global..self.n_candidates {
            for &x in incumbent {
                let v = (x + self.rng.normal() * LOCAL_SIGMA).clamp(0.0, 1.0);
                self.cand_flat.push(v);
            }
        }
        self.n_candidates
    }

    /// Bring the shared factor to scoring state for this batch: grow (or
    /// rebuild) it over `idx`, install the standardised targets, and
    /// condition on every in-flight trial as a constant-liar fantasy
    /// (capped so the set still fits the window / artifact N_PAD) — this
    /// engine's own open trials first, then sibling *processes'* leased
    /// points (ambient fantasies served back by a surrogate service).
    /// Returns false (factor cleared) if it could not be grown.
    fn setup_incremental(&self, g: &mut SurrogateGuard<'_>, idx: &[usize]) -> bool {
        if !g.sync(idx) {
            return false;
        }
        g.set_targets(&self.y_std);
        // Constant-liar fantasies for in-flight trials: pretend each lands
        // at the observed mean (standardised 0), which kills the variance
        // bonus around pending points and pushes the batch apart.
        let window = self.hyper.max_history;
        for cfg in self.book.open_configs() {
            if g.total() >= window {
                break;
            }
            let u = self.space.to_unit(cfg);
            if !g.extend_fantasy(&u, 0.0) {
                break;
            }
        }
        // Sibling processes' in-flight trials, untracked so this engine's
        // published lease never echoes points it does not own. A refused
        // point (dimension mismatch from a misconfigured sibling, non-PD
        // extension) is skipped, not fatal — the remaining leases still
        // condition the batch.
        for k in 0..g.ambient_len() {
            if g.total() >= window {
                break;
            }
            let (x, lie) = g.ambient_point(k);
            let _ = g.extend_fantasy_untracked(&x, lie);
        }
        true
    }

    /// Score the pool through `Surrogate::fit_score` (HLO artifact or
    /// scratch reference). Returns false on surrogate failure.
    fn generic_scores(&mut self, g: &SurrogateGuard<'_>, idx: &[usize], y_best: f64) -> bool {
        let dim = self.space.dim();
        let window = self.hyper.max_history;
        let mut x: Vec<Vec<f64>> = idx.iter().map(|&i| g.x(i).to_vec()).collect();
        let mut y = self.y_std.clone();
        for cfg in self.book.open_configs() {
            if x.len() >= window {
                break;
            }
            x.push(self.space.to_unit(cfg));
            y.push(0.0);
        }
        for k in 0..g.ambient_len() {
            if x.len() >= window {
                break;
            }
            let (ax, lie) = g.ambient_point(k);
            if ax.len() != dim {
                continue; // misconfigured sibling's lease: skip, not fatal
            }
            x.push(ax);
            y.push(lie);
        }
        let cands: Vec<Vec<f64>> = self.cand_flat.chunks(dim).map(|c| c.to_vec()).collect();
        match self.surrogate.fit_score(&x, &y, &cands, self.hyper, self.acq_alpha, y_best) {
            Ok(s) => {
                self.ws.mean = s.mean;
                self.ws.std = s.std;
                self.ws.gain = s.gain;
                true
            }
            Err(e) => {
                // Surrogate failure (singular kernel etc.): fall back to a
                // random proposal rather than aborting the tuning run.
                eprintln!("tftune: surrogate failed ({e}); proposing randomly");
                false
            }
        }
    }

    /// Build the batch-invariant proposal context: the conditioning set,
    /// its standardised targets (left in `self.y_std`), the acquisition
    /// baseline and the incumbent. The guarded store is frozen while the
    /// guard is held (tells only enqueue), so one ask computes this once
    /// however many proposals it issues. Also the once-per-batch spot for
    /// hyper adoption and lengthscale re-selection.
    fn batch_context(&mut self, g: &mut SurrogateGuard<'_>, inc_ready: &mut bool) -> BatchCtx {
        // Hypers live with the shared model. Builder overrides and
        // lengthscale selection write through to the handle immediately,
        // so a mismatch here always means a sibling engine changed them —
        // adopt (last writer wins group-wide) rather than fight over the
        // factor, which would force a rebuild on every alternating ask.
        self.hyper = g.hyper();

        // Standardise y over the conditioning set.
        let idx = g.conditioning_set();
        self.y_raw.clear();
        for &i in &idx {
            let v = g.y(i);
            self.y_raw.push(v);
        }
        let mean = stats::mean(&self.y_raw);
        let sd = stats::stddev(&self.y_raw).max(1e-9);
        self.y_std.clear();
        for k in 0..idx.len() {
            let v = (self.y_raw[k] - mean) / sd;
            self.y_std.push(v);
        }
        let y_best = self.y_std.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

        let incumbent = {
            let bi = stats::argmax(&self.y_raw);
            g.x(idx[bi]).to_vec()
        };

        if self.tune_lengthscale {
            let n = idx.len();
            if n >= 4 && n.is_power_of_two() && n != self.ls_selected_at {
                let xs: Vec<Vec<f64>> = idx.iter().map(|&i| g.x(i).to_vec()).collect();
                let picked = select_lengthscale(&xs, &self.y_std, self.hyper);
                self.ls_selected_at = n;
                if picked != self.hyper {
                    self.hyper = picked;
                    g.ensure_hyper(picked);
                    *inc_ready = false;
                }
            }
        }

        // Multi-objective batch state: standardise every declared column
        // over the conditioning set (a row that did not measure a column
        // contributes 0.0 — the standardised mean — to that column and
        // stays out of the front), and fix the SMSego baseline.
        let mo = match &self.multi {
            None => None,
            Some(moc) => {
                let k = moc.set.k();
                // Resize without dropping column capacity (once per run
                // in practice — K is fixed per engine).
                self.y_std_obj.resize(k, Vec::new());
                let mut y_best_obj = vec![0.0; k];
                {
                    let col0 = &mut self.y_std_obj[0];
                    col0.clear();
                    col0.extend_from_slice(&self.y_std);
                }
                y_best_obj[0] = y_best;
                for kk in 1..k {
                    let col: Vec<f64> = idx
                        .iter()
                        .map(|&i| g.y_extras(i).get(kk - 1).copied().unwrap_or(f64::NAN))
                        .collect();
                    let finite: Vec<f64> =
                        col.iter().copied().filter(|v| v.is_finite()).collect();
                    let (mean, sd) = if finite.is_empty() {
                        (0.0, 1.0)
                    } else {
                        (stats::mean(&finite), stats::stddev(&finite).max(1e-9))
                    };
                    let dst = &mut self.y_std_obj[kk];
                    dst.clear();
                    dst.extend(
                        col.iter()
                            .map(|&v| if v.is_finite() { (v - mean) / sd } else { 0.0 }),
                    );
                    let best = col
                        .iter()
                        .zip(dst.iter())
                        .filter(|(raw, _)| raw.is_finite())
                        .map(|(_, &s)| s)
                        .fold(f64::NEG_INFINITY, f64::max);
                    y_best_obj[kk] = if best.is_finite() { best } else { 0.0 };
                }
                // Front over rows with every declared column measured.
                let mut pts: Vec<Vec<f64>> = Vec::new();
                for (r, &i) in idx.iter().enumerate() {
                    let fully = (1..k).all(|kk| {
                        g.y_extras(i).get(kk - 1).map_or(false, |v| v.is_finite())
                    });
                    if fully {
                        pts.push((0..k).map(|kk| self.y_std_obj[kk][r]).collect());
                    }
                }
                let front: Vec<Vec<f64>> = objectives::pareto_front_indices(&pts)
                    .into_iter()
                    .map(|i| pts[i].clone())
                    .collect();
                let ref_point = objectives::hv_reference(&front, k, 1.0)
                    .unwrap_or_else(|| vec![-3.0; k]);
                let hv_front = objectives::hypervolume(&front, &ref_point);
                Some(MoBatch { y_best: y_best_obj, front, ref_point, hv_front })
            }
        };

        BatchCtx { idx, y_best, incumbent, mo }
    }

    /// Multi-objective candidate scoring: one panel pass over the shared
    /// factor with K target columns (conditioning targets padded with
    /// the per-fantasy lies — standardised 0 in every column), then the
    /// scalarised or hypervolume acquisition fills `ws.gain`.
    fn score_multi(&mut self, g: &mut SurrogateGuard<'_>, ctx: &BatchCtx, c: usize) {
        let mo = ctx.mo.as_ref().expect("score_multi without multi-objective context");
        let k = self.y_std_obj.len();
        let total = g.total();
        // Pad the per-objective targets to the factor's current row
        // count, reusing column capacity across proposals.
        self.y_pad_obj.resize(k, Vec::new());
        for kk in 0..k {
            let col = &mut self.y_pad_obj[kk];
            col.clear();
            col.extend_from_slice(&self.y_std_obj[kk]);
            // Constant-liar fantasies lie at the standardised mean of
            // every objective, exactly like the single-objective path.
            col.resize(total, 0.0);
        }
        {
            let refs: Vec<&[f64]> = self.y_pad_obj.iter().map(|v| v.as_slice()).collect();
            g.score_multi_into(&self.cand_flat, c, &refs, &mut self.ws);
        }

        let acq = self.acq_alpha;
        let moc = self.multi.as_ref().expect("multi context without declared objectives");
        // K-element optimistic-point scratch, reused across proposals.
        self.mo_opt.clear();
        self.mo_opt.resize(k, 0.0);
        match &moc.scalarize {
            Scalarization::Weighted(w) => {
                // With positive weights a candidate whose optimistic
                // vector is dominated can never argmax (pinned in
                // rust/tests/multi_objective.rs).
                for j in 0..c {
                    for kk in 0..k {
                        self.mo_opt[kk] = self.ws.mean_obj[kk * c + j] + acq * self.ws.std[j];
                    }
                    self.ws.gain[j] = objectives::weighted_gain(w, &self.mo_opt, &mo.y_best);
                }
            }
            Scalarization::Smsego => {
                // SMSego: hypervolume gain of the optimistic candidate
                // point over the batch's non-dominated front. The last
                // slot of `with_u` is rewritten per candidate. Most
                // optimistic points are dominated (zero gain); a tiny
                // equal-weight scalarised term keeps the ranking
                // informative instead of degenerating to index order.
                // (`with_u` is rebuilt per proposal, not per candidate;
                // the c hypervolume sweeps below dominate its cost.)
                let mut with_u: Vec<Vec<f64>> = mo.front.clone();
                with_u.push(vec![0.0; k]);
                for j in 0..c {
                    for kk in 0..k {
                        self.mo_opt[kk] = self.ws.mean_obj[kk * c + j] + acq * self.ws.std[j];
                    }
                    with_u.last_mut().expect("candidate slot").copy_from_slice(&self.mo_opt);
                    let hv_gain =
                        objectives::hypervolume(&with_u, &mo.ref_point) - mo.hv_front;
                    let tie: f64 = self
                        .mo_opt
                        .iter()
                        .zip(&mo.y_best)
                        .map(|(o, b)| o - b)
                        .sum();
                    self.ws.gain[j] = hv_gain.max(0.0) + 1e-9 * tie;
                }
            }
        }
    }

    /// One BO proposal against the guarded shared model. `inc_ready`
    /// tracks per-batch factor state: once the factor is synced, targeted
    /// and fantasy-extended, later proposals in the same `ask` reuse it
    /// (the fantasy-batch contract — see `ask`).
    fn propose_bo(
        &mut self,
        g: &mut SurrogateGuard<'_>,
        ctx: &BatchCtx,
        inc_ready: &mut bool,
    ) -> Config {
        let dim = self.space.dim();
        let n_cand = self.gen_candidates(&ctx.incumbent);

        let mut scored = false;
        if self.surrogate.use_engine_incremental() {
            if !*inc_ready {
                *inc_ready = self.setup_incremental(g, &ctx.idx);
            }
            if *inc_ready {
                let c = self.cand_flat.len() / dim;
                if ctx.mo.is_some() {
                    self.score_multi(g, ctx, c);
                } else {
                    g.score_into(&self.cand_flat, c, self.acq_alpha, ctx.y_best, &mut self.ws);
                }
                scored = true;
            }
        }
        if !scored && !self.generic_scores(g, &ctx.idx, ctx.y_best) {
            return self.space.random(&mut self.rng);
        }

        // Highest-gain candidate whose snapped config is neither measured
        // nor already in flight.
        debug_assert_eq!(self.ws.gain.len(), n_cand);
        for &ci in self.ws.argsort_gain_desc() {
            let cfg = self.space.from_unit(&self.cand_flat[ci * dim..(ci + 1) * dim]);
            if !self.observed.iter().any(|c| c == &cfg)
                && !self.book.open_configs().any(|c| c == &cfg)
            {
                return cfg;
            }
        }
        // Everything scored is already measured: random restart.
        self.space.random(&mut self.rng)
    }
}

impl<S: Surrogate> Tuner for BayesOpt<S> {
    fn name(&self) -> &'static str {
        "bayesian-optimization"
    }

    /// Fantasy-batch ask: the model lock is taken once per batch; each
    /// issued trial is immediately extended into the factor as a
    /// constant-liar fantasy so later proposals in the batch condition on
    /// it, and all fantasies are retracted together when the guard drops
    /// — one extend/retract cycle per batch, n scored pools.
    fn ask(&mut self, n: usize) -> Vec<Trial> {
        // A shared factor that already holds a full design's worth of
        // observations (sibling sessions, warm starts) makes the random
        // initial design redundant — skip straight to model proposals.
        if !self.pending_init.is_empty() && self.shared.total_observations() >= INIT_DESIGN {
            self.pending_init.clear();
        }
        let shared = self.shared.clone_handle();
        let mut guard: Option<SurrogateGuard<'_>> = None;
        let mut ctx: Option<BatchCtx> = None;
        let mut inc_ready = false;
        let mut out = Vec::with_capacity(n);
        for slot in 0..n {
            let cfg = if let Some(cfg) = self.pending_init.pop() {
                cfg
            } else {
                if guard.is_none() {
                    // Drains every queued tell (rank-1 appends, in
                    // observation order) before the first proposal.
                    let mut g = shared.lock();
                    // Engine-local scoring knobs, pushed per batch: a
                    // sibling engine sharing the handle may have set its
                    // own (last locker wins — outputs are unaffected,
                    // threads are bit-identical and the tier is applied
                    // per scoring pass).
                    g.set_score_threads(self.score_threads);
                    g.set_score_tier(self.score_tier);
                    guard = Some(g);
                }
                let g = guard.as_mut().unwrap();
                if g.len() < 2 {
                    self.space.random(&mut self.rng)
                } else {
                    if ctx.is_none() {
                        // The store is frozen while the guard is held, so
                        // the conditioning context serves the whole batch.
                        ctx = Some(self.batch_context(g, &mut inc_ready));
                    }
                    let ctx = ctx.as_ref().unwrap();
                    self.propose_bo(g, ctx, &mut inc_ready)
                }
            };
            let trial = self.book.issue(cfg);
            if inc_ready && slot + 1 < n {
                // Keep the factor conditioned on the new in-flight trial
                // for the rest of the batch.
                let g = guard.as_mut().unwrap();
                if g.total() < self.hyper.max_history {
                    let u = self.space.to_unit(&trial.config);
                    let _ = g.extend_fantasy(&u, 0.0);
                }
            }
            out.push(trial);
        }
        out
        // guard drops here: all batch fantasies retract in one truncation
    }

    fn tell(&mut self, id: TrialId, m: &Measurement) {
        if let Some(cfg) = self.book.settle(id) {
            let u = self.space.to_unit(&cfg);
            // Enqueue only — never blocks on a concurrent scoring pass;
            // the next ask folds it into the factor in observation order.
            match &self.multi {
                Some(mo) => {
                    let (ys, missing) = mo.set.extract(m);
                    if !missing.is_empty() {
                        let names: Vec<&str> = missing
                            .iter()
                            .map(|&k| mo.set.defs()[k].name.as_str())
                            .collect();
                        eprintln!(
                            "tftune: trial {id} did not measure declared objective \
                             column(s) {names:?}; conditioning it on its measured \
                             columns only"
                        );
                    }
                    self.shared.tell_multi(u, ys);
                }
                None => self.shared.tell(u, m.value),
            }
            self.observed.push(cfg);
        }
    }

    /// Inject a past observation (warm start / duplicate-history stress).
    fn warm_start(&mut self, config: &Config, value: f64) {
        let u = self.space.to_unit(config);
        self.shared.tell(u, value);
        self.observed.push(config.clone());
    }

    /// Warm start with a recorded objective vector (primary first): the
    /// resumed store gets the same K columns the interrupted run told, so
    /// a multi-objective acquisition picks up where it left off.
    fn warm_start_obs(&mut self, config: &Config, value: f64, objectives: &[f64]) {
        if objectives.is_empty() {
            self.warm_start(config, value);
            return;
        }
        let u = self.space.to_unit(config);
        self.shared.tell_multi(u, objectives.to_vec());
        self.observed.push(config.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{ExactRefitSurrogate, ARTIFACT_MAX_HISTORY};
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    fn quadratic(s: &SearchSpace, target: &Config) -> impl Fn(&Config) -> f64 {
        let tn = s.to_unit(target);
        let s = s.clone();
        move |c: &Config| {
            let u = s.to_unit(c);
            10.0 - 10.0 * u.iter().zip(&tn).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        }
    }

    /// ask(1)/tell one step against a closure objective.
    fn step<S: Surrogate>(bo: &mut BayesOpt<S>, obj: impl Fn(&Config) -> f64) -> (Config, f64) {
        let t = bo.ask(1).pop().unwrap();
        let v = obj(&t.config);
        bo.tell(t.id, &Measurement::new(v));
        (t.config, v)
    }

    #[test]
    fn finds_good_region_on_quadratic() {
        let s = space();
        let target = vec![3, 40, 640, 60, 36];
        let obj = quadratic(&s, &target);
        let mut bo = BayesOpt::new(s.clone(), 5);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..40 {
            let (_, v) = step(&mut bo, &obj);
            best = best.max(v);
        }
        assert!(best > 9.5, "BO best {best} too low");
    }

    #[test]
    fn beats_random_search_on_smooth_objective() {
        let s = space();
        let target = vec![2, 24, 448, 20, 30];
        let obj = quadratic(&s, &target);
        let mut seeds_bo_wins = 0;
        for seed in 0..5 {
            let mut bo = BayesOpt::new(s.clone(), seed);
            let mut rs = super::super::random::RandomSearch::new(s.clone(), seed);
            let mut best_bo = f64::NEG_INFINITY;
            let mut best_rs = f64::NEG_INFINITY;
            for _ in 0..30 {
                let (_, v) = step(&mut bo, &obj);
                best_bo = best_bo.max(v);
                let t = rs.ask(1).pop().unwrap();
                best_rs = best_rs.max(obj(&t.config));
                rs.tell(t.id, &Measurement::new(0.0));
            }
            if best_bo >= best_rs {
                seeds_bo_wins += 1;
            }
        }
        assert!(seeds_bo_wins >= 4, "BO won only {seeds_bo_wins}/5 seeds");
    }

    #[test]
    fn exploration_signature_full_range_coverage() {
        // Table 2: BO samples ~100% of every parameter's range.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 9);
        let mut h = crate::history::History::new();
        for _ in 0..50 {
            let (c, v) = step(&mut bo, &obj);
            h.push(c, v);
        }
        let pct = h.sampled_range_pct(&s).unwrap();
        let avg = pct.iter().sum::<f64>() / pct.len() as f64;
        assert!(avg > 80.0, "BO coverage too low: {pct:?}");
    }

    #[test]
    fn proposals_on_grid_no_duplicate_spam() {
        let s = space();
        prop::check("bo on grid", 5, |rng| {
            let mut bo = BayesOpt::new(s.clone(), rng.next_u64());
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..25 {
                let t = bo.ask(1).pop().unwrap();
                assert!(s.contains(&t.config));
                seen.insert(t.config.clone());
                bo.tell(t.id, &Measurement::new(rng.range_f64(0.0, 1.0)));
            }
            // BO explicitly avoids re-proposing seen configs
            assert!(seen.len() >= 23, "too many duplicates: {}", seen.len());
        });
    }

    #[test]
    fn batched_ask_spreads_via_constant_liar() {
        // After the initial design, a batch must contain distinct configs:
        // the fantasies suppress re-proposing the same optimistic point.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 11);
        for _ in 0..INIT_DESIGN + 2 {
            step(&mut bo, &obj);
        }
        let batch = bo.ask(6);
        assert_eq!(batch.len(), 6);
        let mut ids: Vec<_> = batch.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 6, "trial ids must be unique");
        let mut cfgs: Vec<_> = batch.iter().map(|t| t.config.clone()).collect();
        cfgs.sort();
        cfgs.dedup();
        assert_eq!(cfgs.len(), 6, "batch collapsed onto duplicate configs");
        // the batch fantasies must have retracted when the ask finished
        assert_eq!(bo.surrogate_handle().lock().total(), INIT_DESIGN + 2);
        // out-of-order completion must be accepted
        for t in batch.iter().rev() {
            bo.tell(t.id, &Measurement::new(obj(&t.config)));
        }
        assert_eq!(bo.book.open_len(), 0);
    }

    #[test]
    fn conditioning_set_caps_at_window() {
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 3);
        let window = bo.hyper().max_history;
        let mut rng = Rng::new(1);
        for i in 0..(window + 40) {
            let c = s.random(&mut rng);
            bo.warm_start(&c, i as f64);
        }
        let idx = bo.surrogate_handle().lock().conditioning_set();
        assert_eq!(idx.len(), window);
        // the globally best observation (last, value = max) must be kept
        assert!(idx.contains(&(window + 39)));
    }

    #[test]
    fn history_window_is_engine_config() {
        // Satellite: the window is a GpHyper field, not a free constant —
        // overriding it must narrow the conditioning set everywhere.
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 4).with_history_window(16);
        assert_eq!(bo.hyper().max_history, 16);
        let mut rng = Rng::new(2);
        for i in 0..40 {
            let c = s.random(&mut rng);
            bo.warm_start(&c, i as f64);
        }
        assert_eq!(bo.surrogate_handle().lock().conditioning_set().len(), 16);
    }

    #[test]
    fn unbounded_window_conditions_on_full_history() {
        // Satellite: with_history_window(None) lifts the N_PAD-parity cap
        // for native-only runs — the conditioning set is the full history.
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 13).with_history_window(None);
        assert_eq!(bo.hyper().max_history, UNBOUNDED_HISTORY);
        let n = ARTIFACT_MAX_HISTORY + 20;
        let mut rng = Rng::new(6);
        for i in 0..n {
            let c = s.random(&mut rng);
            bo.warm_start(&c, (i as f64 * 0.7).sin());
        }
        assert_eq!(bo.surrogate_handle().lock().conditioning_set().len(), n);
        // proposing over the lifted window still works
        let t = bo.ask(1);
        assert_eq!(t.len(), 1);
        assert!(s.contains(&t[0].config));
    }

    #[test]
    fn engines_sharing_a_handle_condition_one_model() {
        // Two engines attached to one handle: both tell into the same
        // factor, and each conditions on the union of observations.
        let s = space();
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut a = BayesOpt::new(s.clone(), 1).with_shared_surrogate(shared.clone());
        let mut b = BayesOpt::new(s.clone(), 2).with_shared_surrogate(shared.clone());
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        for _ in 0..12 {
            step(&mut a, &obj);
        }
        assert_eq!(shared.total_observations(), 12);
        for _ in 0..12 {
            step(&mut b, &obj);
        }
        assert_eq!(shared.total_observations(), 24);
        let g = shared.lock();
        assert_eq!(g.len(), 24, "both engines' tells landed in one store");
    }

    #[test]
    fn populated_shared_handle_skips_the_init_design() {
        // A fresh engine attached to a factor that already holds a full
        // design's worth of observations proposes from the model at once
        // instead of burning its budget on Latin-hypercube randoms.
        let s = space();
        let shared = SharedSurrogate::new(GpHyper::default());
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut seeder = BayesOpt::new(s.clone(), 30).with_shared_surrogate(shared.clone());
        for _ in 0..INIT_DESIGN + 4 {
            step(&mut seeder, &obj);
        }
        let mut fresh = BayesOpt::new(s.clone(), 31).with_shared_surrogate(shared.clone());
        let batch = fresh.ask(2);
        assert_eq!(batch.len(), 2);
        assert!(fresh.pending_init.is_empty(), "init design should be discarded");
        for t in &batch {
            assert!(s.contains(&t.config));
        }
    }

    #[test]
    fn sibling_hyper_override_is_adopted_not_reverted() {
        // A builder override through one handle must win group-wide: the
        // other engine adopts it on its next ask instead of reverting it
        // (which would rebuild the shared factor on every alternating ask).
        let s = space();
        let shared = SharedSurrogate::new(GpHyper::default());
        let obj = quadratic(&s, &vec![3, 30, 576, 80, 40]);
        let mut a = BayesOpt::new(s.clone(), 21).with_shared_surrogate(shared.clone());
        for _ in 0..INIT_DESIGN + 2 {
            step(&mut a, &obj);
        }
        let _b = BayesOpt::new(s.clone(), 22)
            .with_shared_surrogate(shared.clone())
            .with_history_window(16);
        assert_eq!(shared.hyper().max_history, 16);
        let t = a.ask(1).pop().unwrap();
        assert!(s.contains(&t.config));
        assert_eq!(a.hyper().max_history, 16, "sibling override not adopted");
    }

    #[test]
    fn scratch_engine_pays_no_factor_cost() {
        // Fused-refit surrogates never score through the factor; their
        // tells must not trigger eager rank-1 appends.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::with_surrogate(s.clone(), 23, ExactRefitSurrogate);
        for _ in 0..INIT_DESIGN + 3 {
            step(&mut bo, &obj);
        }
        let handle = bo.surrogate_handle();
        let g = handle.lock();
        assert_eq!(g.len(), INIT_DESIGN + 3, "observations still recorded");
        assert_eq!(g.total(), 0, "no factor rows for a fused-refit surrogate");
    }

    #[test]
    fn matern_kernel_engine_smoke() {
        let s = space();
        let target = vec![3, 40, 640, 60, 36];
        let obj = quadratic(&s, &target);
        let mut bo = BayesOpt::new(s.clone(), 6).with_kernel(KernelKind::Matern52);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..30 {
            let (_, v) = step(&mut bo, &obj);
            best = best.max(v);
        }
        assert!(best > 9.0, "Matérn BO best {best} too low");
    }

    #[test]
    fn lengthscale_selection_smoke() {
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 8).with_lengthscale_selection();
        for _ in 0..20 {
            step(&mut bo, &obj);
        }
        // the selected lengthscale must be one of the grid values
        let ls = bo.hyper().lengthscale;
        assert!(
            crate::gp::LENGTHSCALE_GRID.contains(&ls),
            "selected lengthscale {ls} not on grid"
        );
    }

    #[test]
    fn multi_objective_engine_degrades_missing_columns() {
        // Trials missing the declared p99 column (or carrying NaN) must
        // degrade to primary-only conditioning, never crash the ask.
        let s = space();
        let set = ObjectiveSet::parse("throughput,p99:min").unwrap();
        let mut bo = BayesOpt::new(s.clone(), 31)
            .with_objectives(set, Scalarization::Weighted(vec![0.7, 0.3]));
        assert!(bo.objective_set().is_some());
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        for i in 0..INIT_DESIGN + 8 {
            let t = bo.ask(1).pop().unwrap();
            assert!(s.contains(&t.config));
            let v = obj(&t.config);
            let m = match i % 4 {
                0 => Measurement::new(v), // column absent entirely
                1 => Measurement::new(v).with_metadata("p99", f64::NAN),
                _ => Measurement::new(v).with_metadata("p99", 12.0 - v),
            };
            bo.tell(t.id, &m);
        }
        let batch = bo.ask(3);
        assert_eq!(batch.len(), 3);
        for t in &batch {
            assert!(s.contains(&t.config));
        }
        // fantasies retracted after the multi-objective batch too
        assert_eq!(bo.surrogate_handle().lock().total(), INIT_DESIGN + 8);
    }

    #[test]
    fn multi_objective_smsego_finds_a_trade_off_front() {
        // Bi-objective with an analytic trade-off along inter_op: the
        // SMSego engine must populate more than one point of the front
        // (a single-objective engine would collapse onto one end).
        let s = space();
        let set = ObjectiveSet::parse("throughput,p99:min").unwrap();
        let mut bo = BayesOpt::new(s.clone(), 32).with_objectives(set.clone(), Scalarization::Smsego);
        let mut h = crate::history::History::new();
        for _ in 0..30 {
            let t = bo.ask(1).pop().unwrap();
            let u = s.to_unit(&t.config);
            let tp = 10.0 * u[0] - 2.0 * u[1] * u[1];
            let p99 = 2.0 + 8.0 * u[0] * u[0] + 2.0 * u[1] * u[1];
            let m = Measurement::new(tp).with_metadata("p99", p99);
            let (ys, missing) = set.extract(&m);
            assert!(missing.is_empty());
            h.push_trial_multi(t.id, t.config.clone(), &m, ys);
            bo.tell(t.id, &m);
        }
        let front = h.pareto_front();
        assert!(
            front.len() >= 2,
            "SMSego engine collapsed onto one point: front {}",
            front.len()
        );
    }

    #[test]
    #[should_panic(expected = "native incremental surrogate")]
    fn multi_objective_rejects_fused_surrogates() {
        let s = space();
        let set = ObjectiveSet::parse("a,b:min").unwrap();
        let _ = BayesOpt::with_surrogate(s, 1, ExactRefitSurrogate)
            .with_objectives(set, Scalarization::Smsego);
    }

    #[test]
    fn parallel_scoring_engine_proposes_identically() {
        // Thread-parallel scoring is bit-identical to serial, so the
        // whole proposal trajectory must match configuration-for-
        // configuration (same seed, same tells).
        let s = space();
        let obj = quadratic(&s, &vec![3, 30, 576, 80, 40]);
        let mut serial = BayesOpt::new(s.clone(), 19);
        let mut par = BayesOpt::new(s.clone(), 19).with_score_threads(4);
        assert_eq!(par.score_threads(), 4);
        for step_i in 0..20 {
            let a = serial.ask(1).pop().unwrap();
            let b = par.ask(1).pop().unwrap();
            assert_eq!(a.config, b.config, "trajectories diverged at step {step_i}");
            serial.tell(a.id, &Measurement::new(obj(&a.config)));
            par.tell(b.id, &Measurement::new(obj(&b.config)));
        }
    }

    #[test]
    fn incremental_and_scratch_engines_propose_identically() {
        // The in-module twin of the integration-level trajectory pin: the
        // incremental session and the scratch-refit reference must produce
        // identical serial trajectories (same seed, same tells).
        let s = space();
        let obj = quadratic(&s, &vec![3, 30, 576, 80, 40]);
        let mut inc = BayesOpt::new(s.clone(), 17);
        let mut scratch = BayesOpt::with_surrogate(s.clone(), 17, ExactRefitSurrogate);
        for step_i in 0..25 {
            let a = inc.ask(1).pop().unwrap();
            let b = scratch.ask(1).pop().unwrap();
            assert_eq!(a.config, b.config, "trajectories diverged at step {step_i}");
            inc.tell(a.id, &Measurement::new(obj(&a.config)));
            scratch.tell(b.id, &Measurement::new(obj(&b.config)));
        }
    }
}
