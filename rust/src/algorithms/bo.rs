//! Bayesian-optimization engine (paper §2.2): Gaussian-process surrogate
//! + SMSego-style acquisition, built on the incremental surrogate
//! subsystem (`crate::gp`).
//!
//! Per iteration:
//!   1. normalise the history to the unit cube, standardise y,
//!   2. generate a candidate set (global uniform samples + local Gaussian
//!      perturbations of the incumbent — the explore/exploit mix),
//!   3. score every candidate's optimistic gain (mu + alpha*sigma) - y_best,
//!   4. propose the highest-gain unseen candidate.
//!
//! Step 3 is the numeric hot path. With the native stack the engine keeps
//! a **persistent [`IncrementalGp`]** across the whole run: each `tell`
//! folds its observation into the Cholesky factor as an O(n²) rank-1
//! append (no O(n³) refit), each batched `ask` conditions on in-flight
//! trials by *extending* the factor with constant-liar fantasies and
//! *retracting* them after scoring (O(n²) per fantasy), and the
//! 512-candidate pool is scored through one blocked cross-kernel panel +
//! multi-RHS triangular solve with zero heap allocation
//! ([`ScoreWorkspace`]). The model is keyed by the observation list it
//! has factored in (`model_idx`): as long as the conditioning set only
//! grows, appends are rank-1; if it is reshaped (window overflow, new
//! hypers), the factor is rebuilt.
//!
//! Surrogates that refit in one fused call still go through
//! [`Surrogate::fit_score`]: the production HLO artifact (L2 JAX graph +
//! L1 Pallas RBF kernel, via PJRT — `runtime::GpSurrogate`) and the
//! scratch-refit reference path (`ExactRefitSurrogate`). Python is never
//! on this path. Both routes consume the same [`GpHyper`] (kernel,
//! lengthscale, conditioning window), so they stay interchangeable.

use super::{Trial, TrialBook, TrialId, Tuner};
use crate::gp::{
    select_lengthscale, GpHyper, IncrementalGp, KernelKind, NativeSurrogate, ScoreWorkspace,
    Surrogate,
};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::{stats, Rng};

/// Initial Latin-hypercube design size.
pub const INIT_DESIGN: usize = 8;
/// Candidates scored per iteration (matches the AOT artifact's C_CAND).
pub const CANDIDATES: usize = 512;
/// Fraction of candidates drawn globally (rest perturb the incumbent).
const GLOBAL_FRAC: f64 = 0.75;
/// Stddev (unit-cube) of local perturbations.
const LOCAL_SIGMA: f64 = 0.08;
/// Acquisition optimism (alpha in (mu + alpha*sigma) - y_best).
pub const ACQ_ALPHA: f64 = 1.5;

/// One settled observation. (Observations are keyed by their append-only
/// index in `observed` — `tell` order — which is what `model_idx` stores;
/// the trial id itself is consumed by `TrialBook::settle` and not needed
/// afterwards.)
struct Obs {
    /// Unit-cube coordinates.
    x: Vec<f64>,
    /// Raw objective value.
    y: f64,
    config: Config,
}

pub struct BayesOpt<S: Surrogate = NativeSurrogate> {
    space: SearchSpace,
    rng: Rng,
    surrogate: S,
    /// Kernel + lengthscale + noise + conditioning window, shared by every
    /// surrogate path (incremental, scratch oracle, HLO artifact).
    hyper: GpHyper,
    /// Acquisition optimism (ablatable; default ACQ_ALPHA).
    acq_alpha: f64,
    /// Candidate-pool size per iteration (ablatable; default CANDIDATES).
    n_candidates: usize,
    /// Re-select the lengthscale by log marginal likelihood as history
    /// grows (off by default: the paper fixes hypers per run).
    tune_lengthscale: bool,
    /// History size at which the lengthscale was last selected.
    ls_selected_at: usize,
    /// Initial design not yet proposed.
    pending_init: Vec<Config>,
    /// All settled observations, in tell order (append-only).
    observed: Vec<Obs>,
    /// Open trials. Pending configurations are conditioned into the GP as
    /// constant-liar fantasies (at the standardised mean) so a batch of
    /// `ask`ed trials spreads out instead of collapsing onto one point.
    book: TrialBook,
    /// Persistent incremental model (native stack only).
    model: IncrementalGp,
    /// Indices into `observed` currently factored into `model`, in factor
    /// row order — the key deciding between rank-1 append and rebuild.
    model_idx: Vec<usize>,
    /// Reusable scoring buffers (zero-allocation hot path).
    ws: ScoreWorkspace,
    /// Flattened candidate pool (n_candidates × dim), reused per ask.
    cand_flat: Vec<f64>,
    /// Reusable raw/standardised conditioning targets.
    y_raw: Vec<f64>,
    y_std: Vec<f64>,
}

impl BayesOpt<NativeSurrogate> {
    /// BO with the native surrogate stack (persistent incremental GP).
    pub fn new(space: SearchSpace, seed: u64) -> BayesOpt<NativeSurrogate> {
        BayesOpt::with_surrogate(space, seed, NativeSurrogate)
    }
}

impl<S: Surrogate> BayesOpt<S> {
    /// BO with an explicit surrogate (e.g. `runtime::GpSurrogate` for the
    /// AOT/PJRT path, or `ExactRefitSurrogate` for the scratch reference).
    pub fn with_surrogate(space: SearchSpace, seed: u64, surrogate: S) -> BayesOpt<S> {
        let mut rng = Rng::new(seed);
        let mut pending_init = space.latin_hypercube(INIT_DESIGN, &mut rng);
        pending_init.reverse(); // pop from back in LHS order
        let hyper = GpHyper::default();
        BayesOpt {
            space,
            rng,
            surrogate,
            hyper,
            acq_alpha: ACQ_ALPHA,
            n_candidates: CANDIDATES,
            tune_lengthscale: false,
            ls_selected_at: 0,
            pending_init,
            observed: Vec::new(),
            book: TrialBook::new(),
            model: IncrementalGp::new(hyper),
            model_idx: Vec::new(),
            ws: ScoreWorkspace::default(),
            cand_flat: Vec::new(),
            y_raw: Vec::new(),
            y_std: Vec::new(),
        }
    }

    /// Override the acquisition optimism (ablation A2).
    pub fn with_acq_alpha(mut self, alpha: f64) -> BayesOpt<S> {
        assert!(alpha >= 0.0, "acquisition alpha must be non-negative");
        self.acq_alpha = alpha;
        self
    }

    /// Override the candidate-pool size (ablation A3). Capped at the AOT
    /// artifact's C_CAND when the HLO surrogate is used.
    pub fn with_candidates(mut self, n: usize) -> BayesOpt<S> {
        assert!(n > 0, "need at least one candidate");
        self.n_candidates = n.min(CANDIDATES);
        self
    }

    /// Covariance kernel for the surrogate (native stack; the HLO artifact
    /// is RBF-only and rejects other kinds).
    pub fn with_kernel(mut self, kind: KernelKind) -> BayesOpt<S> {
        self.hyper.kernel = kind;
        self.reset_model();
        self
    }

    /// Override the surrogate conditioning window. Must stay ≤ the
    /// artifact's compiled N_PAD when the HLO surrogate is used
    /// (`runtime::GpSurrogate` enforces this at score time).
    pub fn with_history_window(mut self, window: usize) -> BayesOpt<S> {
        assert!(window > 0, "history window must be positive");
        self.hyper.max_history = window;
        self.reset_model();
        self
    }

    /// Re-select the lengthscale over [`crate::gp::LENGTHSCALE_GRID`] by
    /// log marginal likelihood whenever the history reaches a power-of-two
    /// size (rebuilds the incremental factor on change).
    pub fn with_lengthscale_selection(mut self) -> BayesOpt<S> {
        self.tune_lengthscale = true;
        self
    }

    /// The hypers every surrogate path is currently driven by.
    pub fn hyper(&self) -> GpHyper {
        self.hyper
    }

    fn reset_model(&mut self) {
        self.model.set_hyper(self.hyper);
        self.model_idx.clear();
    }

    /// The conditioning set: all history if it fits the window, else the
    /// best window/4 plus the most recent remainder.
    fn conditioning_set(&self) -> Vec<usize> {
        let n = self.observed.len();
        let window = self.hyper.max_history;
        if n <= window {
            return (0..n).collect();
        }
        let keep_best = window / 4;
        let mut by_value: Vec<usize> = (0..n).collect();
        // total_cmp keeps the sort panic-free (and deterministic) even if
        // an evaluator ever reports a NaN measurement.
        by_value.sort_by(|&a, &b| self.observed[b].y.total_cmp(&self.observed[a].y));
        let mut chosen: Vec<usize> = by_value[..keep_best].to_vec();
        for i in (0..n).rev() {
            if chosen.len() >= window {
                break;
            }
            if !chosen.contains(&i) {
                chosen.push(i);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    /// Fill `cand_flat` with the explore/exploit candidate mix; returns
    /// the number of rows. No allocation once the buffer has warmed up.
    fn gen_candidates(&mut self, incumbent: &[f64]) -> usize {
        let dim = self.space.dim();
        let n_global = (self.n_candidates as f64 * GLOBAL_FRAC) as usize;
        self.cand_flat.clear();
        self.cand_flat.reserve(self.n_candidates * dim);
        for _ in 0..n_global * dim {
            let v = self.rng.f64();
            self.cand_flat.push(v);
        }
        for _ in n_global..self.n_candidates {
            for &x in incumbent {
                let v = (x + self.rng.normal() * LOCAL_SIGMA).clamp(0.0, 1.0);
                self.cand_flat.push(v);
            }
        }
        self.n_candidates
    }

    /// Score the pool through the persistent incremental model. Returns
    /// false (model cleared) if the factor could not be grown.
    fn incremental_scores(&mut self, idx: &[usize], y_best: f64) -> bool {
        // Rank-1 appends while the conditioning set extends the factored
        // one; any reshape (window overflow reordering, hyper change)
        // forces a rebuild.
        let keep = self.model_idx.len() <= idx.len()
            && self.model_idx.iter().zip(idx).all(|(a, b)| a == b);
        if !keep {
            self.model.clear();
            self.model_idx.clear();
        }
        let start = self.model_idx.len();
        for &i in &idx[start..] {
            if !self.model.push(&self.observed[i].x, 0.0) {
                self.model.clear();
                self.model_idx.clear();
                return false;
            }
            self.model_idx.push(i);
        }
        self.model.set_targets(&self.y_std);

        // Constant-liar fantasies for in-flight trials: pretend each lands
        // at the observed mean (standardised 0), which kills the variance
        // bonus around pending points and pushes the batch apart. Capped
        // so the conditioning set still fits the window / artifact N_PAD.
        let window = self.hyper.max_history;
        for cfg in self.book.open_configs() {
            if self.model.total() >= window {
                break;
            }
            let u = self.space.to_unit(cfg);
            if !self.model.extend_fantasy(&u, 0.0) {
                break;
            }
        }

        let n_cand = self.cand_flat.len() / self.space.dim();
        self.model.score_into(&self.cand_flat, n_cand, self.acq_alpha, y_best, &mut self.ws);
        self.model.retract_fantasies();
        true
    }

    /// Score the pool through `Surrogate::fit_score` (HLO artifact or
    /// scratch reference). Returns false on surrogate failure.
    fn generic_scores(&mut self, idx: &[usize], y_best: f64) -> bool {
        let dim = self.space.dim();
        let window = self.hyper.max_history;
        let mut x: Vec<Vec<f64>> = idx.iter().map(|&i| self.observed[i].x.clone()).collect();
        let mut y = self.y_std.clone();
        for cfg in self.book.open_configs() {
            if x.len() >= window {
                break;
            }
            x.push(self.space.to_unit(cfg));
            y.push(0.0);
        }
        let cands: Vec<Vec<f64>> = self.cand_flat.chunks(dim).map(|c| c.to_vec()).collect();
        match self.surrogate.fit_score(&x, &y, &cands, self.hyper, self.acq_alpha, y_best) {
            Ok(s) => {
                self.ws.mean = s.mean;
                self.ws.std = s.std;
                self.ws.gain = s.gain;
                true
            }
            Err(e) => {
                // Surrogate failure (singular kernel etc.): fall back to a
                // random proposal rather than aborting the tuning run.
                eprintln!("tftune: surrogate failed ({e}); proposing randomly");
                false
            }
        }
    }

    fn propose_bo(&mut self) -> Config {
        // Standardise y over the conditioning set.
        let idx = self.conditioning_set();
        self.y_raw.clear();
        for &i in &idx {
            let v = self.observed[i].y;
            self.y_raw.push(v);
        }
        let mean = stats::mean(&self.y_raw);
        let sd = stats::stddev(&self.y_raw).max(1e-9);
        self.y_std.clear();
        for k in 0..idx.len() {
            let v = (self.y_raw[k] - mean) / sd;
            self.y_std.push(v);
        }
        let y_best = self.y_std.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

        let incumbent = {
            let bi = stats::argmax(&self.y_raw);
            self.observed[idx[bi]].x.clone()
        };

        if self.tune_lengthscale {
            let n = idx.len();
            if n >= 4 && n.is_power_of_two() && n != self.ls_selected_at {
                let xs: Vec<Vec<f64>> =
                    idx.iter().map(|&i| self.observed[i].x.clone()).collect();
                let picked = select_lengthscale(&xs, &self.y_std, self.hyper);
                self.ls_selected_at = n;
                if picked != self.hyper {
                    self.hyper = picked;
                    self.reset_model();
                }
            }
        }

        let dim = self.space.dim();
        let n_cand = self.gen_candidates(&incumbent);

        let scored = if self.surrogate.use_engine_incremental() {
            self.incremental_scores(&idx, y_best)
        } else {
            false
        };
        if !scored && !self.generic_scores(&idx, y_best) {
            return self.space.random(&mut self.rng);
        }

        // Highest-gain candidate whose snapped config is neither measured
        // nor already in flight.
        debug_assert_eq!(self.ws.gain.len(), n_cand);
        for &ci in self.ws.argsort_gain_desc() {
            let cfg = self.space.from_unit(&self.cand_flat[ci * dim..(ci + 1) * dim]);
            if !self.observed.iter().any(|o| o.config == cfg)
                && !self.book.open_configs().any(|c| c == &cfg)
            {
                return cfg;
            }
        }
        // Everything scored is already measured: random restart.
        self.space.random(&mut self.rng)
    }
}

impl<S: Surrogate> Tuner for BayesOpt<S> {
    fn name(&self) -> &'static str {
        "bayesian-optimization"
    }

    fn ask(&mut self, n: usize) -> Vec<Trial> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let cfg = if let Some(cfg) = self.pending_init.pop() {
                cfg
            } else if self.observed.len() < 2 {
                self.space.random(&mut self.rng)
            } else {
                self.propose_bo()
            };
            out.push(self.book.issue(cfg));
        }
        out
    }

    fn tell(&mut self, id: TrialId, m: &Measurement) {
        if let Some(cfg) = self.book.settle(id) {
            let u = self.space.to_unit(&cfg);
            self.observed.push(Obs { x: u, y: m.value, config: cfg });
            self.append_latest_to_model();
        }
    }

    /// Inject a past observation (warm start / duplicate-history stress).
    fn warm_start(&mut self, config: &Config, value: f64) {
        let u = self.space.to_unit(config);
        self.observed.push(Obs { x: u, y: value, config: config.clone() });
        self.append_latest_to_model();
    }
}

impl<S: Surrogate> BayesOpt<S> {
    /// Eager rank-1 append of the newest observation into the persistent
    /// factor — the `tell` side of the incremental contract. Only valid
    /// while the conditioning set is the full (windowed) prefix of
    /// history; otherwise the next `ask` rebuilds lazily.
    fn append_latest_to_model(&mut self) {
        if !self.surrogate.use_engine_incremental() {
            return;
        }
        let i = self.observed.len() - 1;
        if self.observed.len() <= self.hyper.max_history && self.model_idx.len() == i {
            if self.model.push(&self.observed[i].x, 0.0) {
                self.model_idx.push(i);
            } else {
                self.model.clear();
                self.model_idx.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::ExactRefitSurrogate;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    fn quadratic(s: &SearchSpace, target: &Config) -> impl Fn(&Config) -> f64 {
        let tn = s.to_unit(target);
        let s = s.clone();
        move |c: &Config| {
            let u = s.to_unit(c);
            10.0 - 10.0 * u.iter().zip(&tn).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        }
    }

    /// ask(1)/tell one step against a closure objective.
    fn step<S: Surrogate>(bo: &mut BayesOpt<S>, obj: impl Fn(&Config) -> f64) -> (Config, f64) {
        let t = bo.ask(1).pop().unwrap();
        let v = obj(&t.config);
        bo.tell(t.id, &Measurement::new(v));
        (t.config, v)
    }

    #[test]
    fn finds_good_region_on_quadratic() {
        let s = space();
        let target = vec![3, 40, 640, 60, 36];
        let obj = quadratic(&s, &target);
        let mut bo = BayesOpt::new(s.clone(), 5);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..40 {
            let (_, v) = step(&mut bo, &obj);
            best = best.max(v);
        }
        assert!(best > 9.5, "BO best {best} too low");
    }

    #[test]
    fn beats_random_search_on_smooth_objective() {
        let s = space();
        let target = vec![2, 24, 448, 20, 30];
        let obj = quadratic(&s, &target);
        let mut seeds_bo_wins = 0;
        for seed in 0..5 {
            let mut bo = BayesOpt::new(s.clone(), seed);
            let mut rs = super::super::random::RandomSearch::new(s.clone(), seed);
            let mut best_bo = f64::NEG_INFINITY;
            let mut best_rs = f64::NEG_INFINITY;
            for _ in 0..30 {
                let (_, v) = step(&mut bo, &obj);
                best_bo = best_bo.max(v);
                let t = rs.ask(1).pop().unwrap();
                best_rs = best_rs.max(obj(&t.config));
                rs.tell(t.id, &Measurement::new(0.0));
            }
            if best_bo >= best_rs {
                seeds_bo_wins += 1;
            }
        }
        assert!(seeds_bo_wins >= 4, "BO won only {seeds_bo_wins}/5 seeds");
    }

    #[test]
    fn exploration_signature_full_range_coverage() {
        // Table 2: BO samples ~100% of every parameter's range.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 9);
        let mut h = crate::history::History::new();
        for _ in 0..50 {
            let (c, v) = step(&mut bo, &obj);
            h.push(c, v);
        }
        let pct = h.sampled_range_pct(&s).unwrap();
        let avg = pct.iter().sum::<f64>() / pct.len() as f64;
        assert!(avg > 80.0, "BO coverage too low: {pct:?}");
    }

    #[test]
    fn proposals_on_grid_no_duplicate_spam() {
        let s = space();
        prop::check("bo on grid", 5, |rng| {
            let mut bo = BayesOpt::new(s.clone(), rng.next_u64());
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..25 {
                let t = bo.ask(1).pop().unwrap();
                assert!(s.contains(&t.config));
                seen.insert(t.config.clone());
                bo.tell(t.id, &Measurement::new(rng.range_f64(0.0, 1.0)));
            }
            // BO explicitly avoids re-proposing seen configs
            assert!(seen.len() >= 23, "too many duplicates: {}", seen.len());
        });
    }

    #[test]
    fn batched_ask_spreads_via_constant_liar() {
        // After the initial design, a batch must contain distinct configs:
        // the fantasies suppress re-proposing the same optimistic point.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 11);
        for _ in 0..INIT_DESIGN + 2 {
            step(&mut bo, &obj);
        }
        let batch = bo.ask(6);
        assert_eq!(batch.len(), 6);
        let mut ids: Vec<_> = batch.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 6, "trial ids must be unique");
        let mut cfgs: Vec<_> = batch.iter().map(|t| t.config.clone()).collect();
        cfgs.sort();
        cfgs.dedup();
        assert_eq!(cfgs.len(), 6, "batch collapsed onto duplicate configs");
        // out-of-order completion must be accepted
        for t in batch.iter().rev() {
            bo.tell(t.id, &Measurement::new(obj(&t.config)));
        }
        assert_eq!(bo.book.open_len(), 0);
    }

    #[test]
    fn conditioning_set_caps_at_window() {
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 3);
        let window = bo.hyper().max_history;
        let mut rng = Rng::new(1);
        for i in 0..(window + 40) {
            let c = s.random(&mut rng);
            bo.warm_start(&c, i as f64);
        }
        let idx = bo.conditioning_set();
        assert_eq!(idx.len(), window);
        // the globally best observation (last, value = max) must be kept
        assert!(idx.contains(&(window + 39)));
    }

    #[test]
    fn history_window_is_engine_config() {
        // Satellite: the window is a GpHyper field, not a free constant —
        // overriding it must narrow the conditioning set everywhere.
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 4).with_history_window(16);
        assert_eq!(bo.hyper().max_history, 16);
        let mut rng = Rng::new(2);
        for i in 0..40 {
            let c = s.random(&mut rng);
            bo.warm_start(&c, i as f64);
        }
        assert_eq!(bo.conditioning_set().len(), 16);
    }

    #[test]
    fn matern_kernel_engine_smoke() {
        let s = space();
        let target = vec![3, 40, 640, 60, 36];
        let obj = quadratic(&s, &target);
        let mut bo = BayesOpt::new(s.clone(), 6).with_kernel(KernelKind::Matern52);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..30 {
            let (_, v) = step(&mut bo, &obj);
            best = best.max(v);
        }
        assert!(best > 9.0, "Matérn BO best {best} too low");
    }

    #[test]
    fn lengthscale_selection_smoke() {
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 8).with_lengthscale_selection();
        for _ in 0..20 {
            step(&mut bo, &obj);
        }
        // the selected lengthscale must be one of the grid values
        let ls = bo.hyper().lengthscale;
        assert!(
            crate::gp::LENGTHSCALE_GRID.contains(&ls),
            "selected lengthscale {ls} not on grid"
        );
    }

    #[test]
    fn incremental_and_scratch_engines_propose_identically() {
        // The in-module twin of the integration-level trajectory pin: the
        // incremental session and the scratch-refit reference must produce
        // identical serial trajectories (same seed, same tells).
        let s = space();
        let obj = quadratic(&s, &vec![3, 30, 576, 80, 40]);
        let mut inc = BayesOpt::new(s.clone(), 17);
        let mut scratch = BayesOpt::with_surrogate(s.clone(), 17, ExactRefitSurrogate);
        for step_i in 0..25 {
            let a = inc.ask(1).pop().unwrap();
            let b = scratch.ask(1).pop().unwrap();
            assert_eq!(a.config, b.config, "trajectories diverged at step {step_i}");
            inc.tell(a.id, &Measurement::new(obj(&a.config)));
            scratch.tell(b.id, &Measurement::new(obj(&b.config)));
        }
    }
}
