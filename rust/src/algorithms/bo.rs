//! Bayesian-optimization engine (paper §2.2): Gaussian-process surrogate
//! + SMSego-style acquisition.
//!
//! Per iteration:
//!   1. normalise the history to the unit cube, standardise y,
//!   2. generate a candidate set (global uniform samples + local Gaussian
//!      perturbations of the incumbent — the explore/exploit mix),
//!   3. fit the GP and score every candidate's optimistic gain
//!      (mu + alpha*sigma) - y_best,
//!   4. propose the highest-gain unseen candidate.
//!
//! Step 3 is the numeric hot path and runs through the [`crate::gp::Surrogate`]
//! abstraction: the production implementation executes the AOT-compiled
//! HLO artifact (L2 JAX graph + L1 Pallas RBF kernel) via PJRT
//! (`runtime::GpSurrogate`); the exact native GP is the oracle/fallback.
//! Python is never on this path.

use super::{TrialBook, Tuner};
use crate::gp::{GpHyper, NativeSurrogate, Surrogate};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::{stats, Rng};

/// Initial Latin-hypercube design size.
pub const INIT_DESIGN: usize = 8;
/// Candidates scored per iteration (matches the AOT artifact's C_CAND).
pub const CANDIDATES: usize = 512;
/// Fraction of candidates drawn globally (rest perturb the incumbent).
const GLOBAL_FRAC: f64 = 0.75;
/// Stddev (unit-cube) of local perturbations.
const LOCAL_SIGMA: f64 = 0.08;
/// Acquisition optimism (alpha in (mu + alpha*sigma) - y_best).
pub const ACQ_ALPHA: f64 = 1.5;
/// Most recent history points the surrogate conditions on (the AOT
/// artifact is compiled for at most this many; see python/compile/model.py).
pub const MAX_HISTORY: usize = 64;

pub struct BayesOpt<S: Surrogate = NativeSurrogate> {
    space: SearchSpace,
    rng: Rng,
    surrogate: S,
    hyper: GpHyper,
    /// Acquisition optimism (ablatable; default ACQ_ALPHA).
    acq_alpha: f64,
    /// Candidate-pool size per iteration (ablatable; default CANDIDATES).
    n_candidates: usize,
    /// Initial design not yet proposed.
    pending_init: Vec<Config>,
    /// All observations: (unit-cube x, raw y, config).
    observed: Vec<(Vec<f64>, f64, Config)>,
    /// Open trials. Pending configurations are conditioned into the GP as
    /// constant-liar fantasies (at the standardised mean) so a batch of
    /// `ask`ed trials spreads out instead of collapsing onto one point.
    book: TrialBook,
}

impl BayesOpt<NativeSurrogate> {
    /// BO with the exact native GP surrogate.
    pub fn new(space: SearchSpace, seed: u64) -> BayesOpt<NativeSurrogate> {
        BayesOpt::with_surrogate(space, seed, NativeSurrogate)
    }
}

impl<S: Surrogate> BayesOpt<S> {
    /// BO with an explicit surrogate (e.g. `runtime::GpSurrogate` for the
    /// AOT/PJRT path).
    pub fn with_surrogate(space: SearchSpace, seed: u64, surrogate: S) -> BayesOpt<S> {
        let mut rng = Rng::new(seed);
        let mut pending_init = space.latin_hypercube(INIT_DESIGN, &mut rng);
        pending_init.reverse(); // pop from back in LHS order
        BayesOpt {
            space,
            rng,
            surrogate,
            hyper: GpHyper::default(),
            acq_alpha: ACQ_ALPHA,
            n_candidates: CANDIDATES,
            pending_init,
            observed: Vec::new(),
            book: TrialBook::new(),
        }
    }

    /// Override the acquisition optimism (ablation A2).
    pub fn with_acq_alpha(mut self, alpha: f64) -> BayesOpt<S> {
        assert!(alpha >= 0.0, "acquisition alpha must be non-negative");
        self.acq_alpha = alpha;
        self
    }

    /// Override the candidate-pool size (ablation A3). Capped at the AOT
    /// artifact's C_CAND when the HLO surrogate is used.
    pub fn with_candidates(mut self, n: usize) -> BayesOpt<S> {
        assert!(n > 0, "need at least one candidate");
        self.n_candidates = n.min(CANDIDATES);
        self
    }

    /// The conditioning set: all history if it fits the artifact, else the
    /// best MAX_HISTORY/4 plus the most recent remainder.
    fn conditioning_set(&self) -> Vec<usize> {
        let n = self.observed.len();
        if n <= MAX_HISTORY {
            return (0..n).collect();
        }
        let keep_best = MAX_HISTORY / 4;
        let mut by_value: Vec<usize> = (0..n).collect();
        by_value.sort_by(|&a, &b| {
            self.observed[b].1.partial_cmp(&self.observed[a].1).unwrap()
        });
        let mut chosen: Vec<usize> = by_value[..keep_best].to_vec();
        for i in (0..n).rev() {
            if chosen.len() >= MAX_HISTORY {
                break;
            }
            if !chosen.contains(&i) {
                chosen.push(i);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    fn candidates(&mut self, incumbent: &[f64]) -> Vec<Vec<f64>> {
        let dim = self.space.dim();
        let n_global = (self.n_candidates as f64 * GLOBAL_FRAC) as usize;
        let mut cands = Vec::with_capacity(self.n_candidates);
        for _ in 0..n_global {
            cands.push((0..dim).map(|_| self.rng.f64()).collect());
        }
        while cands.len() < self.n_candidates {
            let p: Vec<f64> = incumbent
                .iter()
                .map(|&x| (x + self.rng.normal() * LOCAL_SIGMA).clamp(0.0, 1.0))
                .collect();
            cands.push(p);
        }
        cands
    }

    fn propose_bo(&mut self) -> Config {
        // Standardise y over the conditioning set.
        let idx = self.conditioning_set();
        let mut x: Vec<Vec<f64>> = idx.iter().map(|&i| self.observed[i].0.clone()).collect();
        let y_raw: Vec<f64> = idx.iter().map(|&i| self.observed[i].1).collect();
        let mean = stats::mean(&y_raw);
        let sd = stats::stddev(&y_raw).max(1e-9);
        let mut y: Vec<f64> = y_raw.iter().map(|v| (v - mean) / sd).collect();
        let y_best = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

        let incumbent = {
            let bi = stats::argmax(&y_raw);
            x[bi].clone()
        };

        // Constant-liar fantasies for in-flight trials: pretend each lands
        // at the observed mean (standardised 0), which kills the variance
        // bonus around pending points and pushes the batch apart. Capped so
        // the conditioning set still fits the AOT artifact's N_PAD.
        for cfg in self.book.open_configs() {
            if x.len() >= MAX_HISTORY {
                break;
            }
            x.push(self.space.to_unit(cfg));
            y.push(0.0);
        }

        let cands = self.candidates(&incumbent);

        let scores =
            match self.surrogate.fit_score(&x, &y, &cands, self.hyper, self.acq_alpha, y_best) {
            Ok(s) => s,
            Err(e) => {
                // Surrogate failure (singular kernel etc.): fall back to a
                // random proposal rather than aborting the tuning run.
                eprintln!("tftune: surrogate failed ({e}); proposing randomly");
                return self.space.random(&mut self.rng);
            }
        };

        // Highest-gain candidate whose snapped config is neither measured
        // nor already in flight.
        let mut order: Vec<usize> = (0..cands.len()).collect();
        order.sort_by(|&a, &b| scores.gain[b].partial_cmp(&scores.gain[a]).unwrap());
        for &ci in &order {
            let cfg = self.space.from_unit(&cands[ci]);
            if !self.observed.iter().any(|(_, _, c)| c == &cfg)
                && !self.book.open_configs().any(|c| c == &cfg)
            {
                return cfg;
            }
        }
        // Everything scored is already measured: random restart.
        self.space.random(&mut self.rng)
    }
}

impl<S: Surrogate> Tuner for BayesOpt<S> {
    fn name(&self) -> &'static str {
        "bayesian-optimization"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let cfg = if let Some(cfg) = self.pending_init.pop() {
                cfg
            } else if self.observed.len() < 2 {
                self.space.random(&mut self.rng)
            } else {
                self.propose_bo()
            };
            out.push(self.book.issue(cfg));
        }
        out
    }

    fn tell(&mut self, id: super::TrialId, m: &Measurement) {
        if let Some(cfg) = self.book.settle(id) {
            let u = self.space.to_unit(&cfg);
            self.observed.push((u, m.value, cfg));
        }
    }

    /// Inject a past observation (warm start / duplicate-history stress).
    fn warm_start(&mut self, config: &Config, value: f64) {
        let u = self.space.to_unit(config);
        self.observed.push((u, value, config.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    fn quadratic(s: &SearchSpace, target: &Config) -> impl Fn(&Config) -> f64 {
        let tn = s.to_unit(target);
        let s = s.clone();
        move |c: &Config| {
            let u = s.to_unit(c);
            10.0 - 10.0 * u.iter().zip(&tn).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        }
    }

    /// ask(1)/tell one step against a closure objective.
    fn step<S: Surrogate>(bo: &mut BayesOpt<S>, obj: impl Fn(&Config) -> f64) -> (Config, f64) {
        let t = bo.ask(1).pop().unwrap();
        let v = obj(&t.config);
        bo.tell(t.id, &Measurement::new(v));
        (t.config, v)
    }

    #[test]
    fn finds_good_region_on_quadratic() {
        let s = space();
        let target = vec![3, 40, 640, 60, 36];
        let obj = quadratic(&s, &target);
        let mut bo = BayesOpt::new(s.clone(), 5);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..40 {
            let (_, v) = step(&mut bo, &obj);
            best = best.max(v);
        }
        assert!(best > 9.5, "BO best {best} too low");
    }

    #[test]
    fn beats_random_search_on_smooth_objective() {
        let s = space();
        let target = vec![2, 24, 448, 20, 30];
        let obj = quadratic(&s, &target);
        let mut seeds_bo_wins = 0;
        for seed in 0..5 {
            let mut bo = BayesOpt::new(s.clone(), seed);
            let mut rs = super::super::random::RandomSearch::new(s.clone(), seed);
            let mut best_bo = f64::NEG_INFINITY;
            let mut best_rs = f64::NEG_INFINITY;
            for _ in 0..30 {
                let (_, v) = step(&mut bo, &obj);
                best_bo = best_bo.max(v);
                let t = rs.ask(1).pop().unwrap();
                best_rs = best_rs.max(obj(&t.config));
                rs.tell(t.id, &Measurement::new(0.0));
            }
            if best_bo >= best_rs {
                seeds_bo_wins += 1;
            }
        }
        assert!(seeds_bo_wins >= 4, "BO won only {seeds_bo_wins}/5 seeds");
    }

    #[test]
    fn exploration_signature_full_range_coverage() {
        // Table 2: BO samples ~100% of every parameter's range.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 9);
        let mut h = crate::history::History::new();
        for _ in 0..50 {
            let (c, v) = step(&mut bo, &obj);
            h.push(c, v);
        }
        let pct = h.sampled_range_pct(&s).unwrap();
        let avg = pct.iter().sum::<f64>() / pct.len() as f64;
        assert!(avg > 80.0, "BO coverage too low: {pct:?}");
    }

    #[test]
    fn proposals_on_grid_no_duplicate_spam() {
        let s = space();
        prop::check("bo on grid", 5, |rng| {
            let mut bo = BayesOpt::new(s.clone(), rng.next_u64());
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..25 {
                let t = bo.ask(1).pop().unwrap();
                assert!(s.contains(&t.config));
                seen.insert(t.config.clone());
                bo.tell(t.id, &Measurement::new(rng.range_f64(0.0, 1.0)));
            }
            // BO explicitly avoids re-proposing seen configs
            assert!(seen.len() >= 23, "too many duplicates: {}", seen.len());
        });
    }

    #[test]
    fn batched_ask_spreads_via_constant_liar() {
        // After the initial design, a batch must contain distinct configs:
        // the fantasies suppress re-proposing the same optimistic point.
        let s = space();
        let obj = quadratic(&s, &vec![2, 28, 512, 100, 28]);
        let mut bo = BayesOpt::new(s.clone(), 11);
        for _ in 0..INIT_DESIGN + 2 {
            step(&mut bo, &obj);
        }
        let batch = bo.ask(6);
        assert_eq!(batch.len(), 6);
        let mut ids: Vec<_> = batch.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 6, "trial ids must be unique");
        let mut cfgs: Vec<_> = batch.iter().map(|t| t.config.clone()).collect();
        cfgs.sort();
        cfgs.dedup();
        assert_eq!(cfgs.len(), 6, "batch collapsed onto duplicate configs");
        // out-of-order completion must be accepted
        for t in batch.iter().rev() {
            bo.tell(t.id, &Measurement::new(obj(&t.config)));
        }
        assert_eq!(bo.book.open_len(), 0);
    }

    #[test]
    fn conditioning_set_caps_at_artifact_size() {
        let s = space();
        let mut bo = BayesOpt::new(s.clone(), 3);
        let mut rng = Rng::new(1);
        for i in 0..(MAX_HISTORY + 40) {
            let c = s.random(&mut rng);
            bo.warm_start(&c, i as f64);
        }
        let idx = bo.conditioning_set();
        assert_eq!(idx.len(), MAX_HISTORY);
        // the globally best observation (last, value = max) must be kept
        assert!(idx.contains(&(MAX_HISTORY + 39)));
    }
}
