//! Coordinate-descent baseline — the systematic version of what a human
//! expert does manually: sweep one parameter at a time around the current
//! best, keep the winner, move to the next parameter, repeat.
//!
//! Included as an extension baseline: it is strong when parameters are
//! independent (NCF) and weak under interactions (Transformer-LT's
//! intra×OMP core sharing), which makes it a useful probe of the
//! simulator's interaction structure in the ablation benches.

use super::Tuner;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

/// Probe values per coordinate sweep (endpoints + quartiles + midpoint).
const PROBES: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

pub struct CoordinateDescent {
    space: SearchSpace,
    rng: Rng,
    best: Option<(Config, f64)>,
    /// Which parameter is being swept.
    param: usize,
    /// Which probe of that parameter is next.
    probe: usize,
    in_flight: Option<Config>,
}

impl CoordinateDescent {
    pub fn new(space: SearchSpace, seed: u64) -> CoordinateDescent {
        CoordinateDescent {
            space,
            rng: Rng::new(seed),
            best: None,
            param: 0,
            probe: 0,
            in_flight: None,
        }
    }
}

impl Tuner for CoordinateDescent {
    fn name(&self) -> &'static str {
        "coordinate-descent"
    }

    fn propose(&mut self) -> Config {
        let cfg = match &self.best {
            None => self.space.random(&mut self.rng),
            Some((best, _)) => {
                let mut cfg = best.clone();
                let p = &self.space.params[self.param];
                cfg[self.param] = p.from_unit(PROBES[self.probe]);
                cfg
            }
        };
        self.in_flight = Some(cfg.clone());
        cfg
    }

    fn observe(&mut self, config: &Config, value: f64) {
        let cfg = self.in_flight.take().unwrap_or_else(|| config.clone());
        let improved = match &self.best {
            None => true,
            Some((_, v)) => value > *v,
        };
        if improved {
            self.best = Some((cfg, value));
        }
        if self.best.is_some() {
            self.probe += 1;
            if self.probe >= PROBES.len() {
                self.probe = 0;
                self.param = (self.param + 1) % self.space.dim();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn solves_separable_objective() {
        // separable: best at intra=56, omp=56, rest irrelevant
        let s = space();
        let obj = |c: &Config| (c[1] + c[4]) as f64;
        let mut cd = CoordinateDescent::new(s.clone(), 1);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..55 {
            let c = cd.propose();
            let v = obj(&c);
            cd.observe(&c, v);
            best = best.max(v);
        }
        assert_eq!(best, 112.0, "coordinate descent must max a separable sum");
    }

    #[test]
    fn sweeps_every_parameter() {
        let s = space();
        let mut cd = CoordinateDescent::new(s.clone(), 2);
        let mut seen_params = std::collections::BTreeSet::new();
        let mut last: Option<Config> = None;
        for _ in 0..(1 + 5 * 5) {
            let c = cd.propose();
            if let Some(prev) = &last {
                for (i, (a, b)) in prev.iter().zip(&c).enumerate() {
                    if a != b {
                        seen_params.insert(i);
                    }
                }
            }
            cd.observe(&c, 1.0); // flat: never improves after first
            last = Some(c);
        }
        // flat objective: probes still walk every parameter
        assert!(seen_params.len() >= 4, "only swept {seen_params:?}");
    }

    #[test]
    fn prop_on_grid() {
        let s = space();
        prop::check("cd on grid", 25, |rng| {
            let mut cd = CoordinateDescent::new(s.clone(), rng.next_u64());
            for _ in 0..30 {
                let c = cd.propose();
                assert!(s.contains(&c));
                cd.observe(&c, rng.range_f64(0.0, 5.0));
            }
        });
    }
}
