//! Coordinate-descent baseline — the systematic version of what a human
//! expert does manually: sweep one parameter at a time around the current
//! best, keep the winner, move to the next parameter, repeat.
//!
//! Included as an extension baseline: it is strong when parameters are
//! independent (NCF) and weak under interactions (Transformer-LT's
//! intra×OMP core sharing), which makes it a useful probe of the
//! simulator's interaction structure in the ablation benches.

use super::{TrialBook, TrialId, Tuner};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

/// Probe values per coordinate sweep (endpoints + quartiles + midpoint).
const PROBES: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

pub struct CoordinateDescent {
    space: SearchSpace,
    rng: Rng,
    best: Option<(Config, f64)>,
    /// Which parameter is being swept.
    param: usize,
    /// Which probe of that parameter is next.
    probe: usize,
    /// Open trials. The probe cursor advances once per *probe* tell; `ask`
    /// offsets by the number of open probes so a batch covers successive
    /// probes instead of measuring one probe n times.
    book: TrialBook,
    /// Ids of open probe trials. Bootstrap randoms (issued while `best` is
    /// still unset) are deliberately absent: their tells must not consume
    /// probe-ladder slots, or a parallel warm-up would skip the first
    /// parameter's sweep entirely.
    open_probes: Vec<TrialId>,
}

impl CoordinateDescent {
    pub fn new(space: SearchSpace, seed: u64) -> CoordinateDescent {
        CoordinateDescent {
            space,
            rng: Rng::new(seed),
            best: None,
            param: 0,
            probe: 0,
            book: TrialBook::new(),
            open_probes: Vec::new(),
        }
    }

    /// The (param, probe) pair `ahead` tells into the future.
    fn cursor_ahead(&self, ahead: usize) -> (usize, usize) {
        let linear = self.param * PROBES.len() + self.probe + ahead;
        let probe = linear % PROBES.len();
        let param = (linear / PROBES.len()) % self.space.dim();
        (param, probe)
    }
}

impl Tuner for CoordinateDescent {
    fn name(&self) -> &'static str {
        "coordinate-descent"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match &self.best {
                None => {
                    let cfg = self.space.random(&mut self.rng);
                    out.push(self.book.issue(cfg));
                }
                Some((best, _)) => {
                    let (param, probe) = self.cursor_ahead(self.open_probes.len());
                    let mut cfg = best.clone();
                    cfg[param] = self.space.params[param].from_unit(PROBES[probe]);
                    let trial = self.book.issue(cfg);
                    self.open_probes.push(trial.id);
                    out.push(trial);
                }
            }
        }
        out
    }

    fn tell(&mut self, id: super::TrialId, m: &Measurement) {
        let Some(cfg) = self.book.settle(id) else { return };
        let was_probe = match self.open_probes.iter().position(|t| *t == id) {
            Some(i) => {
                self.open_probes.remove(i);
                true
            }
            None => false,
        };
        let bootstrap = self.best.is_none();
        let improved = match &self.best {
            None => true,
            Some((_, v)) => m.value > *v,
        };
        if improved {
            self.best = Some((cfg, m.value));
        }
        // Advance the ladder for probe results, plus once for the very
        // first (bootstrap) observation — the serial propose/observe loop
        // advanced there too, and that quirk is part of the preserved
        // trajectory. Later bootstrap randoms resolving out of a parallel
        // warm-up batch do not consume probe slots.
        if was_probe || bootstrap {
            self.probe += 1;
            if self.probe >= PROBES.len() {
                self.probe = 0;
                self.param = (self.param + 1) % self.space.dim();
            }
        }
    }

    fn warm_start(&mut self, config: &Config, value: f64) {
        let better = self.best.as_ref().map_or(true, |(_, v)| value > *v);
        if better {
            self.best = Some((config.clone(), value));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    fn step(cd: &mut CoordinateDescent, obj: impl Fn(&Config) -> f64) -> (Config, f64) {
        let t = cd.ask(1).pop().unwrap();
        let v = obj(&t.config);
        cd.tell(t.id, &Measurement::new(v));
        (t.config, v)
    }

    #[test]
    fn solves_separable_objective() {
        // separable: best at intra=56, omp=56, rest irrelevant
        let s = space();
        let obj = |c: &Config| (c[1] + c[4]) as f64;
        let mut cd = CoordinateDescent::new(s.clone(), 1);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..55 {
            let (_, v) = step(&mut cd, obj);
            best = best.max(v);
        }
        assert_eq!(best, 112.0, "coordinate descent must max a separable sum");
    }

    #[test]
    fn sweeps_every_parameter() {
        let s = space();
        let mut cd = CoordinateDescent::new(s.clone(), 2);
        let mut seen_params = std::collections::BTreeSet::new();
        let mut last: Option<Config> = None;
        for _ in 0..(1 + 5 * 5) {
            let (c, _) = step(&mut cd, |_| 1.0); // flat: never improves after first
            if let Some(prev) = &last {
                for (i, (a, b)) in prev.iter().zip(&c).enumerate() {
                    if a != b {
                        seen_params.insert(i);
                    }
                }
            }
            last = Some(c);
        }
        // flat objective: probes still walk every parameter
        assert!(seen_params.len() >= 4, "only swept {seen_params:?}");
    }

    #[test]
    fn prop_on_grid() {
        let s = space();
        prop::check("cd on grid", 25, |rng| {
            let mut cd = CoordinateDescent::new(s.clone(), rng.next_u64());
            for _ in 0..30 {
                let t = cd.ask(1).pop().unwrap();
                assert!(s.contains(&t.config));
                cd.tell(t.id, &Measurement::new(rng.range_f64(0.0, 5.0)));
            }
        });
    }

    #[test]
    fn bootstrap_randoms_do_not_consume_probe_slots() {
        let s = space();
        let mut cd = CoordinateDescent::new(s.clone(), 7);
        // parallel-style warm-up: 4 bootstrap randoms in flight at once
        let batch = cd.ask(4);
        assert_eq!(batch.len(), 4);
        for t in batch {
            cd.tell(t.id, &Measurement::new(1.0));
        }
        // Only the first bootstrap tell advances the ladder (the serial
        // quirk); the other three must not, or parameter 0 would never be
        // swept after a parallel warm-up.
        assert_eq!((cd.param, cd.probe), (0, 1));
        let t = cd.ask(1).pop().unwrap();
        assert_eq!(t.config[0], s.params[0].from_unit(PROBES[1]));
    }

    #[test]
    fn batched_ask_covers_successive_probes() {
        let s = space();
        let mut cd = CoordinateDescent::new(s.clone(), 3);
        step(&mut cd, |c: &Config| (c[1] + c[4]) as f64); // establish best
        // A batch of 5 lays out successive probes. The first tell already
        // advanced the cursor to (param 0, probe 1), so the batch covers
        // probes 1..=4 of parameter 0 and then probe 0 of parameter 1.
        let batch = cd.ask(5);
        assert_eq!(batch.len(), 5);
        let probed: Vec<i64> = batch[..4].iter().map(|t| t.config[0]).collect();
        let expected: Vec<i64> =
            PROBES[1..].iter().map(|&u| s.params[0].from_unit(u)).collect();
        assert_eq!(probed, expected, "batch must walk the probe ladder");
        assert_eq!(batch[4].config[1], s.params[1].from_unit(PROBES[0]));
        // shuffled tells keep the sweep moving without panicking
        for t in batch.iter().rev() {
            cd.tell(t.id, &Measurement::new((t.config[1] + t.config[4]) as f64));
        }
        assert!(cd.ask(1).pop().is_some());
    }
}
