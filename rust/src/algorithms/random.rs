//! Random-search baseline: uniform iid samples from the grid.

use super::Tuner;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

pub struct RandomSearch {
    space: SearchSpace,
    rng: Rng,
}

impl RandomSearch {
    pub fn new(space: SearchSpace, seed: u64) -> RandomSearch {
        RandomSearch { space, rng: Rng::new(seed) }
    }
}

impl Tuner for RandomSearch {
    fn name(&self) -> &'static str {
        "random-search"
    }

    fn propose(&mut self) -> Config {
        self.space.random(&mut self.rng)
    }

    fn observe(&mut self, _config: &Config, _value: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;

    #[test]
    fn proposals_on_grid_and_varied() {
        let space = threading_space(64, 1024, 64);
        let mut t = RandomSearch::new(space.clone(), 3);
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..50 {
            let c = t.propose();
            assert!(space.contains(&c));
            distinct.insert(c);
        }
        assert!(distinct.len() > 40, "only {} distinct proposals", distinct.len());
    }

    #[test]
    fn seeded_reproducible() {
        let space = threading_space(64, 1024, 64);
        let mut a = RandomSearch::new(space.clone(), 5);
        let mut b = RandomSearch::new(space, 5);
        for _ in 0..20 {
            assert_eq!(a.propose(), b.propose());
        }
    }
}
