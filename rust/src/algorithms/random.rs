//! Random-search baseline: uniform iid samples from the grid.

use super::{TrialBook, Tuner};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

pub struct RandomSearch {
    space: SearchSpace,
    rng: Rng,
    book: TrialBook,
}

impl RandomSearch {
    pub fn new(space: SearchSpace, seed: u64) -> RandomSearch {
        RandomSearch { space, rng: Rng::new(seed), book: TrialBook::new() }
    }
}

impl Tuner for RandomSearch {
    fn name(&self) -> &'static str {
        "random-search"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        (0..n)
            .map(|_| {
                let cfg = self.space.random(&mut self.rng);
                self.book.issue(cfg)
            })
            .collect()
    }

    fn tell(&mut self, id: super::TrialId, _m: &Measurement) {
        self.book.settle(id);
    }

    fn warm_start(&mut self, _config: &Config, _value: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;

    #[test]
    fn proposals_on_grid_and_varied() {
        let space = threading_space(64, 1024, 64);
        let mut t = RandomSearch::new(space.clone(), 3);
        let mut distinct = std::collections::BTreeSet::new();
        for trial in t.ask(50) {
            assert!(space.contains(&trial.config));
            distinct.insert(trial.config);
        }
        assert!(distinct.len() > 40, "only {} distinct proposals", distinct.len());
    }

    #[test]
    fn seeded_reproducible() {
        let space = threading_space(64, 1024, 64);
        let mut a = RandomSearch::new(space.clone(), 5);
        let mut b = RandomSearch::new(space, 5);
        for _ in 0..20 {
            let ta = a.ask(1).pop().unwrap();
            let tb = b.ask(1).pop().unwrap();
            assert_eq!(ta.config, tb.config);
            assert_eq!(ta.id, tb.id);
            a.tell(ta.id, &Measurement::new(0.0));
            b.tell(tb.id, &Measurement::new(0.0));
        }
    }
}
