//! Exhaustive grid-search baseline — the "close to a month of CPU time"
//! strawman from the paper's introduction, and the engine behind the
//! Fig. 6 exhaustive sweep. Batched `ask` hands out consecutive odometer
//! points, so a parallel session shards the grid across evaluators.

use super::{TrialBook, Tuner};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};

pub struct GridSearch {
    space: SearchSpace,
    /// Odometer over value indices (last parameter fastest).
    idx: Vec<usize>,
    exhausted: bool,
    book: TrialBook,
}

impl GridSearch {
    pub fn new(space: SearchSpace) -> GridSearch {
        let dim = space.dim();
        GridSearch { space, idx: vec![0; dim], exhausted: false, book: TrialBook::new() }
    }

    /// Has the full grid been proposed at least once?
    pub fn exhausted(&self) -> bool {
        self.exhausted
    }

    fn next_point(&mut self) -> Config {
        let cfg: Config = self
            .space
            .params
            .iter()
            .zip(&self.idx)
            .map(|(p, &i)| p.value_at(i))
            .collect();
        // advance odometer; wrap around (and mark) at the end
        let mut k = self.space.dim();
        loop {
            if k == 0 {
                self.exhausted = true;
                break;
            }
            k -= 1;
            self.idx[k] += 1;
            if self.idx[k] < self.space.params[k].n_values() {
                break;
            }
            self.idx[k] = 0;
        }
        cfg
    }
}

impl Tuner for GridSearch {
    fn name(&self) -> &'static str {
        "grid-search"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        (0..n)
            .map(|_| {
                let cfg = self.next_point();
                self.book.issue(cfg)
            })
            .collect()
    }

    fn tell(&mut self, id: super::TrialId, _m: &Measurement) {
        self.book.settle(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{ParamDef, SearchSpace};

    #[test]
    fn covers_grid_exactly_once_then_wraps() {
        let space = SearchSpace::new(vec![
            ParamDef::new("a", 0, 1, 1),
            ParamDef::new("b", 0, 2, 1),
        ]);
        let mut t = GridSearch::new(space);
        let mut seen = Vec::new();
        for _ in 0..6 {
            assert!(!t.exhausted());
            seen.push(t.ask(1).pop().unwrap().config);
        }
        assert!(t.exhausted());
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 6);
        // wraps deterministically
        assert_eq!(t.ask(1).pop().unwrap().config, vec![0, 0]);
    }

    #[test]
    fn batched_ask_shards_the_grid() {
        let space = SearchSpace::new(vec![
            ParamDef::new("a", 0, 1, 1),
            ParamDef::new("b", 0, 2, 1),
        ]);
        let mut t = GridSearch::new(space);
        let batch = t.ask(6);
        assert_eq!(batch.len(), 6);
        let mut ids: Vec<_> = batch.iter().map(|tr| tr.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 6, "trial ids must be unique");
        let mut cfgs: Vec<_> = batch.iter().map(|tr| tr.config.clone()).collect();
        cfgs.sort();
        cfgs.dedup();
        assert_eq!(cfgs.len(), 6, "one batch covers distinct grid points");
        assert!(t.exhausted());
    }
}
