//! Exhaustive grid-search baseline — the "close to a month of CPU time"
//! strawman from the paper's introduction, and the engine behind the
//! Fig. 6 exhaustive sweep.

use super::Tuner;
use crate::space::{Config, SearchSpace};

pub struct GridSearch {
    space: SearchSpace,
    /// Odometer over value indices (last parameter fastest).
    idx: Vec<usize>,
    exhausted: bool,
}

impl GridSearch {
    pub fn new(space: SearchSpace) -> GridSearch {
        let dim = space.dim();
        GridSearch { space, idx: vec![0; dim], exhausted: false }
    }

    /// Has the full grid been proposed at least once?
    pub fn exhausted(&self) -> bool {
        self.exhausted
    }
}

impl Tuner for GridSearch {
    fn name(&self) -> &'static str {
        "grid-search"
    }

    fn propose(&mut self) -> Config {
        let cfg: Config = self
            .space
            .params
            .iter()
            .zip(&self.idx)
            .map(|(p, &i)| p.value_at(i))
            .collect();
        // advance odometer; wrap around (and mark) at the end
        let mut k = self.space.dim();
        loop {
            if k == 0 {
                self.exhausted = true;
                break;
            }
            k -= 1;
            self.idx[k] += 1;
            if self.idx[k] < self.space.params[k].n_values() {
                break;
            }
            self.idx[k] = 0;
        }
        cfg
    }

    fn observe(&mut self, _config: &Config, _value: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{ParamDef, SearchSpace};

    #[test]
    fn covers_grid_exactly_once_then_wraps() {
        let space = SearchSpace::new(vec![
            ParamDef::new("a", 0, 1, 1),
            ParamDef::new("b", 0, 2, 1),
        ]);
        let mut t = GridSearch::new(space);
        let mut seen = Vec::new();
        for _ in 0..6 {
            assert!(!t.exhausted());
            seen.push(t.propose());
        }
        assert!(t.exhausted());
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 6);
        // wraps deterministically
        assert_eq!(t.propose(), vec![0, 0]);
    }
}
