//! Nelder-Mead simplex engine (paper §2.2; also TensorTuner's algorithm).
//!
//! Standard downhill simplex (alpha=1 reflection, gamma=2 expansion,
//! rho=0.5 contraction, sigma=0.5 shrink) on the continuous unit cube,
//! with proposals snapped to the parameter grid at evaluation time. When
//! the simplex collapses (all vertices within a small diameter) it
//! restarts around the incumbent — the "clusters of points" visible in
//! the paper's Fig. 7 pairplots are exactly these local refinement phases.
//!
//! Ask/tell bookkeeping: the naturally parallel phases (initial simplex
//! construction, shrink re-evaluation) are issued as batches whose tells
//! may arrive in any order — vertices are sorted by value, so arrival
//! order is irrelevant. The reflect/expand/contract steps are inherently
//! sequential: while one is in flight, `ask` returns an empty batch.
//!
//! Internally minimises f = -throughput.

use super::{Trial, TrialId, Tuner};
use crate::history::Measurement;
use crate::space::SearchSpace;
use crate::util::Rng;

const ALPHA: f64 = 1.0; // reflection
const GAMMA: f64 = 2.0; // expansion
const RHO: f64 = 0.5; // contraction
const SIGMA: f64 = 0.5; // shrink
/// Simplex diameter below which we restart around the best vertex.
const RESTART_DIAMETER: f64 = 0.02;
/// Edge length of a fresh (restarted) simplex.
const INIT_STEP: f64 = 0.25;

type Point = Vec<f64>;

#[derive(Debug)]
enum Phase {
    /// Evaluating the initial simplex vertices.
    Init,
    /// Waiting for the reflected point's value.
    Reflect,
    /// Waiting for the expanded point's value (carrying f(xr)).
    Expand { xr: Point, fr: f64 },
    /// Waiting for an outside contraction (carrying f(xr)).
    ContractOut { fr: f64 },
    /// Waiting for an inside contraction.
    ContractIn,
    /// Re-evaluating shrunk vertices one at a time.
    Shrink,
}

/// How an open trial participates in the state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Issued {
    /// A simplex vertex (initial construction or shrink re-evaluation):
    /// batched freely, tells accepted in any order.
    Vertex,
    /// The single sequential point of a reflect/expand/contract step.
    Step,
}

pub struct NelderMead {
    space: SearchSpace,
    rng: Rng,
    /// Evaluated simplex vertices: (continuous point, f = -value).
    simplex: Vec<(Point, f64)>,
    /// Points proposed but not yet issued as trials (Init/Shrink queues,
    /// plus the one-deep queue the sequential steps pass through).
    queue: Vec<Point>,
    /// Issued trials awaiting their tell.
    open: Vec<(TrialId, Point, Issued)>,
    next_id: TrialId,
    phase: Phase,
    restarts: usize,
    /// Restart a collapsed simplex around the incumbent. The paper's
    /// reference implementation (TensorTuner) does NOT restart — it is
    /// precisely the "tendency to get stuck in local optima" the paper
    /// describes — so this defaults to off; `with_restarts(true)` gives
    /// the modernised variant (see the nms_restart ablation bench).
    restart_enabled: bool,
}

fn clamp01(p: &mut Point) {
    for x in p.iter_mut() {
        *x = x.clamp(0.0, 1.0);
    }
}

impl NelderMead {
    pub fn new(space: SearchSpace, seed: u64) -> NelderMead {
        let mut rng = Rng::new(seed);
        let dim = space.dim();
        let x0: Point = (0..dim).map(|_| rng.f64()).collect();
        let queue = Self::fresh_simplex(&x0, INIT_STEP, dim);
        NelderMead {
            space,
            rng,
            simplex: Vec::new(),
            queue,
            open: Vec::new(),
            next_id: 0,
            phase: Phase::Init,
            restarts: 0,
            restart_enabled: false,
        }
    }

    /// Enable/disable oriented restarts on simplex collapse.
    pub fn with_restarts(mut self, enabled: bool) -> NelderMead {
        self.restart_enabled = enabled;
        self
    }

    /// Number of degenerate-simplex restarts performed (introspection).
    pub fn restarts(&self) -> usize {
        self.restarts
    }

    fn fresh_simplex(x0: &Point, step: f64, dim: usize) -> Vec<Point> {
        let mut pts = vec![x0.clone()];
        for i in 0..dim {
            let mut p = x0.clone();
            // step away from the wall if needed
            p[i] = if p[i] + step <= 1.0 { p[i] + step } else { p[i] - step };
            clamp01(&mut p);
            pts.push(p);
        }
        pts.reverse(); // queue pops from the back
        pts
    }

    fn order(&mut self) {
        self.simplex
            .sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    }

    /// Centroid of all vertices except the worst.
    fn centroid(&self) -> Point {
        let dim = self.space.dim();
        let n = self.simplex.len() - 1;
        let mut c = vec![0.0; dim];
        for (p, _) in &self.simplex[..n] {
            for (ci, pi) in c.iter_mut().zip(p) {
                *ci += pi;
            }
        }
        for ci in c.iter_mut() {
            *ci /= n as f64;
        }
        c
    }

    fn diameter(&self) -> f64 {
        let mut d: f64 = 0.0;
        for i in 0..self.simplex.len() {
            for j in i + 1..self.simplex.len() {
                let dist = crate::util::linalg::sqdist(&self.simplex[i].0, &self.simplex[j].0)
                    .sqrt();
                d = d.max(dist);
            }
        }
        d
    }

    fn point_along(&self, from: &Point, toward: &Point, t: f64) -> Point {
        let mut p: Point =
            from.iter().zip(toward).map(|(a, b)| a + t * (b - a)).collect();
        clamp01(&mut p);
        p
    }

    /// Begin a reflect step from the current (complete) simplex.
    fn start_reflect(&mut self) -> Point {
        self.order();
        // Degenerate simplex -> oriented restart around the best vertex
        // (only in the modernised variant; TensorTuner-style NMS keeps
        // reflecting the collapsed simplex and stays stuck).
        if self.restart_enabled && self.diameter() < RESTART_DIAMETER {
            self.restart();
            return self.queue.pop().expect("restart queue non-empty");
        }
        let c = self.centroid();
        let worst = &self.simplex.last().unwrap().0;
        // xr = c + ALPHA * (c - worst)
        let xr = self.point_along(&c, worst, -ALPHA);
        self.phase = Phase::Reflect;
        xr
    }

    fn restart(&mut self) {
        self.restarts += 1;
        let dim = self.space.dim();
        let best = self.simplex[0].clone();
        // random orientation: jitter the incumbent, keep it in the simplex
        let mut x0 = best.0.clone();
        for x in x0.iter_mut() {
            *x = (*x + self.rng.normal() * 0.05).clamp(0.0, 1.0);
        }
        self.queue = Self::fresh_simplex(&x0, INIT_STEP, dim);
        self.simplex = vec![best]; // incumbent survives the restart
        self.queue.pop(); // one slot taken by the incumbent
        self.phase = Phase::Init;
    }

    fn dim1(&self) -> usize {
        self.space.dim() + 1
    }
}

impl NelderMead {
    /// Issue one point as a trial.
    fn issue(&mut self, point: Point, kind: Issued) -> Trial {
        let id = self.next_id;
        self.next_id += 1;
        let config = self.space.from_unit(&point);
        self.open.push((id, point, kind));
        Trial { id, config }
    }

    /// Is a sequential reflect/expand/contract point currently in flight?
    fn step_open(&self) -> bool {
        self.open.iter().any(|(_, _, k)| *k == Issued::Step)
    }
}

impl Tuner for NelderMead {
    fn name(&self) -> &'static str {
        "nelder-mead"
    }

    fn ask(&mut self, n: usize) -> Vec<Trial> {
        let mut out = Vec::new();
        while out.len() < n {
            // A sequential step admits no concurrency: wait for its tell.
            if self.step_open() {
                break;
            }
            if let Some(p) = self.queue.pop() {
                // In Init/Shrink the queue holds batchable vertices; in the
                // sequential phases it holds that phase's single point.
                let kind = match self.phase {
                    Phase::Init | Phase::Shrink => Issued::Vertex,
                    _ => Issued::Step,
                };
                out.push(self.issue(p, kind));
                continue;
            }
            // Queue drained: a new step can only start once every vertex
            // of the current generation has been told back.
            if !self.open.is_empty() {
                break;
            }
            match self.phase {
                Phase::Init | Phase::Shrink => {
                    let p = self.start_reflect();
                    // start_reflect either produced the reflected point
                    // (phase = Reflect) or triggered a restart and handed
                    // back the first fresh vertex (phase = Init).
                    let kind = match self.phase {
                        Phase::Init => Issued::Vertex,
                        _ => Issued::Step,
                    };
                    out.push(self.issue(p, kind));
                }
                // A sequential phase with nothing queued or open cannot
                // occur: each such phase queues its follow-up point.
                _ => break,
            }
        }
        out
    }

    fn tell(&mut self, id: TrialId, m: &Measurement) {
        let Some(i) = self.open.iter().position(|(t, _, _)| *t == id) else {
            return; // stale/unknown id
        };
        let (_, point, kind) = self.open.remove(i);
        let f = -m.value; // minimise
        if kind == Issued::Vertex {
            // Init or Shrink vertex: accumulate; when the generation is
            // complete (and nothing else is outstanding) the next ask
            // starts a reflect step.
            self.simplex.push((point, f));
            if self.simplex.len() >= self.dim1() && self.queue.is_empty() && self.open.is_empty()
            {
                self.phase = Phase::Shrink; // state meaning "start_reflect next"
            }
            return;
        }
        match std::mem::replace(&mut self.phase, Phase::Init) {
            Phase::Init | Phase::Shrink => {
                unreachable!("sequential tell in a batch phase")
            }
            Phase::Reflect => {
                let fr = f;
                let xr = point;
                let best = self.simplex[0].1;
                let second_worst = self.simplex[self.simplex.len() - 2].1;
                let worst = self.simplex.last().unwrap().1;
                if fr < best {
                    // try expansion: xe = c + GAMMA*(xr - c)
                    let c = self.centroid();
                    let xe = self.point_along(&c, &xr, GAMMA);
                    self.queue.push(xe);
                    self.phase = Phase::Expand { xr, fr };
                } else if fr < second_worst {
                    *self.simplex.last_mut().unwrap() = (xr, fr);
                    self.phase = Phase::Shrink; // reflect next
                } else if fr < worst {
                    // outside contraction: xc = c + RHO*(xr - c)
                    let c = self.centroid();
                    let xc = self.point_along(&c, &xr, RHO);
                    self.queue.push(xc);
                    self.phase = Phase::ContractOut { fr };
                } else {
                    // inside contraction: xc = c + RHO*(worst - c)
                    let c = self.centroid();
                    let xw = self.simplex.last().unwrap().0.clone();
                    let xc = self.point_along(&c, &xw, RHO);
                    self.queue.push(xc);
                    self.phase = Phase::ContractIn;
                }
            }
            Phase::Expand { xr, fr } => {
                let (xe, fe) = (point, f);
                *self.simplex.last_mut().unwrap() =
                    if fe < fr { (xe, fe) } else { (xr, fr) };
                self.phase = Phase::Shrink; // reflect next
            }
            Phase::ContractOut { fr } => {
                if f <= fr {
                    *self.simplex.last_mut().unwrap() = (point, f);
                    self.phase = Phase::Shrink;
                } else {
                    self.begin_shrink();
                }
            }
            Phase::ContractIn => {
                let worst = self.simplex.last().unwrap().1;
                if f < worst {
                    *self.simplex.last_mut().unwrap() = (point, f);
                    self.phase = Phase::Shrink;
                } else {
                    self.begin_shrink();
                }
            }
        }
    }
}

impl NelderMead {
    /// Shrink every vertex toward the best and queue re-evaluations.
    fn begin_shrink(&mut self) {
        self.order();
        let best = self.simplex[0].0.clone();
        let others: Vec<Point> =
            self.simplex[1..].iter().map(|(p, _)| p.clone()).collect();
        self.simplex.truncate(1);
        for p in others {
            let shrunk = self.point_along(&best, &p, SIGMA);
            self.queue.push(shrunk);
        }
        self.phase = Phase::Shrink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{threading_space, Config, ParamDef};
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    /// Drive NMS on a closure objective for `iters` steps (serial ask/tell).
    fn drive<F: Fn(&Config) -> f64>(
        mut t: NelderMead,
        f: F,
        iters: usize,
    ) -> (NelderMead, Vec<(Config, f64)>) {
        let mut trace = Vec::new();
        for _ in 0..iters {
            let trial = t.ask(1).pop().expect("serial NMS always has a next point");
            let v = f(&trial.config);
            t.tell(trial.id, &Measurement::new(v));
            trace.push((trial.config, v));
        }
        (t, trace)
    }

    #[test]
    fn optimizes_smooth_quadratic() {
        // maximise -(sum of squared distances to a target config)
        let s = space();
        let target = vec![2, 28, 512, 100, 28];
        let tnorm = s.to_unit(&target);
        let obj = |c: &Config| {
            let u = s.to_unit(c);
            -u.iter().zip(&tnorm).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        };
        let (_, trace) = drive(NelderMead::new(s.clone(), 7).with_restarts(true), obj, 60);
        let best = trace.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max);
        assert!(best > -0.02, "NMS best {best} too far from optimum");
    }

    #[test]
    fn proposals_always_on_grid() {
        let s = space();
        prop::check("nms on grid", 20, |rng| {
            let mut t = NelderMead::new(s.clone(), rng.next_u64());
            for _ in 0..40 {
                let trial = t.ask(1).pop().unwrap();
                assert!(s.contains(&trial.config), "off grid: {:?}", trial.config);
                t.tell(trial.id, &Measurement::new(rng.range_f64(0.0, 10.0)));
            }
        });
    }

    #[test]
    fn restarts_on_degenerate_simplex() {
        // constant objective: simplex shrinks forever -> must restart
        let s = SearchSpace::new(vec![
            ParamDef::new("a", 0, 100, 1),
            ParamDef::new("b", 0, 100, 1),
        ]);
        let (t, _) = drive(NelderMead::new(s, 3).with_restarts(true), |_| 1.0, 300);
        assert!(t.restarts() > 0, "no restart after 300 flat evaluations");
    }

    #[test]
    fn ask_batches_vertices_but_serialises_steps() {
        let s = space();
        let dim1 = s.dim() + 1;
        let mut t = NelderMead::new(s.clone(), 1);
        // The whole initial simplex comes out as one batch of vertices...
        let init = t.ask(16);
        assert_eq!(init.len(), dim1, "initial batch is the full simplex");
        let mut ids: Vec<_> = init.iter().map(|tr| tr.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), dim1, "trial ids must be unique");
        // ...and with vertices outstanding no new step can start.
        assert!(t.ask(4).is_empty(), "no points while the generation is open");
        // Tell the vertices back out of order.
        for (i, tr) in init.iter().enumerate().rev() {
            t.tell(tr.id, &Measurement::new(i as f64));
        }
        // The reflect step is sequential: one point, then nothing until told.
        let step = t.ask(4);
        assert_eq!(step.len(), 1, "reflect step admits no concurrency");
        assert!(t.ask(1).is_empty(), "step in flight blocks further asks");
        t.tell(step[0].id, &Measurement::new(0.5));
        assert!(!t.ask(1).is_empty(), "engine resumes after the step's tell");
    }

    #[test]
    fn exploitation_cluster_signature() {
        // On a unimodal surface, NMS spends most late evaluations near the
        // optimum: the mean pairwise distance of the last 10 samples must
        // be far below that of random search (the Fig. 7 cluster effect).
        let s = space();
        let target = vec![1, 40, 256, 0, 40];
        let tn = s.to_unit(&target);
        let obj = |c: &Config| {
            let u = s.to_unit(c);
            -u.iter().zip(&tn).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        };
        let (_, trace) = drive(NelderMead::new(s.clone(), 11), obj, 50);
        let last: Vec<Vec<f64>> =
            trace[40..].iter().map(|(c, _)| s.to_unit(c)).collect();
        let mut dsum = 0.0;
        let mut cnt = 0;
        for i in 0..last.len() {
            for j in i + 1..last.len() {
                dsum += crate::util::linalg::sqdist(&last[i], &last[j]).sqrt();
                cnt += 1;
            }
        }
        let mean_dist = dsum / cnt as f64;
        assert!(mean_dist < 0.8, "late NMS samples not clustered: {mean_dist}");
    }
}
