//! Simulated-annealing baseline.
//!
//! Not evaluated in the paper, but the natural next member of the
//! gradient-free family (§2.2 mentions heuristic methods); included as an
//! extension baseline for the ablation benches. Metropolis acceptance on
//! -throughput with a geometric temperature schedule and grid-neighbour
//! moves.

use super::{TrialBook, Tuner};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

/// Fraction of coordinates perturbed per move.
const MOVE_PROB: f64 = 0.4;
/// Geometric cooling factor per iteration.
const COOLING: f64 = 0.93;

pub struct SimulatedAnnealing {
    space: SearchSpace,
    rng: Rng,
    current: Option<(Config, f64)>,
    /// Open trials: each tell resolves its proposal by id, so a batch of
    /// moves can complete in any order (each is Metropolis-tested against
    /// whatever the chain state is when its result arrives).
    book: TrialBook,
    /// Temperature in units of *relative* objective change.
    temperature: f64,
}

impl SimulatedAnnealing {
    pub fn new(space: SearchSpace, seed: u64) -> SimulatedAnnealing {
        SimulatedAnnealing {
            space,
            rng: Rng::new(seed),
            current: None,
            book: TrialBook::new(),
            // accept ~20% worse moves at the start
            temperature: 0.2,
        }
    }

    pub fn temperature(&self) -> f64 {
        self.temperature
    }

    /// One temperature-scaled move from the current chain state.
    fn next_move(&mut self) -> Config {
        match &self.current {
            None => self.space.random(&mut self.rng),
            Some((cur, _)) => {
                // temperature-scaled Gaussian move in unit space: big jumps
                // while hot, fine steps once cooled.
                let u = self.space.to_unit(cur);
                let sigma = self.temperature.max(0.02);
                let moved: Vec<f64> = u
                    .iter()
                    .map(|&x| {
                        if self.rng.bool(MOVE_PROB) {
                            (x + self.rng.normal() * sigma).clamp(0.0, 1.0)
                        } else {
                            x
                        }
                    })
                    .collect();
                let cfg = self.space.from_unit(&moved);
                if cfg == *cur {
                    // degenerate move: force a single-step neighbour
                    self.space.neighbour(cur, MOVE_PROB, &mut self.rng)
                } else {
                    cfg
                }
            }
        }
    }
}

impl Tuner for SimulatedAnnealing {
    fn name(&self) -> &'static str {
        "simulated-annealing"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        // A batch is n independent moves from the same chain state (the
        // chain only advances on tells).
        (0..n)
            .map(|_| {
                let cfg = self.next_move();
                self.book.issue(cfg)
            })
            .collect()
    }

    fn tell(&mut self, id: super::TrialId, m: &Measurement) {
        let Some(proposed) = self.book.settle(id) else { return };
        let value = m.value;
        match &self.current {
            None => self.current = Some((proposed, value)),
            Some((_, cur_v)) => {
                // Metropolis on relative change (objective scales vary by
                // orders of magnitude across models).
                let rel = (value - cur_v) / cur_v.abs().max(1e-12);
                let accept = rel >= 0.0
                    || self.rng.f64() < (rel / self.temperature.max(1e-6)).exp();
                if accept {
                    self.current = Some((proposed, value));
                }
            }
        }
        self.temperature *= COOLING;
    }

    fn warm_start(&mut self, config: &Config, value: f64) {
        // Adopt the injected point when it beats the chain state.
        let better = self.current.as_ref().map_or(true, |(_, v)| value > *v);
        if better {
            self.current = Some((config.clone(), value));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    fn quadratic(s: &SearchSpace, target: &Config) -> impl Fn(&Config) -> f64 {
        let tn = s.to_unit(target);
        let s = s.clone();
        move |c: &Config| {
            let u = s.to_unit(c);
            10.0 - 10.0 * u.iter().zip(&tn).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        }
    }

    fn step(sa: &mut SimulatedAnnealing, value: f64) -> Config {
        let t = sa.ask(1).pop().unwrap();
        sa.tell(t.id, &Measurement::new(value));
        t.config
    }

    #[test]
    fn improves_on_smooth_objective() {
        let s = space();
        let obj = quadratic(&s, &vec![2, 30, 512, 100, 30]);
        let mut sa = SimulatedAnnealing::new(s.clone(), 3);
        let mut first = None;
        let mut best = f64::NEG_INFINITY;
        for _ in 0..80 {
            let t = sa.ask(1).pop().unwrap();
            let v = obj(&t.config);
            sa.tell(t.id, &Measurement::new(v));
            first.get_or_insert(v);
            best = best.max(v);
        }
        assert!(best > first.unwrap() + 0.5, "SA didn't improve: first {first:?} best {best}");
        assert!(best > 9.0, "SA best {best}");
    }

    #[test]
    fn temperature_cools_monotonically() {
        let s = space();
        let mut sa = SimulatedAnnealing::new(s.clone(), 1);
        let mut prev = sa.temperature();
        for _ in 0..20 {
            step(&mut sa, 1.0);
            assert!(sa.temperature() < prev);
            prev = sa.temperature();
        }
    }

    #[test]
    fn prop_proposals_on_grid() {
        let s = space();
        prop::check("sa on grid", 25, |rng| {
            let mut sa = SimulatedAnnealing::new(s.clone(), rng.next_u64());
            for _ in 0..30 {
                let t = sa.ask(1).pop().unwrap();
                assert!(s.contains(&t.config));
                sa.tell(t.id, &Measurement::new(rng.range_f64(0.0, 10.0)));
            }
        });
    }

    #[test]
    fn accepts_improvements_always() {
        let s = space();
        let mut sa = SimulatedAnnealing::new(s.clone(), 2);
        step(&mut sa, 1.0);
        step(&mut sa, 2.0); // improvement: must become current
        assert_eq!(sa.current.as_ref().unwrap().1, 2.0);
    }

    #[test]
    fn batched_moves_resolve_out_of_order() {
        let s = space();
        let mut sa = SimulatedAnnealing::new(s.clone(), 4);
        step(&mut sa, 5.0); // establish the chain
        let batch = sa.ask(4);
        assert_eq!(batch.len(), 4);
        // resolve in reverse; the chain state must always be one of the
        // told outcomes (Metropolis may keep any of them, never corrupt)
        for (i, t) in batch.iter().enumerate().rev() {
            sa.tell(t.id, &Measurement::new(5.0 + i as f64));
        }
        let cur = sa.current.as_ref().unwrap().1;
        assert!((5.0..=8.0).contains(&cur), "chain state {cur} not a told value");
        // a stale tell for an already-settled id is ignored
        let temp = sa.temperature();
        sa.tell(batch[0].id, &Measurement::new(1e9));
        assert_eq!(sa.temperature(), temp);
    }
}
