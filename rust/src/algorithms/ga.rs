//! Genetic algorithm engine (paper §2.2).
//!
//! Faithful to the paper's description: after an initial random
//! population, each iteration (a) reorders the full evaluation history by
//! fitness, (b) picks the two fittest configurations as parents,
//! (c) produces one child by crossover of parent components, and
//! (d) mutates components to random values with a small probability.
//!
//! This parents-are-the-global-top-2 scheme is exactly what produces the
//! paper's Table 2 signature for GA: the population collapses around the
//! early winners, mutation is the only mechanism that ever reaches the
//! range extremes, and sampled range coverage stays below ~50%.

use super::{TrialBook, Tuner};
use crate::history::Measurement;
use crate::space::{Config, SearchSpace};
use crate::util::Rng;

/// Per-gene mutation probability.
const MUTATION_RATE: f64 = 0.10;
/// Stddev (unit cube) of a bounded mutation jump.
const MUTATION_SIGMA: f64 = 0.22;
/// Initial population size.
const POPULATION: usize = 8;
/// Stddev (unit cube) of the initial population around its seed point.
const INIT_SIGMA: f64 = 0.12;
//
// Calibration note (Table 2 reproduction): the paper's GA samples only
// ~30-40% of every parameter range, and every sampled *minimum* sits at
// the low end (inter [1,2], blocktime [0,50..70], batch [64,..]). That
// signature requires (a) a *concentrated* initial population seeded near a
// small/default-style configuration — a uniform population would already
// cover most of each range by itself — and (b) *bounded* mutation jumps
// rather than uniform resampling, since ~25 uniform resamples across 50
// iterations would hit the 4-value inter_op extremes almost surely. Both
// are standard GA variants; DESIGN.md §7 records the substitution.

pub struct Genetic {
    space: SearchSpace,
    rng: Rng,
    /// Full evaluated history (the paper's GA reorders the history).
    history: Vec<(Config, f64)>,
    /// Seeds not yet evaluated.
    pending_init: Vec<Config>,
    /// Open trials keyed by id: a tell looks its configuration up here, so
    /// out-of-order completions land in the right history slot.
    book: TrialBook,
}

impl Genetic {
    pub fn new(space: SearchSpace, seed: u64) -> Genetic {
        let mut rng = Rng::new(seed);
        // Population seeded as Gaussian scatter around a random start in
        // the lower half of each range (default-style configurations).
        let center: Vec<f64> = (0..space.dim()).map(|_| rng.range_f64(0.05, 0.75)).collect();
        let pending_init: Vec<Config> = (0..POPULATION)
            .map(|_| {
                let u: Vec<f64> = center
                    .iter()
                    .map(|&c| (c + rng.normal() * INIT_SIGMA).clamp(0.0, 1.0))
                    .collect();
                space.from_unit(&u)
            })
            .collect();
        Genetic { space, rng, history: Vec::new(), pending_init, book: TrialBook::new() }
    }

    /// The two fittest configurations observed so far.
    fn parents(&self) -> (&Config, &Config) {
        assert!(self.history.len() >= 2, "need two evaluations before breeding");
        let mut best = 0;
        let mut second = 1;
        if self.history[second].1 > self.history[best].1 {
            std::mem::swap(&mut best, &mut second);
        }
        for i in 2..self.history.len() {
            let v = self.history[i].1;
            if v > self.history[best].1 {
                second = best;
                best = i;
            } else if v > self.history[second].1 {
                second = i;
            }
        }
        (&self.history[best].0, &self.history[second].0)
    }

    /// One-point crossover + per-gene mutation.
    fn breed(&mut self) -> Config {
        let dim = self.space.dim();
        let (p1, p2) = {
            let (a, b) = self.parents();
            (a.clone(), b.clone())
        };
        // Crossover point in [1, dim-1]: child takes a prefix of p1 and a
        // suffix of p2 (paper: "copying part of the components from the
        // first parent and the other from the second"). A 1-D space has no
        // interior cut: the child is parent 1 + mutation.
        let cut = if dim > 1 { 1 + self.rng.index(dim - 1) } else { 1 };
        let mut child: Config =
            (0..dim).map(|i| if i < cut { p1[i] } else { p2[i] }).collect();
        // Mutation: bounded Gaussian jump in unit space (see note above).
        for (i, p) in self.space.params.iter().enumerate() {
            if self.rng.bool(MUTATION_RATE) {
                let u = (p.to_unit(child[i]) + self.rng.normal() * MUTATION_SIGMA)
                    .clamp(0.0, 1.0);
                child[i] = p.from_unit(u);
            }
        }
        self.space.snap(&child)
    }
}

impl Tuner for Genetic {
    fn name(&self) -> &'static str {
        "genetic-algorithm"
    }

    fn ask(&mut self, n: usize) -> Vec<super::Trial> {
        // A batch is one (partial) generation: children bred back-to-back
        // from the current top-2 parents. Parents only refresh on tells, so
        // the generation stays coherent however its results interleave.
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let cfg = if let Some(cfg) = self.pending_init.pop() {
                cfg
            } else if self.history.len() < 2 {
                // degenerate budget: fall back to random
                let mut r = self.rng.fork(1);
                self.space.random(&mut r)
            } else {
                self.breed()
            };
            out.push(self.book.issue(cfg));
        }
        out
    }

    fn tell(&mut self, id: super::TrialId, m: &Measurement) {
        if let Some(cfg) = self.book.settle(id) {
            self.history.push((cfg, m.value));
        }
    }

    fn warm_start(&mut self, config: &Config, value: f64) {
        self.history.push((config.clone(), value));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::threading_space;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    /// ask(1)/tell one step with the given value; returns the config.
    fn step(ga: &mut Genetic, value: f64) -> Config {
        let t = ga.ask(1).pop().unwrap();
        ga.tell(t.id, &Measurement::new(value));
        t.config
    }

    #[test]
    fn initial_population_is_random_grid_points() {
        let s = space();
        let mut ga = Genetic::new(s.clone(), 1);
        for _ in 0..POPULATION {
            let c = step(&mut ga, 1.0);
            assert!(s.contains(&c));
        }
    }

    #[test]
    fn children_inherit_parent_components() {
        let s = space();
        let mut ga = Genetic::new(s.clone(), 2);
        // Drain the initial population with low fitness...
        for _ in 0..POPULATION {
            step(&mut ga, -1.0);
        }
        // ...then inject two very different parents with top fitness.
        let p1 = vec![1, 1, 64, 0, 1];
        let p2 = vec![4, 56, 1024, 200, 56];
        ga.warm_start(&p1, 100.0);
        ga.warm_start(&p2, 90.0);
        for _ in 0..50 {
            let child = step(&mut ga, 0.0); // keep parents on top
            // Each unmutated gene must come from one of the parents.
            let inherited = child
                .iter()
                .enumerate()
                .filter(|(i, &v)| v == p1[*i] || v == p2[*i])
                .count();
            assert!(inherited >= 3, "child {child:?} shares too little with parents");
        }
    }

    #[test]
    fn exploitation_signature_low_range_coverage() {
        // GA's defining behaviour in the paper (Table 2): starting from a
        // concentrated population it rarely reaches range extremes.
        let s = space();
        let mut ga = Genetic::new(s.clone(), 3);
        // Simulate a tuning run with a smooth objective.
        let mut sampled: Vec<Config> = Vec::new();
        for _ in 0..50 {
            let t = ga.ask(1).pop().unwrap();
            let c = t.config;
            let v = -((c[1] - 28).abs() as f64) - (c[4] - 20).abs() as f64;
            ga.tell(t.id, &Measurement::new(v));
            sampled.push(c);
        }
        let mut h = crate::history::History::new();
        for c in sampled {
            h.push(c, 0.0);
        }
        let pct = h.sampled_range_pct(&s).unwrap();
        // average coverage clearly below full exploration (Table 2 shows
        // GA below ~50% on most parameters)
        let avg = pct.iter().sum::<f64>() / pct.len() as f64;
        assert!(avg < 70.0, "GA coverage unexpectedly high: {pct:?}");
    }

    #[test]
    fn prop_children_always_on_grid() {
        let s = space();
        prop::check("ga children on grid", 30, |rng| {
            let mut ga = Genetic::new(s.clone(), rng.next_u64());
            for i in 0..20 {
                let t = ga.ask(1).pop().unwrap();
                assert!(s.contains(&t.config), "off-grid {:?}", t.config);
                ga.tell(t.id, &Measurement::new(rng.range_f64(0.0, 100.0 + i as f64)));
            }
        });
    }

    #[test]
    fn parents_are_top_two() {
        let s = space();
        let mut ga = Genetic::new(s.clone(), 4);
        ga.warm_start(&vec![1, 10, 64, 0, 10], 5.0);
        ga.warm_start(&vec![2, 20, 128, 10, 20], 50.0);
        ga.warm_start(&vec![3, 30, 192, 20, 30], 20.0);
        let (b, s2) = ga.parents();
        assert_eq!(b, &vec![2, 20, 128, 10, 20]);
        assert_eq!(s2, &vec![3, 30, 192, 20, 30]);
    }

    #[test]
    fn out_of_order_tells_fill_history_with_told_configs() {
        let s = space();
        let mut ga = Genetic::new(s.clone(), 5);
        let trials = ga.ask(POPULATION);
        assert_eq!(trials.len(), POPULATION);
        // tell in reverse order; history must pair each value with the
        // config that trial id was issued for
        for (i, t) in trials.iter().enumerate().rev() {
            ga.tell(t.id, &Measurement::new(i as f64));
        }
        assert_eq!(ga.history.len(), POPULATION);
        for (i, t) in trials.iter().enumerate() {
            let slot = ga
                .history
                .iter()
                .find(|(_, v)| *v == i as f64)
                .expect("every value recorded");
            assert_eq!(slot.0, t.config, "value {i} paired with the wrong config");
        }
        // stale tell is ignored
        ga.tell(trials[0].id, &Measurement::new(999.0));
        assert_eq!(ga.history.len(), POPULATION);
    }
}
