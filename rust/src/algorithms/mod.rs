//! The algorithmic engines (paper Fig. 4): Bayesian optimization, genetic
//! algorithm, Nelder-Mead simplex, plus random-search and exhaustive-grid
//! baselines.
//!
//! All engines implement [`Tuner`], a propose/observe interface: the
//! framework asks for the next configuration to measure, applies it to the
//! system under test, and feeds the measurement back. The engines never
//! talk to the system directly — that separation is the paper's
//! "algorithm selection switch" and lets every engine share the same
//! TensorFlow interface and data-acquisition module (`History`).

pub mod bo;
pub mod coord;
pub mod ga;
pub mod grid;
pub mod nms;
pub mod random;
pub mod sa;

pub use bo::BayesOpt;
pub use coord::CoordinateDescent;
pub use ga::Genetic;
pub use grid::GridSearch;
pub use nms::NelderMead;
pub use random::RandomSearch;
pub use sa::SimulatedAnnealing;

use crate::space::Config;

/// A tuning engine. Implementations are stateful: `propose` yields the
/// next configuration, `observe` feeds back its measured objective
/// (throughput in examples/s; higher is better).
pub trait Tuner {
    /// Engine name (figure legends, CLI).
    fn name(&self) -> &'static str;

    /// Next configuration to evaluate. Always a valid grid point.
    fn propose(&mut self) -> Config;

    /// Report the measurement for the configuration from the most recent
    /// `propose` call.
    fn observe(&mut self, config: &Config, value: f64);
}

/// Which engine to run (the algorithm-selection switch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Bo,
    Ga,
    Nms,
    Random,
    Grid,
    /// Extension baseline (not in the paper): simulated annealing.
    Sa,
    /// Extension baseline (not in the paper): coordinate descent — the
    /// systematic analogue of manual expert tuning.
    Coord,
}

impl Algorithm {
    pub fn all_paper() -> [Algorithm; 3] {
        [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Bo => "bayesian-optimization",
            Algorithm::Ga => "genetic-algorithm",
            Algorithm::Nms => "nelder-mead",
            Algorithm::Random => "random-search",
            Algorithm::Grid => "grid-search",
            Algorithm::Sa => "simulated-annealing",
            Algorithm::Coord => "coordinate-descent",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_lowercase().as_str() {
            "bo" | "bayes" | "bayesian" | "bayesian-optimization" => Some(Algorithm::Bo),
            "ga" | "genetic" | "genetic-algorithm" => Some(Algorithm::Ga),
            "nms" | "nelder-mead" | "neldermead" | "simplex" => Some(Algorithm::Nms),
            "random" | "random-search" => Some(Algorithm::Random),
            "grid" | "grid-search" | "exhaustive" => Some(Algorithm::Grid),
            "sa" | "annealing" | "simulated-annealing" => Some(Algorithm::Sa),
            "cd" | "coord" | "coordinate-descent" | "hill" => Some(Algorithm::Coord),
            _ => None,
        }
    }

    /// Construct the engine with the native GP surrogate (BO). The PJRT
    /// surrogate variant is constructed explicitly via `BayesOpt::with_surrogate`.
    pub fn build(&self, space: &crate::space::SearchSpace, seed: u64) -> Box<dyn Tuner> {
        match self {
            Algorithm::Bo => Box::new(BayesOpt::new(space.clone(), seed)),
            Algorithm::Ga => Box::new(Genetic::new(space.clone(), seed)),
            Algorithm::Nms => Box::new(NelderMead::new(space.clone(), seed)),
            Algorithm::Random => Box::new(RandomSearch::new(space.clone(), seed)),
            Algorithm::Grid => Box::new(GridSearch::new(space.clone())),
            Algorithm::Sa => Box::new(SimulatedAnnealing::new(space.clone(), seed)),
            Algorithm::Coord => Box::new(CoordinateDescent::new(space.clone(), seed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Algorithm::parse("BO"), Some(Algorithm::Bo));
        assert_eq!(Algorithm::parse("simplex"), Some(Algorithm::Nms));
        assert_eq!(Algorithm::parse("genetic"), Some(Algorithm::Ga));
        assert_eq!(Algorithm::parse("unknown"), None);
    }

    #[test]
    fn build_all() {
        let space = crate::space::threading_space(64, 1024, 64);
        for a in [
            Algorithm::Bo,
            Algorithm::Ga,
            Algorithm::Nms,
            Algorithm::Random,
            Algorithm::Grid,
            Algorithm::Sa,
            Algorithm::Coord,
        ] {
            let mut t = a.build(&space, 1);
            let cfg = t.propose();
            assert!(space.contains(&cfg), "{} proposed off-grid {cfg:?}", t.name());
            t.observe(&cfg, 1.0);
        }
    }
}
