//! The algorithmic engines (paper Fig. 4): Bayesian optimization, genetic
//! algorithm, Nelder-Mead simplex, plus random-search, exhaustive-grid,
//! simulated-annealing and coordinate-descent baselines.
//!
//! # The ask/tell trial model
//!
//! All engines implement [`Tuner`], an *ask/tell* interface built around
//! [`Trial`]s: [`Tuner::ask`] requests up to `n` configurations to measure
//! — each wrapped in a `Trial` carrying an engine-unique id — and
//! [`Tuner::tell`] reports the [`Measurement`] for one trial id. Ids make
//! the conversation stateless in ordering: a driver may hold several
//! trials in flight at once (a batch spread over parallel evaluators or
//! remote daemons) and tell results back in whatever order they complete.
//!
//! Engines honour that contract each in their own way:
//! - **BO** treats open trials as *constant-liar fantasies*: pending
//!   configurations are conditioned into the GP at the mean of the
//!   observed objective so a batch spreads out instead of re-proposing
//!   the same optimistic point.
//! - **GA / SA / coordinate descent** key their bookkeeping (fitness
//!   history, Metropolis chain, probe cursor) by trial id, so late or
//!   shuffled tells land in the right slot.
//! - **NMS** issues whole simplex generations (initial vertices, shrink
//!   re-evaluations) as batches and serialises only the genuinely
//!   sequential reflect/expand/contract steps; while such a step is in
//!   flight `ask` returns an empty batch rather than inventing points.
//!
//! `ask(n)` may return *fewer* than `n` trials (even zero) when the
//! engine's internal state cannot justify more concurrency; drivers top
//! up on the next call. The engines never talk to the system under test
//! directly — that separation is the paper's "algorithm selection switch"
//! and lets every engine share the same TensorFlow interface and
//! data-acquisition module (`History`).
//!
//! # Migration from propose/observe
//!
//! Until this redesign the trait was `propose() -> Config` plus
//! `observe(&Config, f64)`, hard-coding one in-flight configuration and a
//! bare-float objective. The mapping is mechanical:
//!
//! ```text
//! let cfg = tuner.propose();            let trial = tuner.ask(1).pop().unwrap();
//! let v = eval.evaluate(&cfg)?;    =>   let m = eval.measure(&trial.config)?;
//! tuner.observe(&cfg, v);               tuner.tell(trial.id, &m);
//! ```
//!
//! The free function `evaluator::tune(tuner, evaluator, iters)` wraps
//! exactly that loop, and `session::TuningSession` is the batched,
//! budgeted, parallel driver built on the same two calls.

pub mod bo;
pub mod coord;
pub mod ga;
pub mod grid;
pub mod nms;
pub mod random;
pub mod sa;

pub use bo::BayesOpt;
pub use coord::CoordinateDescent;
pub use ga::Genetic;
pub use grid::GridSearch;
pub use nms::NelderMead;
pub use random::RandomSearch;
pub use sa::SimulatedAnnealing;

use crate::history::Measurement;
use crate::space::Config;

/// Engine-assigned identifier of one proposed trial. Unique per engine
/// instance for its whole lifetime.
pub type TrialId = u64;

/// One proposed evaluation: a grid configuration tagged with the id the
/// engine will recognise when the measurement is told back.
#[derive(Debug, Clone, PartialEq)]
pub struct Trial {
    pub id: TrialId,
    pub config: Config,
}

/// A tuning engine (ask/tell; see the module docs for the contract).
///
/// The whole conversation in six lines (any engine, any evaluator):
///
/// ```
/// use tftune::algorithms::{Algorithm, Tuner};
/// use tftune::evaluator::{Evaluator, SimEvaluator};
/// use tftune::sim::ModelId;
///
/// let space = ModelId::NcfFp32.space();
/// let mut tuner = Algorithm::Bo.build(&space, 42);
/// let mut eval = SimEvaluator::new(ModelId::NcfFp32, 42);
/// for trial in tuner.ask(2) {                       // batch of in-flight trials
///     let m = eval.measure(&trial.config).unwrap(); // Measurement, not bare f64
///     tuner.tell(trial.id, &m);                     // any completion order is fine
/// }
/// ```
pub trait Tuner {
    /// Engine name (figure legends, CLI).
    fn name(&self) -> &'static str;

    /// Request up to `n` trials to measure. Every returned configuration
    /// is a valid grid point and every id is unique across the engine's
    /// lifetime. May return fewer than `n` (or none) when the engine's
    /// state cannot justify more concurrent trials.
    fn ask(&mut self, n: usize) -> Vec<Trial>;

    /// Report the measurement for a previously asked trial. Tells may
    /// arrive in any order and interleaved with further `ask` calls;
    /// unknown ids are ignored.
    fn tell(&mut self, id: TrialId, m: &Measurement);

    /// Inject a past observation without going through ask/tell (warm
    /// starts from a persisted `History`). Engines that cannot use
    /// out-of-band data ignore it.
    fn warm_start(&mut self, _config: &Config, _value: f64) {}

    /// Like [`Tuner::warm_start`] but with the record's full objective
    /// vector (primary first, maximisation orientation — the shape
    /// `ObjectiveSet::extract` produces and `History` persists). Engines
    /// that model only the primary objective fall back to
    /// [`Tuner::warm_start`]; BO re-conditions every column, so a resumed
    /// multi-objective run restores the same K-column store.
    fn warm_start_obs(&mut self, config: &Config, value: f64, _objectives: &[f64]) {
        self.warm_start(config, value);
    }
}

/// Id allocation + open-trial ledger shared by the engine implementations.
#[derive(Debug, Default)]
pub(crate) struct TrialBook {
    next_id: TrialId,
    open: Vec<(TrialId, Config)>,
}

impl TrialBook {
    pub fn new() -> TrialBook {
        TrialBook::default()
    }

    /// Allocate an id for `config` and record it as in flight.
    pub fn issue(&mut self, config: Config) -> Trial {
        let id = self.next_id;
        self.next_id += 1;
        self.open.push((id, config.clone()));
        Trial { id, config }
    }

    /// Close an open trial, returning its configuration. None for ids
    /// that were never issued (or already settled) — callers treat that
    /// as an ignorable stale tell.
    pub fn settle(&mut self, id: TrialId) -> Option<Config> {
        let i = self.open.iter().position(|(t, _)| *t == id)?;
        Some(self.open.remove(i).1)
    }

    /// Configurations currently in flight (issue order).
    pub fn open_configs(&self) -> impl Iterator<Item = &Config> {
        self.open.iter().map(|(_, c)| c)
    }

    pub fn open_len(&self) -> usize {
        self.open.len()
    }
}

/// Which engine to run (the algorithm-selection switch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Bo,
    Ga,
    Nms,
    Random,
    Grid,
    /// Extension baseline (not in the paper): simulated annealing.
    Sa,
    /// Extension baseline (not in the paper): coordinate descent — the
    /// systematic analogue of manual expert tuning.
    Coord,
}

impl Algorithm {
    pub fn all_paper() -> [Algorithm; 3] {
        [Algorithm::Bo, Algorithm::Ga, Algorithm::Nms]
    }

    pub fn all() -> [Algorithm; 7] {
        [
            Algorithm::Bo,
            Algorithm::Ga,
            Algorithm::Nms,
            Algorithm::Random,
            Algorithm::Grid,
            Algorithm::Sa,
            Algorithm::Coord,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Bo => "bayesian-optimization",
            Algorithm::Ga => "genetic-algorithm",
            Algorithm::Nms => "nelder-mead",
            Algorithm::Random => "random-search",
            Algorithm::Grid => "grid-search",
            Algorithm::Sa => "simulated-annealing",
            Algorithm::Coord => "coordinate-descent",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_lowercase().as_str() {
            "bo" | "bayes" | "bayesian" | "bayesian-optimization" => Some(Algorithm::Bo),
            "ga" | "genetic" | "genetic-algorithm" => Some(Algorithm::Ga),
            "nms" | "nelder-mead" | "neldermead" | "simplex" => Some(Algorithm::Nms),
            "random" | "random-search" => Some(Algorithm::Random),
            "grid" | "grid-search" | "exhaustive" => Some(Algorithm::Grid),
            "sa" | "annealing" | "simulated-annealing" => Some(Algorithm::Sa),
            "cd" | "coord" | "coordinate-descent" | "hill" => Some(Algorithm::Coord),
            _ => None,
        }
    }

    /// Construct the engine with the native GP surrogate (BO). The PJRT
    /// surrogate variant is constructed explicitly via `BayesOpt::with_surrogate`.
    /// Engines are `Send` so a session can be driven from a
    /// `session::SessionGroup` thread.
    pub fn build(&self, space: &crate::space::SearchSpace, seed: u64) -> Box<dyn Tuner + Send> {
        match self {
            Algorithm::Bo => Box::new(BayesOpt::new(space.clone(), seed)),
            Algorithm::Ga => Box::new(Genetic::new(space.clone(), seed)),
            Algorithm::Nms => Box::new(NelderMead::new(space.clone(), seed)),
            Algorithm::Random => Box::new(RandomSearch::new(space.clone(), seed)),
            Algorithm::Grid => Box::new(GridSearch::new(space.clone())),
            Algorithm::Sa => Box::new(SimulatedAnnealing::new(space.clone(), seed)),
            Algorithm::Coord => Box::new(CoordinateDescent::new(space.clone(), seed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Algorithm::parse("BO"), Some(Algorithm::Bo));
        assert_eq!(Algorithm::parse("simplex"), Some(Algorithm::Nms));
        assert_eq!(Algorithm::parse("genetic"), Some(Algorithm::Ga));
        assert_eq!(Algorithm::parse("unknown"), None);
    }

    #[test]
    fn build_all() {
        let space = crate::space::threading_space(64, 1024, 64);
        for a in Algorithm::all() {
            let mut t = a.build(&space, 1);
            let trial = t.ask(1).pop().expect("fresh engine must issue a trial");
            assert!(
                space.contains(&trial.config),
                "{} proposed off-grid {:?}",
                t.name(),
                trial.config
            );
            t.tell(trial.id, &Measurement::new(1.0));
        }
    }

    #[test]
    fn trial_book_ids_unique_and_settle_once() {
        let mut book = TrialBook::new();
        let a = book.issue(vec![1]);
        let b = book.issue(vec![2]);
        assert_ne!(a.id, b.id);
        assert_eq!(book.open_len(), 2);
        assert_eq!(book.settle(a.id), Some(vec![1]));
        assert_eq!(book.settle(a.id), None, "double settle must be a no-op");
        assert_eq!(book.open_configs().collect::<Vec<_>>(), vec![&vec![2]]);
    }
}
