//! Declared objective sets and multi-objective utilities.
//!
//! The paper tunes a single scalar (training/inference time), but the
//! knobs it tunes — inter/intra-op threads, `OMP_NUM_THREADS`, allocator
//! settings — trade *throughput against tail latency*, and
//! [`Measurement`](crate::history::Measurement) already carries named
//! metadata columns (e.g. `p99_latency_ms`). An [`ObjectiveSet`] declares
//! which columns a tuning run optimises: the **primary** objective is
//! always `Measurement::value`; every further objective names a metadata
//! column and a direction (`max` by default, `:min` to minimise).
//!
//! Internally everything is *maximisation*: [`ObjectiveSet::extract`]
//! negates `:min` columns at extraction time, so the engines, the Pareto
//! helpers and the [`History`](crate::history::History) front all work in
//! one orientation. A declared column that is missing from a measurement
//! (or non-finite) extracts as NaN — the engine degrades that one trial
//! to primary-objective-only instead of poisoning the shared factor (see
//! `algorithms::bo`).
//!
//! [`Scalarization`] selects the acquisition used by the BO engine's
//! multi-objective mode: a fixed **weighted** scalarisation of the
//! per-objective optimistic gains, or an **SMSego**-style hypervolume
//! gain of the optimistic candidate point over the non-dominated front
//! (computed by [`pareto_front_indices`] / [`hypervolume`] below).
//!
//! Spec strings (CLI `--objectives` / `--scalarize`, `TuneConfig` JSON):
//!
//! ```text
//! --objectives throughput,p99_latency_ms:min   primary + one minimised column
//! --scalarize  weighted:0.7,0.3                fixed weights (one per objective)
//! --scalarize  smsego                          hypervolume-gain acquisition
//! ```

use crate::history::Measurement;

/// One declared objective: a display name (for the primary) or the
/// metadata column it reads (for secondaries), plus its direction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectiveDef {
    /// Display name; for secondary objectives this is the
    /// `Measurement::metadata` key the value is read from.
    pub name: String,
    /// Minimised objectives are negated at extraction, so every internal
    /// consumer maximises.
    pub minimize: bool,
}

/// The declared objective set of a tuning run: primary `value` first,
/// then named metadata columns. Parse one from a spec string like
/// `"throughput,p99_latency_ms:min"`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectiveSet {
    defs: Vec<ObjectiveDef>,
}

impl ObjectiveSet {
    /// Build from explicit definitions. The first entry is the primary
    /// objective (read from `Measurement::value`).
    pub fn new(defs: Vec<ObjectiveDef>) -> Result<ObjectiveSet, String> {
        if defs.is_empty() {
            return Err("objective set needs at least the primary objective".to_string());
        }
        for d in &defs {
            if d.name.is_empty() {
                return Err("empty objective name".to_string());
            }
        }
        for i in 1..defs.len() {
            if defs[..i].iter().any(|d| d.name == defs[i].name) {
                return Err(format!("duplicate objective '{}'", defs[i].name));
            }
        }
        Ok(ObjectiveSet { defs })
    }

    /// Parse `"name[:min|:max],name[:min|:max],..."`. The first entry is
    /// the primary objective (its name is informational — the value is
    /// always `Measurement::value`); later entries name metadata columns.
    pub fn parse(spec: &str) -> Result<ObjectiveSet, String> {
        let mut defs = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(format!("empty objective in spec '{spec}'"));
            }
            let (name, minimize) = match part.rsplit_once(':') {
                Some((n, "min")) => (n, true),
                Some((n, "max")) => (n, false),
                Some((_, dir)) => {
                    return Err(format!("unknown direction '{dir}' (use :min or :max)"));
                }
                None => (part, false),
            };
            defs.push(ObjectiveDef { name: name.trim().to_string(), minimize });
        }
        ObjectiveSet::new(defs)
    }

    /// Canonical spec string (round-trips through [`ObjectiveSet::parse`]).
    pub fn spec(&self) -> String {
        self.defs
            .iter()
            .map(|d| {
                if d.minimize {
                    format!("{}:min", d.name)
                } else {
                    d.name.clone()
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Number of objectives (K), primary included.
    pub fn k(&self) -> usize {
        self.defs.len()
    }

    /// More than one objective declared?
    pub fn is_multi(&self) -> bool {
        self.defs.len() > 1
    }

    pub fn defs(&self) -> &[ObjectiveDef] {
        &self.defs
    }

    /// Extract the K objective values from a measurement, in declared
    /// order and **maximisation orientation** (`:min` columns negated).
    /// `values[0]` is always `m.value`. A declared metadata column that
    /// is absent or non-finite extracts as NaN, and its index lands in
    /// `missing` — callers degrade that trial to primary-objective-only.
    pub fn extract(&self, m: &Measurement) -> (Vec<f64>, Vec<usize>) {
        let mut values = Vec::with_capacity(self.defs.len());
        let mut missing = Vec::new();
        for (k, d) in self.defs.iter().enumerate() {
            let raw = if k == 0 {
                Some(m.value)
            } else {
                m.metadata.iter().find(|(name, _)| name == &d.name).map(|&(_, v)| v)
            };
            match raw {
                Some(v) if v.is_finite() => values.push(if d.minimize { -v } else { v }),
                _ => {
                    values.push(f64::NAN);
                    missing.push(k);
                }
            }
        }
        (values, missing)
    }
}

/// How the BO engine collapses K per-objective gains into one
/// acquisition value per candidate.
#[derive(Debug, Clone, PartialEq)]
pub enum Scalarization {
    /// Fixed weighted sum of per-objective optimistic gains
    /// `Σ_k w_k ((μ_k + α σ) − y*_k)`. Weights must be positive, one per
    /// objective; permuting the weights together with the objectives
    /// leaves the scalarised gain unchanged.
    Weighted(Vec<f64>),
    /// SMSego-style hypervolume gain: the increase in dominated
    /// hypervolume when the candidate's optimistic point joins the
    /// current non-dominated front.
    Smsego,
}

impl Scalarization {
    /// Parse `"weighted:w1,w2,..."` or `"smsego"` (aliases `hv`,
    /// `hypervolume`). `"weighted"` without weights means equal weights,
    /// resolved against the objective set at build time.
    pub fn parse(spec: &str) -> Result<Scalarization, String> {
        let spec = spec.trim();
        match spec.to_lowercase().as_str() {
            "smsego" | "hv" | "hypervolume" => return Ok(Scalarization::Smsego),
            "weighted" => return Ok(Scalarization::Weighted(Vec::new())),
            _ => {}
        }
        if let Some(ws) = spec.strip_prefix("weighted:") {
            let weights: Result<Vec<f64>, String> = ws
                .split(',')
                .map(|w| {
                    w.trim()
                        .parse::<f64>()
                        .map_err(|_| format!("bad weight '{}'", w.trim()))
                })
                .collect();
            let weights = weights?;
            if weights.iter().any(|&w| !(w.is_finite() && w > 0.0)) {
                return Err("scalarisation weights must be positive and finite".to_string());
            }
            return Ok(Scalarization::Weighted(weights));
        }
        Err(format!("unknown scalarization '{spec}' (weighted:<w,..> or smsego)"))
    }

    /// Canonical spec string (round-trips through [`Scalarization::parse`]).
    pub fn spec(&self) -> String {
        match self {
            Scalarization::Smsego => "smsego".to_string(),
            Scalarization::Weighted(w) if w.is_empty() => "weighted".to_string(),
            Scalarization::Weighted(w) => format!(
                "weighted:{}",
                w.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
            ),
        }
    }

    /// Resolve empty weighted specs to equal weights over `k` objectives
    /// and validate the weight count.
    pub fn resolve(self, k: usize) -> Result<Scalarization, String> {
        match self {
            Scalarization::Weighted(w) if w.is_empty() => {
                Ok(Scalarization::Weighted(vec![1.0 / k as f64; k]))
            }
            Scalarization::Weighted(w) if w.len() != k => Err(format!(
                "{} scalarisation weights for {k} objectives",
                w.len()
            )),
            other => Ok(other),
        }
    }
}

/// The weighted scalarised gain of one candidate:
/// `Σ_k w_k (optimistic_k − y_best_k)` — exactly what the BO engine's
/// `Weighted` acquisition evaluates per candidate. Permuting the weights
/// together with the objectives leaves the value unchanged (addition is
/// commutative; for K>2 re-association stays within a few ulp), and with
/// positive weights a candidate whose optimistic vector is dominated by
/// another's can never score highest.
pub fn weighted_gain(weights: &[f64], optimistic: &[f64], y_best: &[f64]) -> f64 {
    debug_assert_eq!(weights.len(), optimistic.len());
    debug_assert_eq!(weights.len(), y_best.len());
    let mut g = 0.0;
    for ((w, o), b) in weights.iter().zip(optimistic).zip(y_best) {
        g += w * (o - b);
    }
    g
}

// ---------------------------------------------------------------------------
// Pareto helpers (maximisation orientation throughout).
// ---------------------------------------------------------------------------

/// Does `a` dominate `b`? (a ≥ b in every coordinate, > in at least one;
/// maximisation.) Any NaN coordinate makes the answer false.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (&x, &y) in a.iter().zip(b) {
        if !(x >= y) {
            return false; // also catches NaN on either side
        }
        if x > y {
            strict = true;
        }
    }
    strict
}

/// Indices of the non-dominated points among `points` (maximisation).
/// Points with any non-finite coordinate never enter the front. Among
/// exact duplicates the earliest index is kept.
pub fn pareto_front_indices(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        if p.iter().any(|v| !v.is_finite()) {
            continue;
        }
        for (j, q) in points.iter().enumerate() {
            if i == j || q.iter().any(|v| !v.is_finite()) {
                continue;
            }
            if dominates(q, p) || (q == p && j < i) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Dominated hypervolume of `points` with respect to the reference point
/// `ref_point` (maximisation: the measure of the region dominated by at
/// least one point and above `ref_point` in every coordinate). Exact, by
/// recursive slicing on the last dimension — fine for the small fronts a
/// tuning history produces. Points not strictly above the reference in
/// every coordinate contribute nothing; non-finite points are ignored.
pub fn hypervolume(points: &[Vec<f64>], ref_point: &[f64]) -> f64 {
    let d = ref_point.len();
    let pts: Vec<&Vec<f64>> = points
        .iter()
        .filter(|p| {
            p.len() == d
                && p.iter().all(|v| v.is_finite())
                && p.iter().zip(ref_point).all(|(v, r)| v > r)
        })
        .collect();
    hv_rec(&pts, ref_point, d)
}

fn hv_rec(points: &[&Vec<f64>], ref_point: &[f64], d: usize) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    if d == 1 {
        let best = points.iter().map(|p| p[0]).fold(f64::NEG_INFINITY, f64::max);
        return (best - ref_point[0]).max(0.0);
    }
    // Slice along dimension d-1: between consecutive levels, the cross
    // section is the (d-1)-dimensional hypervolume of the points reaching
    // that high.
    let mut levels: Vec<f64> = points.iter().map(|p| p[d - 1]).collect();
    levels.sort_by(|a, b| b.partial_cmp(a).expect("finite by construction"));
    levels.dedup();
    let mut total = 0.0;
    for (i, &z) in levels.iter().enumerate() {
        let lower = if i + 1 < levels.len() { levels[i + 1] } else { ref_point[d - 1] };
        let slab = z - lower;
        if slab <= 0.0 {
            continue;
        }
        let active: Vec<&Vec<f64>> =
            points.iter().filter(|p| p[d - 1] >= z).copied().collect();
        total += slab * hv_rec(&active, ref_point, d - 1);
    }
    total
}

/// A reference point safely below `points` in every coordinate
/// (componentwise finite minimum minus `margin`). `None` when no point
/// is fully finite.
pub fn hv_reference(points: &[Vec<f64>], k: usize, margin: f64) -> Option<Vec<f64>> {
    let mut r = vec![f64::INFINITY; k];
    let mut any = false;
    for p in points {
        if p.len() != k || p.iter().any(|v| !v.is_finite()) {
            continue;
        }
        any = true;
        for (ri, &v) in r.iter_mut().zip(p) {
            *ri = ri.min(v);
        }
    }
    if !any {
        return None;
    }
    Some(r.into_iter().map(|v| v - margin).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_spec_round_trip() {
        let set = ObjectiveSet::parse("throughput,p99_latency_ms:min").unwrap();
        assert_eq!(set.k(), 2);
        assert!(set.is_multi());
        assert!(!set.defs()[0].minimize);
        assert!(set.defs()[1].minimize);
        assert_eq!(set.spec(), "throughput,p99_latency_ms:min");
        assert_eq!(ObjectiveSet::parse(&set.spec()).unwrap(), set);

        let single = ObjectiveSet::parse("throughput").unwrap();
        assert!(!single.is_multi());

        assert!(ObjectiveSet::parse("").is_err());
        assert!(ObjectiveSet::parse("a,,b").is_err());
        assert!(ObjectiveSet::parse("a,a").is_err());
        assert!(ObjectiveSet::parse("a:sideways").is_err());
    }

    #[test]
    fn extract_negates_min_and_flags_missing() {
        let set = ObjectiveSet::parse("tp,p99:min,mem:min").unwrap();
        let m = Measurement::new(100.0)
            .with_metadata("p99", 7.5)
            .with_metadata("unrelated", 1.0);
        let (v, missing) = set.extract(&m);
        assert_eq!(v[0], 100.0);
        assert_eq!(v[1], -7.5, "minimised column is negated");
        assert!(v[2].is_nan(), "absent column extracts as NaN");
        assert_eq!(missing, vec![2]);

        let m2 = Measurement::new(1.0).with_metadata("p99", f64::NAN).with_metadata("mem", 3.0);
        let (v2, missing2) = set.extract(&m2);
        assert!(v2[1].is_nan());
        assert_eq!(v2[2], -3.0);
        assert_eq!(missing2, vec![1]);
    }

    #[test]
    fn scalarization_parse_round_trip() {
        for spec in ["smsego", "weighted:0.7,0.3", "weighted"] {
            let s = Scalarization::parse(spec).unwrap();
            assert_eq!(Scalarization::parse(&s.spec()).unwrap(), s, "spec {spec}");
        }
        assert_eq!(Scalarization::parse("hv").unwrap(), Scalarization::Smsego);
        assert!(Scalarization::parse("weighted:0.5,-1").is_err());
        assert!(Scalarization::parse("weighted:x").is_err());
        assert!(Scalarization::parse("nope").is_err());

        let eq = Scalarization::Weighted(Vec::new()).resolve(2).unwrap();
        assert_eq!(eq, Scalarization::Weighted(vec![0.5, 0.5]));
        assert!(Scalarization::Weighted(vec![1.0]).resolve(2).is_err());
        assert_eq!(Scalarization::Smsego.resolve(3).unwrap(), Scalarization::Smsego);
    }

    #[test]
    fn dominance_and_front() {
        assert!(dominates(&[2.0, 2.0], &[1.0, 2.0]));
        assert!(!dominates(&[2.0, 1.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal points do not dominate");
        assert!(!dominates(&[f64::NAN, 5.0], &[0.0, 0.0]));

        let pts = vec![
            vec![1.0, 4.0],
            vec![3.0, 3.0],
            vec![2.0, 2.0], // dominated by (3,3)
            vec![4.0, 1.0],
            vec![f64::NAN, 9.0], // never on the front
            vec![3.0, 3.0],      // duplicate: earliest kept
        ];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn hypervolume_matches_hand_computed_2d() {
        let r = [0.0, 0.0];
        // Single point: a rectangle.
        assert!((hypervolume(&[vec![2.0, 3.0]], &r) - 6.0).abs() < 1e-12);
        // Two staircase points: union of rectangles = 3*1 + 2*... let's
        // hand-compute: (1,3) and (3,1): 1*3 + (3-1)*1 = 5.
        let hv = hypervolume(&[vec![1.0, 3.0], vec![3.0, 1.0]], &r);
        assert!((hv - 5.0).abs() < 1e-12, "hv {hv}");
        // A dominated point adds nothing.
        let hv2 =
            hypervolume(&[vec![1.0, 3.0], vec![3.0, 1.0], vec![0.5, 0.5]], &r);
        assert!((hv2 - 5.0).abs() < 1e-12);
        // Points at/below the reference contribute nothing.
        assert_eq!(hypervolume(&[vec![0.0, 5.0]], &r), 0.0);
        assert_eq!(hypervolume(&[vec![-1.0, -1.0]], &r), 0.0);
    }

    #[test]
    fn hypervolume_3d_box_union() {
        // Two boxes sharing a corner at the reference: (1,1,2) and
        // (2,1,1): union = 2 + 2 - overlap(1*1*1) = 3.
        let hv = hypervolume(&[vec![1.0, 1.0, 2.0], vec![2.0, 1.0, 1.0]], &[0.0, 0.0, 0.0]);
        assert!((hv - 3.0).abs() < 1e-12, "hv {hv}");
    }

    #[test]
    fn hypervolume_is_monotone_in_added_points() {
        let r = [-1.0, -1.0];
        let mut pts: Vec<Vec<f64>> = Vec::new();
        let mut prev = 0.0;
        for p in [vec![0.0, 1.0], vec![1.0, 0.0], vec![0.6, 0.6], vec![-0.5, 2.0]] {
            pts.push(p);
            let hv = hypervolume(&pts, &r);
            assert!(hv >= prev - 1e-15, "hv shrank: {hv} < {prev}");
            prev = hv;
        }
    }

    #[test]
    fn hv_reference_sits_below_every_point() {
        let pts = vec![vec![1.0, -2.0], vec![0.5, 4.0], vec![f64::NAN, 0.0]];
        let r = hv_reference(&pts, 2, 1.0).unwrap();
        assert_eq!(r, vec![-0.5, -3.0]);
        assert!(hv_reference(&[vec![f64::NAN, 0.0]], 2, 1.0).is_none());
    }
}
