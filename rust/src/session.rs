//! [`TuningSession`]: the batched, budgeted tuning driver.
//!
//! A session owns one ask/tell engine, a pool of one or more
//! [`Evaluator`]s (threads over sim/real targets, or one TCP connection
//! per remote daemon), and a [`Budget`]. It keeps up to `pool-size` trials
//! in flight: the engine is asked for as many trials as there are idle
//! evaluators, results are told back in completion order (which under
//! parallelism is *not* issue order — the engines are built for that), and
//! every completed trial streams through the optional per-trial callback
//! before landing in the returned [`History`].
//!
//! With a single evaluator the session runs inline on the caller's thread
//! and is bit-for-bit identical to the serial `evaluator::tune()` loop —
//! that is the `--parallel 1` reproducibility guarantee the tests pin.
//!
//! [`SessionGroup`] runs *several* sessions concurrently on one host —
//! one thread per session — which is where the shared surrogate earns its
//! keep: give every BO engine in the group a handle to one
//! [`SharedSurrogate`] ([`SessionGroup::shared_bo`] wires this up) and
//! all of their measurements condition a single incremental factor
//! instead of each session refitting its own.
//!
//! # Example
//!
//! ```
//! use tftune::algorithms::Algorithm;
//! use tftune::evaluator::{sim_pool, Objective};
//! use tftune::session::{Budget, StopReason, TuningSession};
//! use tftune::sim::ModelId;
//!
//! let model = ModelId::NcfFp32;
//! let mut session = TuningSession::new(
//!     Algorithm::Bo.build(&model.space(), 7),
//!     sim_pool(model, 7, 0.0, Objective::Throughput, 2), // 2 evaluator threads
//!     Budget::evaluations(12),
//! );
//! let history = session.run().unwrap();
//! assert_eq!(history.len(), 12);
//! assert_eq!(session.stop_reason(), Some(StopReason::MaxEvaluations));
//! ```

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::algorithms::{BayesOpt, Trial, Tuner};
use crate::evaluator::Evaluator;
use crate::gp::{GpHyper, RemoteSurrogate, SharedSurrogate};
use crate::history::{History, Measurement};
use crate::objectives::ObjectiveSet;
use crate::obs::{Event, EventSource};
use crate::space::SearchSpace;

/// Plateau stop: end the run after `window` consecutive completed trials
/// without a relative improvement of at least `min_rel_gain` over the best
/// value seen so far.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plateau {
    pub window: usize,
    pub min_rel_gain: f64,
}

/// Stopping rules for a [`TuningSession`]. At least one rule must be set.
///
/// Rules compose; the first one to fire stops the session:
///
/// ```
/// use tftune::session::{Budget, Plateau};
///
/// let b = Budget::evaluations(50)       // the paper's per-run cap
///     .with_max_seconds(300.0)          // …or five minutes of wall clock
///     .with_plateau(8, 0.01);           // …or 8 trials without +1% gain
/// assert!(b.is_bounded());
/// assert_eq!(b.max_evaluations, Some(50));
/// assert_eq!(b.plateau, Some(Plateau { window: 8, min_rel_gain: 0.01 }));
/// assert!(!Budget::default().is_bounded()); // no rule: session refuses to run
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Budget {
    /// Stop after this many completed evaluations (the paper caps at 50).
    pub max_evaluations: Option<usize>,
    /// Stop once this much wall-clock time has elapsed (checked at trial
    /// completion granularity; in-flight trials run to completion).
    pub max_seconds: Option<f64>,
    /// Stop when the best-so-far curve plateaus.
    pub plateau: Option<Plateau>,
}

impl Budget {
    /// Budget with only an evaluation cap — the classic fixed-iteration run.
    pub fn evaluations(n: usize) -> Budget {
        Budget { max_evaluations: Some(n), ..Budget::default() }
    }

    pub fn with_max_seconds(mut self, seconds: f64) -> Budget {
        self.max_seconds = Some(seconds);
        self
    }

    pub fn with_plateau(mut self, window: usize, min_rel_gain: f64) -> Budget {
        self.plateau = Some(Plateau { window, min_rel_gain });
        self
    }

    /// Does any stopping rule exist? An unbounded session would never end.
    pub fn is_bounded(&self) -> bool {
        self.max_evaluations.is_some() || self.max_seconds.is_some() || self.plateau.is_some()
    }
}

/// Why a session ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The evaluation cap was reached.
    MaxEvaluations,
    /// The wall-clock limit elapsed.
    MaxSeconds,
    /// The best-so-far curve plateaued.
    Plateau,
    /// The engine issued no trials with none in flight (nothing left to try).
    EngineExhausted,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::MaxEvaluations => "max-evaluations",
            StopReason::MaxSeconds => "max-seconds",
            StopReason::Plateau => "plateau",
            StopReason::EngineExhausted => "engine-exhausted",
        }
    }
}

/// Best-so-far improvement tracking for the plateau rule.
struct PlateauTracker {
    rule: Option<Plateau>,
    best: f64,
    stale: usize,
}

impl PlateauTracker {
    fn new(rule: Option<Plateau>) -> PlateauTracker {
        PlateauTracker { rule, best: f64::NEG_INFINITY, stale: 0 }
    }

    fn record(&mut self, value: f64) {
        let Some(rule) = self.rule else { return };
        let bar = if self.best.is_finite() {
            self.best + self.best.abs() * rule.min_rel_gain
        } else {
            f64::NEG_INFINITY
        };
        if value > bar {
            self.best = self.best.max(value);
            self.stale = 0;
        } else {
            self.stale += 1;
        }
    }

    fn plateaued(&self) -> bool {
        self.rule.map_or(false, |r| self.stale >= r.window)
    }
}

/// Per-trial callback: invoked on the driving thread for every completed
/// trial, in completion order (streaming history out of a long run).
/// `Send` so whole sessions can run on [`SessionGroup`] threads.
pub type TrialCallback = Box<dyn FnMut(&Trial, &Measurement) + Send>;

/// The session's hook into the observability plane: one [`EventSource`]
/// plus the incumbent tracking needed to decide when the front advanced.
/// Every emission is non-blocking (see [`crate::obs`]) and near-free on
/// a sink-less bus, so the tap rides the driver loop unconditionally
/// once installed.
struct EventTap {
    src: EventSource,
    /// Single-objective incumbent (front-advanced = new strict best).
    best: f64,
}

impl EventTap {
    fn new(src: EventSource) -> EventTap {
        EventTap { src, best: f64::NEG_INFINITY }
    }

    /// `ask-start` before the engine call; returns the timing anchor.
    fn ask_start(&self, want: usize) -> Instant {
        self.src.emit(Event::AskStart { want });
        Instant::now()
    }

    /// `ask-end` + one `trial-issued` per issued trial.
    fn asked(&self, t0: Instant, trials: &[Trial]) {
        self.src.emit(Event::AskEnd {
            issued: trials.len(),
            ns: t0.elapsed().as_nanos() as u64,
        });
        for t in trials {
            self.src.emit(Event::TrialIssued { trial: t.id });
        }
    }

    /// `trial-measured` (the full replayable payload, read back off the
    /// just-pushed history row), then front tracking: single-objective
    /// runs advance on a strict new best; multi-objective runs advance
    /// when the new point is non-dominated, and every measurement
    /// re-states the dominated hypervolume (the reference point is
    /// history-derived, so HV can move even when the front does not —
    /// see `History::hypervolume_auto`). Skipped entirely — including
    /// the front recomputation — while the bus has no sink.
    fn measured(&mut self, history: &History) {
        if !self.src.enabled() {
            return;
        }
        let e = history.last().expect("EventTap::measured before any push");
        self.src.emit(Event::TrialMeasured {
            trial: e.trial_id,
            config: e.config.clone(),
            value: e.value,
            cost_s: e.cost_s,
            objectives: e.objectives.clone(),
        });
        if e.objectives.is_empty() {
            if e.value > self.best {
                self.best = e.value;
                self.src.emit(Event::FrontAdvanced { trial: e.trial_id, front_size: 1 });
            }
        } else {
            let trial_id = e.trial_id;
            let newest = e.iteration;
            let front = history.pareto_front();
            if front.iter().any(|f| f.iteration == newest) {
                self.src.emit(Event::FrontAdvanced { trial: trial_id, front_size: front.len() });
            }
            if let Some(hv) = history.hypervolume_auto(crate::obs::dashboard::HV_MARGIN) {
                self.src.emit(Event::Hypervolume { hv });
            }
        }
    }
}

/// The tuning driver: engine + evaluator pool + budget (module docs).
pub struct TuningSession {
    tuner: Box<dyn Tuner + Send>,
    evaluators: Vec<Box<dyn Evaluator + Send>>,
    budget: Budget,
    on_trial: Option<TrialCallback>,
    stop_reason: Option<StopReason>,
    /// Declared objective set of a multi-objective run: every completed
    /// trial's K-objective vector is extracted and recorded in the
    /// [`History`], so Pareto fronts and hypervolume curves are readable
    /// straight off the returned history.
    objectives: Option<ObjectiveSet>,
    /// Observability tap (see [`crate::obs`]); None = zero overhead.
    events: Option<EventTap>,
}

impl TuningSession {
    pub fn new(
        tuner: Box<dyn Tuner + Send>,
        evaluators: Vec<Box<dyn Evaluator + Send>>,
        budget: Budget,
    ) -> TuningSession {
        TuningSession {
            tuner,
            evaluators,
            budget,
            on_trial: None,
            stop_reason: None,
            objectives: None,
            events: None,
        }
    }

    /// Emit the session's lifecycle onto the observability plane:
    /// `ask-start`/`ask-end` around every engine call, one
    /// `trial-issued` + `trial-measured` per evaluation (the measured
    /// payload replays into a bit-identical [`History`]), and
    /// `front-advanced`/`hypervolume` as the incumbent or the
    /// non-dominated front moves. All emissions are non-blocking; a
    /// sink-less bus costs one atomic load per event.
    pub fn with_events(mut self, source: EventSource) -> Self {
        self.events = Some(EventTap::new(source));
        self
    }

    /// Stream every completed trial through `callback`.
    pub fn on_trial(
        mut self,
        callback: impl FnMut(&Trial, &Measurement) + Send + 'static,
    ) -> Self {
        self.on_trial = Some(Box::new(callback));
        self
    }

    /// Record each completed trial's objective vector (extracted via
    /// `objectives.extract`, maximisation orientation) into the returned
    /// history — [`History::pareto_front`] / [`History::hypervolume`]
    /// then work out of the box. Pair this with a tuner built by
    /// `BayesOpt::with_objectives` so the engine optimises the same set
    /// it records.
    pub fn with_objectives(mut self, objectives: ObjectiveSet) -> Self {
        self.objectives = Some(objectives);
        self
    }

    /// Why the last `run` ended (None before the first run).
    pub fn stop_reason(&self) -> Option<StopReason> {
        self.stop_reason
    }

    /// Evaluator pool size (the in-flight trial cap).
    pub fn parallelism(&self) -> usize {
        self.evaluators.len()
    }

    /// Drive the session to a stop and return the completed history.
    pub fn run(&mut self) -> Result<History> {
        anyhow::ensure!(!self.evaluators.is_empty(), "session needs at least one evaluator");
        anyhow::ensure!(
            self.budget.is_bounded(),
            "session budget has no stopping rule (set max evaluations, max seconds or plateau)"
        );
        self.stop_reason = None;
        let (history, reason) = if self.evaluators.len() == 1 {
            self.run_serial()?
        } else {
            self.run_parallel()?
        };
        self.stop_reason = Some(reason);
        Ok(history)
    }

    /// Which stop rule (if any) fires with `done` completed evaluations?
    fn stopped(
        budget: &Budget,
        done: usize,
        start: Instant,
        tracker: &PlateauTracker,
    ) -> Option<StopReason> {
        if budget.max_evaluations.map_or(false, |m| done >= m) {
            return Some(StopReason::MaxEvaluations);
        }
        if budget.max_seconds.map_or(false, |s| start.elapsed().as_secs_f64() >= s) {
            return Some(StopReason::MaxSeconds);
        }
        if tracker.plateaued() {
            return Some(StopReason::Plateau);
        }
        None
    }

    /// Single-evaluator fast path: inline, deterministic, identical to the
    /// serial `tune()` loop.
    fn run_serial(&mut self) -> Result<(History, StopReason)> {
        let evaluator = &mut self.evaluators[0];
        let mut history = History::new();
        let mut tracker = PlateauTracker::new(self.budget.plateau);
        let start = Instant::now();
        loop {
            if let Some(reason) = Self::stopped(&self.budget, history.len(), start, &tracker) {
                return Ok((history, reason));
            }
            let t0 = self.events.as_ref().map(|tap| tap.ask_start(1));
            let batch = self.tuner.ask(1);
            if let (Some(tap), Some(t0)) = (&self.events, t0) {
                tap.asked(t0, &batch);
            }
            let Some(trial) = batch.into_iter().next() else {
                return Ok((history, StopReason::EngineExhausted));
            };
            let m = evaluator.measure(&trial.config)?;
            anyhow::ensure!(
                m.value.is_finite(),
                "evaluator returned non-finite measurement {} for {:?}",
                m.value,
                trial.config
            );
            self.tuner.tell(trial.id, &m);
            tracker.record(m.value);
            let objectives = match &self.objectives {
                Some(set) => set.extract(&m).0,
                None => Vec::new(),
            };
            history.push_trial_multi(trial.id, trial.config.clone(), &m, objectives);
            if let Some(tap) = &mut self.events {
                tap.measured(&history);
            }
            if let Some(cb) = &mut self.on_trial {
                cb(&trial, &m);
            }
        }
    }

    /// Multi-evaluator path: one worker thread per evaluator, trials fanned
    /// out over a shared queue, results told back in completion order.
    fn run_parallel(&mut self) -> Result<(History, StopReason)> {
        let pool = self.evaluators.len();
        let budget = self.budget.clone();
        let tuner = &mut self.tuner;
        let on_trial = &mut self.on_trial;
        let objectives = self.objectives.clone();
        let events = &mut self.events;
        let evaluators = &mut self.evaluators;

        std::thread::scope(|scope| -> Result<(History, StopReason)> {
            let (work_tx, work_rx) = mpsc::channel::<Trial>();
            let work_rx = Arc::new(Mutex::new(work_rx));
            let (done_tx, done_rx) = mpsc::channel::<(Trial, Result<Measurement>)>();
            for evaluator in evaluators.iter_mut() {
                let work_rx = Arc::clone(&work_rx);
                let done_tx = done_tx.clone();
                scope.spawn(move || loop {
                    // Hold the lock only to pop one trial.
                    let next = { work_rx.lock().unwrap().recv() };
                    let Ok(trial) = next else { break };
                    // A panicking evaluator must surface as an Err, not kill
                    // the worker: a dead worker would strand its trial in
                    // in_flight and deadlock the driver on done_rx.recv().
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || evaluator.measure(&trial.config),
                    ))
                    .unwrap_or_else(|panic| {
                        let msg = panic
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "non-string panic".to_string());
                        Err(anyhow::anyhow!("evaluator panicked: {msg}"))
                    });
                    if done_tx.send((trial, result)).is_err() {
                        break;
                    }
                });
            }
            drop(done_tx);

            let mut history = History::new();
            let mut tracker = PlateauTracker::new(budget.plateau);
            let start = Instant::now();
            let mut in_flight = 0usize;
            let mut error: Option<anyhow::Error> = None;
            let reason = loop {
                if let Some(reason) = Self::stopped(&budget, history.len(), start, &tracker) {
                    break reason;
                }
                // Top the pool up, but never schedule past the eval cap.
                let room = pool - in_flight;
                let capped = budget
                    .max_evaluations
                    .map(|m| m.saturating_sub(history.len() + in_flight))
                    .unwrap_or(usize::MAX);
                let want = room.min(capped);
                if want > 0 {
                    let t0 = events.as_ref().map(|tap| tap.ask_start(want));
                    let batch = tuner.ask(want);
                    if let (Some(tap), Some(t0)) = (events.as_ref(), t0) {
                        tap.asked(t0, &batch);
                    }
                    for trial in batch {
                        if work_tx.send(trial).is_ok() {
                            in_flight += 1;
                        }
                    }
                }
                if in_flight == 0 {
                    break StopReason::EngineExhausted;
                }
                let (trial, result) = done_rx.recv().expect("evaluator pool hung up");
                in_flight -= 1;
                let m = match result {
                    Ok(m) if m.value.is_finite() => m,
                    Ok(m) => {
                        error = Some(anyhow::anyhow!(
                            "evaluator returned non-finite measurement {} for {:?}",
                            m.value,
                            trial.config
                        ));
                        break StopReason::EngineExhausted;
                    }
                    Err(e) => {
                        error = Some(e);
                        break StopReason::EngineExhausted;
                    }
                };
                tuner.tell(trial.id, &m);
                tracker.record(m.value);
                let obj_vec = match &objectives {
                    Some(set) => set.extract(&m).0,
                    None => Vec::new(),
                };
                history.push_trial_multi(trial.id, trial.config.clone(), &m, obj_vec);
                if let Some(tap) = events.as_mut() {
                    tap.measured(&history);
                }
                if let Some(cb) = on_trial.as_mut() {
                    cb(&trial, &m);
                }
            };
            // Unblock the workers (in-flight trials finish and are dropped),
            // then let the scope join them.
            drop(work_tx);
            match error {
                Some(e) => Err(e),
                None => Ok((history, reason)),
            }
        })
    }
}

/// Several [`TuningSession`]s driven concurrently on one host — one
/// thread per session, each with its own engine, evaluator pool and
/// budget.
///
/// The group is surrogate-agnostic: sessions may be fully independent.
/// The intended use, though, is [`SessionGroup::shared_bo`]: every BO
/// engine borrows a handle to **one** [`SharedSurrogate`] per search
/// space, so all concurrent measurements condition a single incremental
/// factor (tells enqueue without blocking; each engine's ask drains and
/// scores under the model lock — see `gp::shared` for the contract).
/// [`SessionGroup::remote_shared_bo`] is the cross-process variant: the
/// factor lives in a surrogate service and every session attaches a
/// [`RemoteSurrogate`] replica over its own TCP connection.
pub struct SessionGroup {
    sessions: Vec<TuningSession>,
}

impl Default for SessionGroup {
    fn default() -> Self {
        SessionGroup::new()
    }
}

impl SessionGroup {
    pub fn new() -> SessionGroup {
        SessionGroup { sessions: Vec::new() }
    }

    /// Add a session to the group.
    pub fn push(&mut self, session: TuningSession) {
        self.sessions.push(session);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// One BO session per seed, all conditioning a single shared
    /// surrogate over `space`. `make_pool(i)` supplies the i-th session's
    /// evaluator pool. Returns the handle (observable/reusable after the
    /// run) and the ready-to-run group.
    pub fn shared_bo(
        space: &SearchSpace,
        seeds: &[u64],
        budget: Budget,
        mut make_pool: impl FnMut(usize) -> Vec<Box<dyn Evaluator + Send>>,
    ) -> (SharedSurrogate, SessionGroup) {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut group = SessionGroup::new();
        for (i, &seed) in seeds.iter().enumerate() {
            let tuner =
                Box::new(BayesOpt::new(space.clone(), seed).with_shared_surrogate(shared.clone()));
            group.push(TuningSession::new(tuner, make_pool(i), budget.clone()));
        }
        (shared, group)
    }

    /// The cross-process sibling of [`SessionGroup::shared_bo`]: one BO
    /// session per seed, each conditioning a [`RemoteSurrogate`] replica
    /// of the factor served at `surrogate_addr` (a daemon started with
    /// `surrogate-serve`, or any [`crate::server::TargetServer`] with an
    /// attached surrogate). Each session gets its *own* connection, so
    /// its constant-liar lease expires independently if it dies — exactly
    /// how separate tuner processes on other hosts attach. Fails fast if
    /// the service is unreachable or speaks the wrong protocol version.
    pub fn remote_shared_bo(
        space: &SearchSpace,
        surrogate_addr: &str,
        seeds: &[u64],
        budget: Budget,
        mut make_pool: impl FnMut(usize) -> Vec<Box<dyn Evaluator + Send>>,
    ) -> Result<SessionGroup> {
        let mut group = SessionGroup::new();
        for (i, &seed) in seeds.iter().enumerate() {
            // Deliberately the un-fingerprinted attach: every session in
            // the group conditions the daemon's *default* space, whatever
            // model it tunes — the pre-v4 contract this helper has always
            // had. Use `RemoteSurrogate::connect_space` directly to target
            // a per-space factor on a fleet daemon.
            let handle = RemoteSurrogate::connect(surrogate_addr)?;
            let tuner = Box::new(BayesOpt::new(space.clone(), seed).with_shared_surrogate(handle));
            group.push(TuningSession::new(tuner, make_pool(i), budget.clone()));
        }
        Ok(group)
    }

    /// Run every session to its stop, concurrently, and return their
    /// histories in push order. The first session error (or panic) is
    /// propagated after all sessions have finished.
    pub fn run(&mut self) -> Result<Vec<History>> {
        anyhow::ensure!(!self.sessions.is_empty(), "session group is empty");
        let results: Vec<Result<History>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .sessions
                .iter_mut()
                .map(|session| scope.spawn(move || session.run()))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow::anyhow!("session thread panicked")))
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Why each session ended (push order; None before the first run).
    pub fn stop_reasons(&self) -> Vec<Option<StopReason>> {
        self.sessions.iter().map(|s| s.stop_reason()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;
    use crate::evaluator::{sim_pool, tune, Objective, SimEvaluator};
    use crate::sim::ModelId;
    use crate::space::Config;

    #[test]
    fn budget_builders_compose() {
        let b = Budget::evaluations(50).with_max_seconds(1.5).with_plateau(8, 0.01);
        assert_eq!(b.max_evaluations, Some(50));
        assert_eq!(b.max_seconds, Some(1.5));
        assert_eq!(b.plateau, Some(Plateau { window: 8, min_rel_gain: 0.01 }));
        assert!(b.is_bounded());
        assert!(!Budget::default().is_bounded());
    }

    #[test]
    fn unbounded_budget_is_rejected() {
        let model = ModelId::NcfFp32;
        let tuner = Algorithm::Random.build(&model.space(), 1);
        let mut s = TuningSession::new(
            tuner,
            sim_pool(model, 1, 0.0, Objective::Throughput, 1),
            Budget::default(),
        );
        let err = s.run().unwrap_err();
        assert!(err.to_string().contains("no stopping rule"), "{err}");
    }

    #[test]
    fn serial_session_matches_tune_shim() {
        // --parallel 1 must reproduce the plain serial loop bit for bit.
        let model = ModelId::Resnet50Int8;
        let space = model.space();
        for alg in Algorithm::all_paper() {
            let mut tuner = alg.build(&space, 21);
            let mut eval = SimEvaluator::new(model, 21);
            let expect = tune(tuner.as_mut(), &mut eval, 30).unwrap();

            let mut session = TuningSession::new(
                alg.build(&space, 21),
                sim_pool(model, 21, crate::sim::noise::DEFAULT_SIGMA, Objective::Throughput, 1),
                Budget::evaluations(30),
            );
            let got = session.run().unwrap();
            assert_eq!(session.stop_reason(), Some(StopReason::MaxEvaluations));
            assert_eq!(expect.values(), got.values(), "{} diverged", alg.name());
            assert_eq!(expect.best_curve(), got.best_curve());
        }
    }

    #[test]
    fn parallel_session_completes_budget_on_grid() {
        let model = ModelId::BertFp32;
        let space = model.space();
        let tuner = Algorithm::Bo.build(&space, 5);
        let mut session = TuningSession::new(
            tuner,
            sim_pool(model, 5, crate::sim::noise::DEFAULT_SIGMA, Objective::Throughput, 4),
            Budget::evaluations(24),
        );
        let h = session.run().unwrap();
        assert_eq!(h.len(), 24);
        assert_eq!(session.stop_reason(), Some(StopReason::MaxEvaluations));
        let mut ids: Vec<u64> = h.iter().map(|e| e.trial_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24, "every history row is a distinct trial");
        for e in h.iter() {
            assert!(space.contains(&e.config), "off-grid {:?}", e.config);
            assert!(e.value > 0.0);
        }
    }

    #[test]
    fn callback_streams_every_trial() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 2),
            sim_pool(model, 2, 0.0, Objective::Throughput, 2),
            Budget::evaluations(12),
        )
        .on_trial(move |_t, m| {
            assert!(m.value.is_finite());
            c2.fetch_add(1, Ordering::SeqCst);
        });
        let h = session.run().unwrap();
        assert_eq!(h.len(), 12);
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 12);
    }

    /// Evaluator whose objective is constant: plateau must fire.
    struct Flat;
    impl Evaluator for Flat {
        fn evaluate(&mut self, _c: &Config) -> Result<f64> {
            Ok(42.0)
        }
        fn describe(&self) -> String {
            "flat".into()
        }
    }

    #[test]
    fn plateau_stops_a_flat_run() {
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 3),
            vec![Box::new(Flat)],
            Budget::evaluations(500).with_plateau(6, 0.01),
        );
        let h = session.run().unwrap();
        assert_eq!(session.stop_reason(), Some(StopReason::Plateau));
        // 1 improving first sample + 6 stale ones
        assert_eq!(h.len(), 7, "plateau fired late: {} evals", h.len());
    }

    /// Evaluator that fails after a fixed number of calls.
    struct FailAfter(std::sync::atomic::AtomicUsize, usize);
    impl Evaluator for FailAfter {
        fn evaluate(&mut self, _c: &Config) -> Result<f64> {
            let n = self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            anyhow::ensure!(n < self.1, "injected pool failure");
            Ok(1.0)
        }
        fn describe(&self) -> String {
            "fail-after".into()
        }
    }

    #[test]
    fn parallel_worker_error_aborts_run() {
        let model = ModelId::NcfFp32;
        let evaluators: Vec<Box<dyn Evaluator + Send>> = vec![
            Box::new(FailAfter(Default::default(), 3)),
            Box::new(FailAfter(Default::default(), 3)),
        ];
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 4),
            evaluators,
            Budget::evaluations(100),
        );
        let err = session.run().unwrap_err();
        assert!(err.to_string().contains("injected pool failure"), "{err}");
    }

    #[test]
    fn parallel_worker_panic_aborts_instead_of_deadlocking() {
        struct Panicky(std::sync::atomic::AtomicUsize);
        impl Evaluator for Panicky {
            fn evaluate(&mut self, _c: &Config) -> Result<f64> {
                let n = self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if n >= 2 {
                    panic!("injected evaluator panic");
                }
                Ok(1.0)
            }
            fn describe(&self) -> String {
                "panicky".into()
            }
        }
        let model = ModelId::NcfFp32;
        let evaluators: Vec<Box<dyn Evaluator + Send>> =
            vec![Box::new(Panicky(Default::default())), Box::new(Panicky(Default::default()))];
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 12),
            evaluators,
            Budget::evaluations(50),
        );
        let err = session.run().unwrap_err();
        assert!(err.to_string().contains("evaluator panicked"), "{err}");
    }

    #[test]
    fn session_group_runs_independent_sessions() {
        let model = ModelId::NcfFp32;
        let mut group = SessionGroup::new();
        for seed in [1u64, 2, 3] {
            group.push(TuningSession::new(
                Algorithm::Random.build(&model.space(), seed),
                sim_pool(model, seed, 0.0, Objective::Throughput, 1),
                Budget::evaluations(6),
            ));
        }
        assert_eq!(group.len(), 3);
        let histories = group.run().unwrap();
        assert_eq!(histories.len(), 3);
        for h in &histories {
            assert_eq!(h.len(), 6);
        }
        assert_eq!(group.stop_reasons(), vec![Some(StopReason::MaxEvaluations); 3]);
    }

    #[test]
    fn session_group_shared_bo_conditions_one_factor() {
        // Three concurrent BO sessions over one search space: all their
        // measurements must land in the single shared surrogate.
        let model = ModelId::BertFp32;
        let space = model.space();
        let (shared, mut group) =
            SessionGroup::shared_bo(&space, &[10, 11, 12], Budget::evaluations(10), |i| {
                sim_pool(model, 100 + i as u64, 0.0, Objective::Throughput, 2)
            });
        let histories = group.run().unwrap();
        assert_eq!(histories.len(), 3);
        for h in &histories {
            assert_eq!(h.len(), 10);
            for e in h.iter() {
                assert!(space.contains(&e.config));
            }
        }
        // Every completed trial of every session conditions the factor.
        assert_eq!(shared.total_observations(), 30);
        let mut g = shared.lock();
        assert_eq!(g.len(), 30);
        let idx = g.conditioning_set();
        assert!(g.sync(&idx), "shared factor must be buildable after the run");
    }

    #[test]
    fn session_group_remote_shared_bo_conditions_one_served_factor() {
        use crate::server::proto::{encode_request, Request};
        use crate::server::TargetServer;
        use std::io::Write;

        let model = ModelId::NcfFp32;
        let space = model.space();
        let (server, factor) =
            TargetServer::bind_surrogate_only("127.0.0.1:0", GpHyper::default()).unwrap();
        let (addr, server_handle) = server.spawn().unwrap();

        let mut group = SessionGroup::remote_shared_bo(
            &space,
            &addr.to_string(),
            &[20, 21],
            Budget::evaluations(8),
            |i| sim_pool(model, 200 + i as u64, 0.0, Objective::Throughput, 2),
        )
        .unwrap();
        let histories = group.run().unwrap();
        assert_eq!(histories.len(), 2);
        for h in &histories {
            assert_eq!(h.len(), 8);
            for e in h.iter() {
                assert!(space.contains(&e.config));
            }
        }
        // Tells are fire-and-forget lines: poll until the served factor
        // has absorbed every completed trial of both sessions.
        let mut n = 0;
        for _ in 0..400 {
            n = factor.total_observations();
            if n >= 16 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(n, 16, "every trial of every process conditions the served factor");

        let mut s = std::net::TcpStream::connect(addr).unwrap();
        writeln!(s, "{}", encode_request(&Request::Shutdown, &space)).unwrap();
        drop(s);
        let _ = server_handle.join();
    }

    #[test]
    fn session_group_propagates_errors() {
        let model = ModelId::NcfFp32;
        let mut group = SessionGroup::new();
        group.push(TuningSession::new(
            Algorithm::Random.build(&model.space(), 5),
            sim_pool(model, 5, 0.0, Objective::Throughput, 1),
            Budget::evaluations(4),
        ));
        group.push(TuningSession::new(
            Algorithm::Random.build(&model.space(), 6),
            vec![Box::new(FailAfter(Default::default(), 1))],
            Budget::evaluations(4),
        ));
        let err = group.run().unwrap_err();
        assert!(err.to_string().contains("injected pool failure"), "{err}");
    }

    #[test]
    fn max_seconds_stops_before_the_cap() {
        struct Slow;
        impl Evaluator for Slow {
            fn evaluate(&mut self, _c: &Config) -> Result<f64> {
                std::thread::sleep(std::time::Duration::from_millis(5));
                Ok(1.0)
            }
            fn describe(&self) -> String {
                "slow".into()
            }
        }
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 6),
            vec![Box::new(Slow)],
            Budget::evaluations(100_000).with_max_seconds(0.15),
        );
        let h = session.run().unwrap();
        assert_eq!(session.stop_reason(), Some(StopReason::MaxSeconds));
        assert!(h.len() < 10_000, "ran far past the wall clock: {}", h.len());
    }
}
