//! [`TuningSession`]: the batched, budgeted tuning driver.
//!
//! A session owns one ask/tell engine, a pool of one or more
//! [`Evaluator`]s (threads over sim/real targets, or one TCP connection
//! per remote daemon), and a [`Budget`]. It keeps up to `pool-size` trials
//! in flight: the engine is asked for as many trials as there are idle
//! evaluators, results are told back in completion order (which under
//! parallelism is *not* issue order — the engines are built for that), and
//! every completed trial streams through the optional per-trial callback
//! before landing in the returned [`History`].
//!
//! With a single evaluator the session runs inline on the caller's thread
//! and is bit-for-bit identical to the serial `evaluator::tune()` loop —
//! that is the `--parallel 1` reproducibility guarantee the tests pin.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::algorithms::{Trial, Tuner};
use crate::evaluator::Evaluator;
use crate::history::{History, Measurement};

/// Plateau stop: end the run after `window` consecutive completed trials
/// without a relative improvement of at least `min_rel_gain` over the best
/// value seen so far.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plateau {
    pub window: usize,
    pub min_rel_gain: f64,
}

/// Stopping rules for a [`TuningSession`]. At least one rule must be set.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Budget {
    /// Stop after this many completed evaluations (the paper caps at 50).
    pub max_evaluations: Option<usize>,
    /// Stop once this much wall-clock time has elapsed (checked at trial
    /// completion granularity; in-flight trials run to completion).
    pub max_seconds: Option<f64>,
    /// Stop when the best-so-far curve plateaus.
    pub plateau: Option<Plateau>,
}

impl Budget {
    /// Budget with only an evaluation cap — the classic fixed-iteration run.
    pub fn evaluations(n: usize) -> Budget {
        Budget { max_evaluations: Some(n), ..Budget::default() }
    }

    pub fn with_max_seconds(mut self, seconds: f64) -> Budget {
        self.max_seconds = Some(seconds);
        self
    }

    pub fn with_plateau(mut self, window: usize, min_rel_gain: f64) -> Budget {
        self.plateau = Some(Plateau { window, min_rel_gain });
        self
    }

    /// Does any stopping rule exist? An unbounded session would never end.
    pub fn is_bounded(&self) -> bool {
        self.max_evaluations.is_some() || self.max_seconds.is_some() || self.plateau.is_some()
    }
}

/// Why a session ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The evaluation cap was reached.
    MaxEvaluations,
    /// The wall-clock limit elapsed.
    MaxSeconds,
    /// The best-so-far curve plateaued.
    Plateau,
    /// The engine issued no trials with none in flight (nothing left to try).
    EngineExhausted,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::MaxEvaluations => "max-evaluations",
            StopReason::MaxSeconds => "max-seconds",
            StopReason::Plateau => "plateau",
            StopReason::EngineExhausted => "engine-exhausted",
        }
    }
}

/// Best-so-far improvement tracking for the plateau rule.
struct PlateauTracker {
    rule: Option<Plateau>,
    best: f64,
    stale: usize,
}

impl PlateauTracker {
    fn new(rule: Option<Plateau>) -> PlateauTracker {
        PlateauTracker { rule, best: f64::NEG_INFINITY, stale: 0 }
    }

    fn record(&mut self, value: f64) {
        let Some(rule) = self.rule else { return };
        let bar = if self.best.is_finite() {
            self.best + self.best.abs() * rule.min_rel_gain
        } else {
            f64::NEG_INFINITY
        };
        if value > bar {
            self.best = self.best.max(value);
            self.stale = 0;
        } else {
            self.stale += 1;
        }
    }

    fn plateaued(&self) -> bool {
        self.rule.map_or(false, |r| self.stale >= r.window)
    }
}

/// Per-trial callback: invoked on the driving thread for every completed
/// trial, in completion order (streaming history out of a long run).
pub type TrialCallback = Box<dyn FnMut(&Trial, &Measurement)>;

/// The tuning driver: engine + evaluator pool + budget (module docs).
pub struct TuningSession {
    tuner: Box<dyn Tuner>,
    evaluators: Vec<Box<dyn Evaluator + Send>>,
    budget: Budget,
    on_trial: Option<TrialCallback>,
    stop_reason: Option<StopReason>,
}

impl TuningSession {
    pub fn new(
        tuner: Box<dyn Tuner>,
        evaluators: Vec<Box<dyn Evaluator + Send>>,
        budget: Budget,
    ) -> TuningSession {
        TuningSession { tuner, evaluators, budget, on_trial: None, stop_reason: None }
    }

    /// Stream every completed trial through `callback`.
    pub fn on_trial(mut self, callback: impl FnMut(&Trial, &Measurement) + 'static) -> Self {
        self.on_trial = Some(Box::new(callback));
        self
    }

    /// Why the last `run` ended (None before the first run).
    pub fn stop_reason(&self) -> Option<StopReason> {
        self.stop_reason
    }

    /// Evaluator pool size (the in-flight trial cap).
    pub fn parallelism(&self) -> usize {
        self.evaluators.len()
    }

    /// Drive the session to a stop and return the completed history.
    pub fn run(&mut self) -> Result<History> {
        anyhow::ensure!(!self.evaluators.is_empty(), "session needs at least one evaluator");
        anyhow::ensure!(
            self.budget.is_bounded(),
            "session budget has no stopping rule (set max evaluations, max seconds or plateau)"
        );
        self.stop_reason = None;
        let (history, reason) = if self.evaluators.len() == 1 {
            self.run_serial()?
        } else {
            self.run_parallel()?
        };
        self.stop_reason = Some(reason);
        Ok(history)
    }

    /// Which stop rule (if any) fires with `done` completed evaluations?
    fn stopped(
        budget: &Budget,
        done: usize,
        start: Instant,
        tracker: &PlateauTracker,
    ) -> Option<StopReason> {
        if budget.max_evaluations.map_or(false, |m| done >= m) {
            return Some(StopReason::MaxEvaluations);
        }
        if budget.max_seconds.map_or(false, |s| start.elapsed().as_secs_f64() >= s) {
            return Some(StopReason::MaxSeconds);
        }
        if tracker.plateaued() {
            return Some(StopReason::Plateau);
        }
        None
    }

    /// Single-evaluator fast path: inline, deterministic, identical to the
    /// serial `tune()` loop.
    fn run_serial(&mut self) -> Result<(History, StopReason)> {
        let evaluator = &mut self.evaluators[0];
        let mut history = History::new();
        let mut tracker = PlateauTracker::new(self.budget.plateau);
        let start = Instant::now();
        loop {
            if let Some(reason) = Self::stopped(&self.budget, history.len(), start, &tracker) {
                return Ok((history, reason));
            }
            let Some(trial) = self.tuner.ask(1).pop() else {
                return Ok((history, StopReason::EngineExhausted));
            };
            let m = evaluator.measure(&trial.config)?;
            anyhow::ensure!(
                m.value.is_finite(),
                "evaluator returned non-finite measurement {} for {:?}",
                m.value,
                trial.config
            );
            self.tuner.tell(trial.id, &m);
            tracker.record(m.value);
            history.push_trial(trial.id, trial.config.clone(), &m);
            if let Some(cb) = &mut self.on_trial {
                cb(&trial, &m);
            }
        }
    }

    /// Multi-evaluator path: one worker thread per evaluator, trials fanned
    /// out over a shared queue, results told back in completion order.
    fn run_parallel(&mut self) -> Result<(History, StopReason)> {
        let pool = self.evaluators.len();
        let budget = self.budget.clone();
        let tuner = &mut self.tuner;
        let on_trial = &mut self.on_trial;
        let evaluators = &mut self.evaluators;

        std::thread::scope(|scope| -> Result<(History, StopReason)> {
            let (work_tx, work_rx) = mpsc::channel::<Trial>();
            let work_rx = Arc::new(Mutex::new(work_rx));
            let (done_tx, done_rx) = mpsc::channel::<(Trial, Result<Measurement>)>();
            for evaluator in evaluators.iter_mut() {
                let work_rx = Arc::clone(&work_rx);
                let done_tx = done_tx.clone();
                scope.spawn(move || loop {
                    // Hold the lock only to pop one trial.
                    let next = { work_rx.lock().unwrap().recv() };
                    let Ok(trial) = next else { break };
                    // A panicking evaluator must surface as an Err, not kill
                    // the worker: a dead worker would strand its trial in
                    // in_flight and deadlock the driver on done_rx.recv().
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || evaluator.measure(&trial.config),
                    ))
                    .unwrap_or_else(|panic| {
                        let msg = panic
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "non-string panic".to_string());
                        Err(anyhow::anyhow!("evaluator panicked: {msg}"))
                    });
                    if done_tx.send((trial, result)).is_err() {
                        break;
                    }
                });
            }
            drop(done_tx);

            let mut history = History::new();
            let mut tracker = PlateauTracker::new(budget.plateau);
            let start = Instant::now();
            let mut in_flight = 0usize;
            let mut error: Option<anyhow::Error> = None;
            let reason = loop {
                if let Some(reason) = Self::stopped(&budget, history.len(), start, &tracker) {
                    break reason;
                }
                // Top the pool up, but never schedule past the eval cap.
                let room = pool - in_flight;
                let capped = budget
                    .max_evaluations
                    .map(|m| m.saturating_sub(history.len() + in_flight))
                    .unwrap_or(usize::MAX);
                let want = room.min(capped);
                if want > 0 {
                    for trial in tuner.ask(want) {
                        if work_tx.send(trial).is_ok() {
                            in_flight += 1;
                        }
                    }
                }
                if in_flight == 0 {
                    break StopReason::EngineExhausted;
                }
                let (trial, result) = done_rx.recv().expect("evaluator pool hung up");
                in_flight -= 1;
                let m = match result {
                    Ok(m) if m.value.is_finite() => m,
                    Ok(m) => {
                        error = Some(anyhow::anyhow!(
                            "evaluator returned non-finite measurement {} for {:?}",
                            m.value,
                            trial.config
                        ));
                        break StopReason::EngineExhausted;
                    }
                    Err(e) => {
                        error = Some(e);
                        break StopReason::EngineExhausted;
                    }
                };
                tuner.tell(trial.id, &m);
                tracker.record(m.value);
                history.push_trial(trial.id, trial.config.clone(), &m);
                if let Some(cb) = on_trial.as_mut() {
                    cb(&trial, &m);
                }
            };
            // Unblock the workers (in-flight trials finish and are dropped),
            // then let the scope join them.
            drop(work_tx);
            match error {
                Some(e) => Err(e),
                None => Ok((history, reason)),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;
    use crate::evaluator::{sim_pool, tune, Objective, SimEvaluator};
    use crate::sim::ModelId;
    use crate::space::Config;

    #[test]
    fn budget_builders_compose() {
        let b = Budget::evaluations(50).with_max_seconds(1.5).with_plateau(8, 0.01);
        assert_eq!(b.max_evaluations, Some(50));
        assert_eq!(b.max_seconds, Some(1.5));
        assert_eq!(b.plateau, Some(Plateau { window: 8, min_rel_gain: 0.01 }));
        assert!(b.is_bounded());
        assert!(!Budget::default().is_bounded());
    }

    #[test]
    fn unbounded_budget_is_rejected() {
        let model = ModelId::NcfFp32;
        let tuner = Algorithm::Random.build(&model.space(), 1);
        let mut s = TuningSession::new(
            tuner,
            sim_pool(model, 1, 0.0, Objective::Throughput, 1),
            Budget::default(),
        );
        let err = s.run().unwrap_err();
        assert!(err.to_string().contains("no stopping rule"), "{err}");
    }

    #[test]
    fn serial_session_matches_tune_shim() {
        // --parallel 1 must reproduce the plain serial loop bit for bit.
        let model = ModelId::Resnet50Int8;
        let space = model.space();
        for alg in Algorithm::all_paper() {
            let mut tuner = alg.build(&space, 21);
            let mut eval = SimEvaluator::new(model, 21);
            let expect = tune(tuner.as_mut(), &mut eval, 30).unwrap();

            let mut session = TuningSession::new(
                alg.build(&space, 21),
                sim_pool(model, 21, crate::sim::noise::DEFAULT_SIGMA, Objective::Throughput, 1),
                Budget::evaluations(30),
            );
            let got = session.run().unwrap();
            assert_eq!(session.stop_reason(), Some(StopReason::MaxEvaluations));
            assert_eq!(expect.values(), got.values(), "{} diverged", alg.name());
            assert_eq!(expect.best_curve(), got.best_curve());
        }
    }

    #[test]
    fn parallel_session_completes_budget_on_grid() {
        let model = ModelId::BertFp32;
        let space = model.space();
        let tuner = Algorithm::Bo.build(&space, 5);
        let mut session = TuningSession::new(
            tuner,
            sim_pool(model, 5, crate::sim::noise::DEFAULT_SIGMA, Objective::Throughput, 4),
            Budget::evaluations(24),
        );
        let h = session.run().unwrap();
        assert_eq!(h.len(), 24);
        assert_eq!(session.stop_reason(), Some(StopReason::MaxEvaluations));
        let mut ids: Vec<u64> = h.iter().map(|e| e.trial_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24, "every history row is a distinct trial");
        for e in h.iter() {
            assert!(space.contains(&e.config), "off-grid {:?}", e.config);
            assert!(e.value > 0.0);
        }
    }

    #[test]
    fn callback_streams_every_trial() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 2),
            sim_pool(model, 2, 0.0, Objective::Throughput, 2),
            Budget::evaluations(12),
        )
        .on_trial(move |_t, m| {
            assert!(m.value.is_finite());
            c2.fetch_add(1, Ordering::SeqCst);
        });
        let h = session.run().unwrap();
        assert_eq!(h.len(), 12);
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 12);
    }

    /// Evaluator whose objective is constant: plateau must fire.
    struct Flat;
    impl Evaluator for Flat {
        fn evaluate(&mut self, _c: &Config) -> Result<f64> {
            Ok(42.0)
        }
        fn describe(&self) -> String {
            "flat".into()
        }
    }

    #[test]
    fn plateau_stops_a_flat_run() {
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 3),
            vec![Box::new(Flat)],
            Budget::evaluations(500).with_plateau(6, 0.01),
        );
        let h = session.run().unwrap();
        assert_eq!(session.stop_reason(), Some(StopReason::Plateau));
        // 1 improving first sample + 6 stale ones
        assert_eq!(h.len(), 7, "plateau fired late: {} evals", h.len());
    }

    /// Evaluator that fails after a fixed number of calls.
    struct FailAfter(std::sync::atomic::AtomicUsize, usize);
    impl Evaluator for FailAfter {
        fn evaluate(&mut self, _c: &Config) -> Result<f64> {
            let n = self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            anyhow::ensure!(n < self.1, "injected pool failure");
            Ok(1.0)
        }
        fn describe(&self) -> String {
            "fail-after".into()
        }
    }

    #[test]
    fn parallel_worker_error_aborts_run() {
        let model = ModelId::NcfFp32;
        let evaluators: Vec<Box<dyn Evaluator + Send>> = vec![
            Box::new(FailAfter(Default::default(), 3)),
            Box::new(FailAfter(Default::default(), 3)),
        ];
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 4),
            evaluators,
            Budget::evaluations(100),
        );
        let err = session.run().unwrap_err();
        assert!(err.to_string().contains("injected pool failure"), "{err}");
    }

    #[test]
    fn parallel_worker_panic_aborts_instead_of_deadlocking() {
        struct Panicky(std::sync::atomic::AtomicUsize);
        impl Evaluator for Panicky {
            fn evaluate(&mut self, _c: &Config) -> Result<f64> {
                let n = self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if n >= 2 {
                    panic!("injected evaluator panic");
                }
                Ok(1.0)
            }
            fn describe(&self) -> String {
                "panicky".into()
            }
        }
        let model = ModelId::NcfFp32;
        let evaluators: Vec<Box<dyn Evaluator + Send>> =
            vec![Box::new(Panicky(Default::default())), Box::new(Panicky(Default::default()))];
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 12),
            evaluators,
            Budget::evaluations(50),
        );
        let err = session.run().unwrap_err();
        assert!(err.to_string().contains("evaluator panicked"), "{err}");
    }

    #[test]
    fn max_seconds_stops_before_the_cap() {
        struct Slow;
        impl Evaluator for Slow {
            fn evaluate(&mut self, _c: &Config) -> Result<f64> {
                std::thread::sleep(std::time::Duration::from_millis(5));
                Ok(1.0)
            }
            fn describe(&self) -> String {
                "slow".into()
            }
        }
        let model = ModelId::NcfFp32;
        let mut session = TuningSession::new(
            Algorithm::Random.build(&model.space(), 6),
            vec![Box::new(Slow)],
            Budget::evaluations(100_000).with_max_seconds(0.15),
        );
        let h = session.run().unwrap();
        assert_eq!(session.stop_reason(), Some(StopReason::MaxSeconds));
        assert!(h.len() < 10_000, "ran far past the wall clock: {}", h.len());
    }
}
