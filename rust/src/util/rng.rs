//! Deterministic, dependency-free PRNG.
//!
//! Every stochastic component in tftune (tuning algorithms, the simulator's
//! measurement noise, workload generators, the property-test harness) draws
//! from this seeded generator, so every figure and table in EXPERIMENTS.md
//! is exactly reproducible. The core is SplitMix64 (Steele et al., 2014) —
//! tiny, fast, passes BigCrush when used as a 64-bit stream, and more than
//! adequate for Monte-Carlo use.

/// Seeded 64-bit PRNG (SplitMix64 stream).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal deviate from Box-Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point and decorrelate tiny seeds.
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), cached_normal: None }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(s)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high-quality mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range_i64: lo {lo} > hi {hi}");
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform index in [0, n). Panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty range");
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Rejection-free polar-less form; u1 in (0,1].
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_i64_inclusive_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.range_i64(10, 14);
            assert!((10..=14).contains(&v));
            seen[(v - 10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(8);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    #[should_panic]
    fn empty_index_panics() {
        Rng::new(0).index(0);
    }
}
