//! Minimal JSON parser/serializer (no external crates — the image is
//! offline and serde is not vendored).
//!
//! Used for: the `artifacts/meta.json` shape contract, run-spec config
//! files, JSONL history persistence, and the host⇄target tuning protocol.
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Numbers are f64 (adequate: every number we
//! exchange is a small int or a throughput).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x.round() as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.field` convenience with an error message naming the key.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::new(format!("missing key '{key}'")))
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Parse / access error with byte offset where available.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl JsonError {
    fn new(msg: String) -> Self {
        JsonError { msg, offset: 0 }
    }
    fn at(msg: &str, offset: usize) -> Self {
        JsonError { msg: msg.to_string(), offset }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

/// Parse a JSON document. Trailing whitespace allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(JsonError::at("trailing characters", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::at("unexpected character", self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::at("invalid literal", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::at("expected a value", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(JsonError::at("expected ',' or '}'", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(JsonError::at("expected ',' or ']'", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::at("unterminated string", self.i)),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(JsonError::at("bad escape", self.i))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::at("bad \\u escape", self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::at("bad \\u escape", self.i))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::at("bad \\u escape", self.i))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(JsonError::at("unknown escape", self.i)),
                    }
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError::at("invalid utf-8", start))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::at("invalid number", start))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact single-line serialization (JSONL-friendly).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_into(self, &mut s);
        f.write_str(&s)
    }
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64().unwrap(), 2);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2.5,true,null,"s\""],"b":{"c":[]}}"#,
            r#"[]"#,
            r#"{"k":-0.125}"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integral_floats_serialize_as_ints() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn req_reports_missing_key() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("a").is_ok());
        assert!(v.req("zz").unwrap_err().to_string().contains("zz"));
    }

    #[test]
    fn deterministic_object_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
