//! Small dense linear algebra for the surrogate subsystem.
//!
//! Two tiers live here:
//!
//! 1. The original row-major [`Mat`] with allocating Cholesky and
//!    triangular solves — used by the exact oracle (`gp::native`), where
//!    clarity beats speed.
//! 2. A packed-lower kernel set with caller-provided storage — in-place
//!    packed Cholesky ([`chol_packed`]), O(n²) factor *append*
//!    ([`chol_append_packed`]), in-place triangular solves and a
//!    multi-RHS forward solve ([`trsm_lower_packed`]). These back the
//!    incremental GP (`gp::incremental`) and are written so the BO
//!    scoring loop performs zero heap allocation. Two further kernels
//!    round out the set ahead of their callers: the classic rank-1
//!    *update* ([`chol_rank1_update_packed`], for covariance bumps that
//!    cannot be expressed as appends) and a gemm-style block multiply
//!    ([`gemm_nt`], for panel builds that do not need the oracle's exact
//!    operation order).
//!
//! The multi-RHS trsm and the gemm are *cache-blocked*
//! ([`trsm_lower_packed_blocked`] / [`gemm_nt_blocked`]): a tunable
//! [`BlockSpec`] `{mc, nc, kc}` tiles the row/column/depth loops so the
//! active panel block stays cache-resident at n=512-scale scoring
//! problems, while [`BlockSpec::naive`] degenerates the same code into
//! the historical unblocked loops. An f32 twin of the trsm
//! ([`trsm_lower_packed_blocked_f32`]) backs the optional fast scoring
//! tier (`gp::ScoreTier::F32`).
//!
//! Lower-triangular factors are stored row-major *packed*: entry `(i, j)`
//! with `j <= i` lives at [`packed_idx`]`(i, j)`; appending a row appends
//! `i + 1` contiguous values, which is what makes the rank-1 append cheap.
//!
//! Bit-compatibility note: the packed routines perform the same
//! floating-point operations in the same order as their `Mat`
//! counterparts (ascending-index accumulation), so an incrementally
//! maintained factor is *bitwise* equal to a from-scratch `cholesky` of
//! the same matrix. Tests and the BO trajectory-equivalence suite rely on
//! this; preserve the accumulation order when touching these loops.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization A = L Lᵀ for SPD A; returns lower-triangular L.
/// Fails (None) if a pivot is non-positive (A not positive definite).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L y = b with L lower-triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve Lᵀ x = y with L lower-triangular (back substitution).
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared euclidean distance.
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// f32 squared euclidean distance — the f32 scoring tier's panel loop
/// (`gp::ScoreTier::F32`); same ascending accumulation as [`sqdist`].
pub fn sqdist_f32(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

// ---------------------------------------------------------------------------
// Packed-lower kernel set (zero-allocation tier).
// ---------------------------------------------------------------------------

/// Index of entry `(i, j)`, `j <= i`, in row-major packed-lower storage.
#[inline]
pub fn packed_idx(i: usize, j: usize) -> usize {
    debug_assert!(j <= i);
    i * (i + 1) / 2 + j
}

/// Number of stored entries of an n×n packed-lower factor.
#[inline]
pub fn packed_len(n: usize) -> usize {
    n * (n + 1) / 2
}

/// In-place packed-lower Cholesky: `a` holds the lower triangle of an SPD
/// matrix (row-major packed, [`packed_len`]`(n)` entries); on success it
/// holds L with A = L Lᵀ. Returns false (contents unspecified) if a pivot
/// is non-positive. Same operation order as [`cholesky`].
pub fn chol_packed(a: &mut [f64], n: usize) -> bool {
    assert_eq!(a.len(), packed_len(n), "packed length mismatch");
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[packed_idx(i, j)];
            for t in 0..j {
                s -= a[packed_idx(i, t)] * a[packed_idx(j, t)];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                a[packed_idx(i, j)] = s.sqrt();
            } else {
                a[packed_idx(i, j)] = s / a[packed_idx(j, j)];
            }
        }
    }
    true
}

/// Append one row to a packed-lower Cholesky factor in O(n²): given the
/// factor L of the n×n matrix K, the covariance vector `k = K[n][..n]` of
/// a new point against the old ones, and its diagonal `d = K[n][n]`,
/// extend `l` in place to the factor of the (n+1)×(n+1) matrix.
///
/// `k` is consumed as workspace (it ends up holding the new row of L).
/// No allocation happens when `l` has spare capacity. Returns false and
/// leaves `l` untouched if the extended matrix is not positive definite.
///
/// The new row is exactly the forward-substitution `w = L⁻¹k` plus pivot
/// `√(d − wᵀw)` — the same operations, in the same order, that a
/// from-scratch [`chol_packed`] of the extended matrix would perform, so
/// repeated appends reproduce the batch factor bit-for-bit.
pub fn chol_append_packed(l: &mut Vec<f64>, n: usize, k: &mut [f64], d: f64) -> bool {
    assert_eq!(l.len(), packed_len(n), "packed length mismatch");
    assert_eq!(k.len(), n, "new-row covariance length mismatch");
    for i in 0..n {
        let mut s = k[i];
        for t in 0..i {
            s -= l[packed_idx(i, t)] * k[t];
        }
        k[i] = s / l[packed_idx(i, i)];
    }
    let mut piv = d;
    for w in k.iter() {
        piv -= w * w;
    }
    if piv <= 0.0 || !piv.is_finite() {
        return false;
    }
    l.extend_from_slice(k);
    l.push(piv.sqrt());
    true
}

/// Rank-1 *update* of a packed-lower Cholesky factor: L ← chol(L Lᵀ + v vᵀ)
/// in O(n²) via hyperbolic-rotation-free Givens sweeps. `v` is consumed as
/// workspace. (The incremental GP appends rows instead — see
/// [`chol_append_packed`] — but covariance bumps such as trust-region
/// reweighting need the classic update form.)
pub fn chol_rank1_update_packed(l: &mut [f64], n: usize, v: &mut [f64]) {
    assert_eq!(l.len(), packed_len(n), "packed length mismatch");
    assert_eq!(v.len(), n, "update vector length mismatch");
    for i in 0..n {
        let di = packed_idx(i, i);
        let lii = l[di];
        let r = (lii * lii + v[i] * v[i]).sqrt();
        let c = r / lii;
        let s = v[i] / lii;
        l[di] = r;
        for k in i + 1..n {
            let ki = packed_idx(k, i);
            l[ki] = (l[ki] + s * v[k]) / c;
            v[k] = c * v[k] - s * l[ki];
        }
    }
}

/// In-place forward substitution on packed L: overwrite `x` with L⁻¹x.
pub fn solve_lower_packed_inplace(l: &[f64], n: usize, x: &mut [f64]) {
    assert_eq!(l.len(), packed_len(n), "packed length mismatch");
    assert_eq!(x.len(), n, "rhs length mismatch");
    for i in 0..n {
        let mut s = x[i];
        for t in 0..i {
            s -= l[packed_idx(i, t)] * x[t];
        }
        x[i] = s / l[packed_idx(i, i)];
    }
}

/// In-place back substitution on packed L: overwrite `x` with L⁻ᵀx.
pub fn solve_lower_t_packed_inplace(l: &[f64], n: usize, x: &mut [f64]) {
    assert_eq!(l.len(), packed_len(n), "packed length mismatch");
    assert_eq!(x.len(), n, "rhs length mismatch");
    for i in (0..n).rev() {
        let mut s = x[i];
        for t in i + 1..n {
            s -= l[packed_idx(t, i)] * x[t];
        }
        x[i] = s / l[packed_idx(i, i)];
    }
}

/// Cache-blocking geometry for the packed trsm / gemm kernels.
///
/// `mc` rows × `nc` columns of the panel form the active output block and
/// `kc` bounds each ascending-index accumulation run, so the working set
/// stays L1/L2-resident at n=512-scale scoring problems. The fields are
/// deliberately plain `usize`s: `examples/self_tune_scoring.rs` searches
/// this space with the repo's own BO engine against scoring-bench
/// timings — the paper's tuning loop closed on ourselves.
///
/// Blocking never changes results: every output element receives exactly
/// the same floating-point operations in the same (ascending) order for
/// **any** `BlockSpec`, so a blocked kernel is bitwise equal to the
/// [`BlockSpec::naive`] degenerate loops. Unit tests and
/// `rust/tests/scoring_engine.rs` pin this at awkward
/// (non-multiple-of-block) shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockSpec {
    /// Row-block height: output rows solved/accumulated per block.
    pub mc: usize,
    /// Column-block width: right-hand sides (candidates) per block.
    pub nc: usize,
    /// Depth tile: factor columns folded in per sweep.
    pub kc: usize,
}

impl Default for BlockSpec {
    /// Starting point picked with `examples/self_tune_scoring.rs` on the
    /// n=512 scoring problem: a 32×64 f64 output block is 16 KB
    /// (L1-resident) and kc=128 keeps each streamed factor tile under the
    /// panel block's footprint.
    fn default() -> BlockSpec {
        BlockSpec { mc: 32, nc: 64, kc: 128 }
    }
}

impl BlockSpec {
    /// Degenerate blocks spanning the whole problem: the blocked kernels
    /// execute exactly the historical unblocked loops. This is the
    /// reference the parity tests and the committed bench baseline
    /// (`score_512_naive_n512` in BENCH_gp.json) run against.
    pub fn naive() -> BlockSpec {
        BlockSpec { mc: usize::MAX, nc: usize::MAX, kc: usize::MAX }
    }
}

macro_rules! trsm_lower_packed_blocked_impl {
    ($(#[$doc:meta])* $name:ident, $t:ty) => {
        $(#[$doc])*
        pub fn $name(l: &[$t], n: usize, b: &mut [$t], c: usize, spec: BlockSpec) {
            assert_eq!(l.len(), packed_len(n), "packed length mismatch");
            assert_eq!(b.len(), n * c, "panel shape mismatch");
            let mc = spec.mc.max(1);
            let nc = spec.nc.max(1);
            let kc = spec.kc.max(1);
            let mut j0 = 0;
            while j0 < c {
                let j1 = j0.saturating_add(nc).min(c);
                let mut i0 = 0;
                while i0 < n {
                    let i1 = i0.saturating_add(mc).min(n);
                    // Rectangular update: fold the already-solved rows
                    // [0, i0) into block rows [i0, i1), kc factor columns
                    // at a time. Every b[i][j] receives its
                    // `-= l[i][t]·b[t][j]` terms one at a time in
                    // ascending t — the unblocked per-column order — so
                    // the result is bitwise independent of the tiling.
                    let mut t0 = 0;
                    while t0 < i0 {
                        let t1 = t0.saturating_add(kc).min(i0);
                        for i in i0..i1 {
                            let (head, tail) = b.split_at_mut(i * c);
                            let bi = &mut tail[j0..j1];
                            for t in t0..t1 {
                                let a = l[packed_idx(i, t)];
                                let bt = &head[t * c + j0..t * c + j1];
                                for (x, y) in bi.iter_mut().zip(bt) {
                                    *x -= a * y;
                                }
                            }
                        }
                        t0 = t1;
                    }
                    // Triangular solve within the diagonal block.
                    for i in i0..i1 {
                        let (head, tail) = b.split_at_mut(i * c);
                        let bi = &mut tail[j0..j1];
                        for t in i0..i {
                            let a = l[packed_idx(i, t)];
                            let bt = &head[t * c + j0..t * c + j1];
                            for (x, y) in bi.iter_mut().zip(bt) {
                                *x -= a * y;
                            }
                        }
                        let inv = l[packed_idx(i, i)];
                        for x in bi.iter_mut() {
                            *x /= inv;
                        }
                    }
                    i0 = i1;
                }
                j0 = j1;
            }
        }
    };
}

trsm_lower_packed_blocked_impl!(
    /// Cache-blocked multi-RHS forward substitution (trsm): overwrite the
    /// n×c row-major panel `b` with L⁻¹B, tiled per `spec` so the active
    /// output block stays cache-resident (this is how 512 candidates are
    /// scored in one pass instead of 512 independent [`solve_lower`]
    /// calls). Per column, the operation order matches [`solve_lower`]
    /// exactly for **any** `spec` — blocking reorders which (row, column)
    /// pair is touched when, never the ascending-index op sequence a
    /// single entry sees — so the output is bitwise spec-independent.
    trsm_lower_packed_blocked,
    f64
);

trsm_lower_packed_blocked_impl!(
    /// f32 twin of [`trsm_lower_packed_blocked`], backing the optional
    /// f32 scoring tier (`gp::ScoreTier::F32`). Same blocking, same
    /// per-column ascending op order; only the arithmetic width differs.
    trsm_lower_packed_blocked_f32,
    f32
);

/// [`trsm_lower_packed_blocked`] at the default [`BlockSpec`] — the
/// historical entry point every existing caller goes through.
pub fn trsm_lower_packed(l: &[f64], n: usize, b: &mut [f64], c: usize) {
    trsm_lower_packed_blocked(l, n, b, c, BlockSpec::default());
}

/// Cache-blocked gemm-style multiply into a caller-provided buffer:
/// `out (m×n) = A · Bᵀ` with A m×k and B n×k, all row-major — i.e.
/// `out[i][j] = aᵢ · bⱼ`. Tiled per `spec` over rows, columns and depth;
/// no allocation. Depth tiling resumes each dot product from its stored
/// partial sum (loads/stores are exact), so every entry is the same
/// ascending-k accumulation [`dot`] performs — bitwise spec-independent.
pub fn gemm_nt_blocked(
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    k: usize,
    out: &mut [f64],
    spec: BlockSpec,
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), n * k, "B shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    let mc = spec.mc.max(1);
    let nc = spec.nc.max(1);
    let kc = spec.kc.max(1);
    let mut j0 = 0;
    while j0 < n {
        let j1 = j0.saturating_add(nc).min(n);
        let mut i0 = 0;
        while i0 < m {
            let i1 = i0.saturating_add(mc).min(m);
            let mut k0 = 0;
            loop {
                let k1 = k0.saturating_add(kc).min(k);
                for i in i0..i1 {
                    let ar = &a[i * k + k0..i * k + k1];
                    let or = &mut out[i * n + j0..i * n + j1];
                    for (j, oj) in or.iter_mut().enumerate() {
                        let br = &b[(j0 + j) * k + k0..(j0 + j) * k + k1];
                        let mut acc = if k0 == 0 { 0.0 } else { *oj };
                        for (x, y) in ar.iter().zip(br) {
                            acc += x * y;
                        }
                        *oj = acc;
                    }
                }
                k0 = k1;
                if k0 >= k {
                    break;
                }
            }
            i0 = i1;
        }
        j0 = j1;
    }
}

/// [`gemm_nt_blocked`] at the default [`BlockSpec`] — the historical
/// entry point; every `out[i][j]` is bitwise an ascending-k [`dot`].
pub fn gemm_nt(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, out: &mut [f64]) {
    gemm_nt_blocked(a, m, b, n, k, out, BlockSpec::default());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matmul(&Mat::eye(2)), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        // SPD matrix.
        let a = Mat::from_rows(&[
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.5],
            vec![0.6, 1.5, 3.0],
        ]);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..3 {
            for j in 0..3 {
                assert!((llt[(i, j)] - a[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigs 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_solve_matches_known_solution() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = solve_spd(&a, &[1.0, 2.0]).unwrap();
        // verify A x = b
        let b2 = a.matvec(&x);
        assert!((b2[0] - 1.0).abs() < 1e-12 && (b2[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn triangular_solves_invert_each_other() {
        let l = Mat::from_rows(&[vec![2.0, 0.0], vec![1.0, 3.0]]);
        let b = [4.0, 10.0];
        let y = solve_lower(&l, &b);
        assert!((l.matvec(&y)[0] - 4.0).abs() < 1e-12);
        let x = solve_lower_t(&l, &b);
        let lt = l.transpose();
        assert!((lt.matvec(&x)[1] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sqdist_and_dot() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    // -- packed tier ---------------------------------------------------------

    /// Random SPD matrix A = G Gᵀ + n·I as both Mat and packed-lower.
    fn random_spd(rng: &mut crate::util::Rng, n: usize) -> (Mat, Vec<f64>) {
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                g[(i, j)] = rng.normal();
            }
        }
        let mut a = g.matmul(&g.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        let mut packed = Vec::with_capacity(packed_len(n));
        for i in 0..n {
            for j in 0..=i {
                packed.push(a[(i, j)]);
            }
        }
        (a, packed)
    }

    #[test]
    fn packed_chol_bitwise_matches_mat_chol() {
        let mut rng = crate::util::Rng::new(11);
        for n in [1usize, 2, 5, 17] {
            let (a, mut packed) = random_spd(&mut rng, n);
            let l = cholesky(&a).unwrap();
            assert!(chol_packed(&mut packed, n));
            for i in 0..n {
                for j in 0..=i {
                    assert_eq!(
                        packed[packed_idx(i, j)].to_bits(),
                        l[(i, j)].to_bits(),
                        "entry ({i},{j}) differs at n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_append_bitwise_matches_batch() {
        let mut rng = crate::util::Rng::new(12);
        let n = 12;
        let (a, mut full) = random_spd(&mut rng, n);
        assert!(chol_packed(&mut full, n));
        // Rebuild the same factor by appending one row at a time.
        let mut inc: Vec<f64> = Vec::new();
        for i in 0..n {
            let mut k: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            assert!(chol_append_packed(&mut inc, i, &mut k, a[(i, i)]));
        }
        assert_eq!(inc.len(), full.len());
        for (x, y) in inc.iter().zip(&full) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn packed_append_rejects_non_pd() {
        // Appending a duplicate of an existing noiseless row must fail.
        let mut l: Vec<f64> = Vec::new();
        let mut empty: [f64; 0] = [];
        assert!(chol_append_packed(&mut l, 0, &mut empty, 1.0));
        let before = l.clone();
        let mut k = [1.0];
        assert!(!chol_append_packed(&mut l, 1, &mut k, 1.0));
        assert_eq!(l, before, "failed append must leave the factor untouched");
    }

    #[test]
    fn rank1_update_reconstructs() {
        let mut rng = crate::util::Rng::new(13);
        let n = 8;
        let (a, mut l) = random_spd(&mut rng, n);
        assert!(chol_packed(&mut l, n));
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut w = v.clone();
        chol_rank1_update_packed(&mut l, n, &mut w);
        // L Lᵀ must now equal A + v vᵀ.
        for i in 0..n {
            for j in 0..=i {
                let mut s = 0.0;
                for t in 0..=j {
                    s += l[packed_idx(i, t)] * l[packed_idx(j, t)];
                }
                let want = a[(i, j)] + v[i] * v[j];
                assert!((s - want).abs() < 1e-9, "({i},{j}): {s} vs {want}");
            }
        }
    }

    #[test]
    fn packed_solves_match_mat_solves() {
        let mut rng = crate::util::Rng::new(14);
        let n = 9;
        let (a, mut packed) = random_spd(&mut rng, n);
        let l = cholesky(&a).unwrap();
        assert!(chol_packed(&mut packed, n));
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        let want_fwd = solve_lower(&l, &b);
        let mut got = b.clone();
        solve_lower_packed_inplace(&packed, n, &mut got);
        for (x, y) in got.iter().zip(&want_fwd) {
            assert_eq!(x.to_bits(), y.to_bits());
        }

        let want_bwd = solve_lower_t(&l, &b);
        let mut got = b.clone();
        solve_lower_t_packed_inplace(&packed, n, &mut got);
        for (x, y) in got.iter().zip(&want_bwd) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn trsm_matches_per_column_solves_bitwise() {
        let mut rng = crate::util::Rng::new(15);
        let n = 7;
        let c = 5;
        let (a, mut packed) = random_spd(&mut rng, n);
        let l = cholesky(&a).unwrap();
        assert!(chol_packed(&mut packed, n));
        let mut panel: Vec<f64> = (0..n * c).map(|_| rng.normal()).collect();
        // Reference: solve each column independently through Mat solves.
        let mut want = vec![0.0; n * c];
        for j in 0..c {
            let col: Vec<f64> = (0..n).map(|i| panel[i * c + j]).collect();
            for (i, v) in solve_lower(&l, &col).into_iter().enumerate() {
                want[i * c + j] = v;
            }
        }
        trsm_lower_packed(&packed, n, &mut panel, c);
        for (x, y) in panel.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn gemm_nt_matches_matmul() {
        let mut rng = crate::util::Rng::new(16);
        let (m, n, k) = (6, 70, 4); // n > TILE would need a bigger case; 70 crosses one tile
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; m * n];
        gemm_nt(&a, m, &b, n, k, &mut out);
        for i in 0..m {
            for j in 0..n {
                let want = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                assert_eq!(out[i * n + j].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn blocked_trsm_bitwise_spec_independent_awkward_shapes() {
        // Awkward (non-multiple-of-block) shapes across several specs:
        // blocked output must equal the naive degenerate loop bit for bit.
        let mut rng = crate::util::Rng::new(17);
        for (n, c) in [(1usize, 1usize), (7, 3), (23, 17), (67, 33)] {
            let (_, mut packed) = random_spd(&mut rng, n);
            assert!(chol_packed(&mut packed, n));
            let panel: Vec<f64> = (0..n * c).map(|_| rng.normal()).collect();
            let mut want = panel.clone();
            trsm_lower_packed_blocked(&packed, n, &mut want, c, BlockSpec::naive());
            for spec in [
                BlockSpec { mc: 1, nc: 1, kc: 1 },
                BlockSpec { mc: 5, nc: 7, kc: 3 },
                BlockSpec { mc: 16, nc: 8, kc: 64 },
                BlockSpec::default(),
            ] {
                let mut got = panel.clone();
                trsm_lower_packed_blocked(&packed, n, &mut got, c, spec);
                for (x, y) in got.iter().zip(&want) {
                    assert_eq!(x.to_bits(), y.to_bits(), "spec {spec:?} at n={n} c={c}");
                }
            }
        }
    }

    #[test]
    fn blocked_trsm_f32_bitwise_spec_independent() {
        let mut rng = crate::util::Rng::new(18);
        let (n, c) = (29usize, 13usize);
        let (_, mut packed) = random_spd(&mut rng, n);
        assert!(chol_packed(&mut packed, n));
        let l32: Vec<f32> = packed.iter().map(|&v| v as f32).collect();
        let panel: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
        let mut want = panel.clone();
        trsm_lower_packed_blocked_f32(&l32, n, &mut want, c, BlockSpec::naive());
        for spec in [BlockSpec { mc: 4, nc: 5, kc: 6 }, BlockSpec::default()] {
            let mut got = panel.clone();
            trsm_lower_packed_blocked_f32(&l32, n, &mut got, c, spec);
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.to_bits(), y.to_bits(), "f32 spec {spec:?}");
            }
        }
    }

    #[test]
    fn blocked_gemm_bitwise_matches_dot_awkward_shapes() {
        let mut rng = crate::util::Rng::new(19);
        for (m, n, k) in [(1usize, 1usize, 1usize), (13, 29, 17), (6, 70, 4), (3, 5, 0)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            for spec in [
                BlockSpec { mc: 4, nc: 6, kc: 5 },
                BlockSpec { mc: 1, nc: 1, kc: 1 },
                BlockSpec::naive(),
                BlockSpec::default(),
            ] {
                let mut out = vec![f64::NAN; m * n];
                gemm_nt_blocked(&a, m, &b, n, k, &mut out, spec);
                for i in 0..m {
                    for j in 0..n {
                        let want = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                        assert_eq!(
                            out[i * n + j].to_bits(),
                            want.to_bits(),
                            "spec {spec:?} at ({m},{n},{k})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_idx_layout() {
        assert_eq!(packed_idx(0, 0), 0);
        assert_eq!(packed_idx(1, 0), 1);
        assert_eq!(packed_idx(1, 1), 2);
        assert_eq!(packed_idx(3, 2), 8);
        assert_eq!(packed_len(4), 10);
    }
}
