//! Small dense linear algebra for the native Gaussian process
//! (`gp::native`) — the correctness oracle for the AOT HLO artifact and
//! the small-history fallback path. Row-major `Mat` with Cholesky and
//! triangular solves; n stays ≤ a few hundred here, so simple loops are
//! fine (the hot path runs in XLA, not here).

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product self * other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization A = L Lᵀ for SPD A; returns lower-triangular L.
/// Fails (None) if a pivot is non-positive (A not positive definite).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L y = b with L lower-triangular (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve Lᵀ x = y with L lower-triangular (back substitution).
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared euclidean distance.
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matmul(&Mat::eye(2)), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        // SPD matrix.
        let a = Mat::from_rows(&[
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.5],
            vec![0.6, 1.5, 3.0],
        ]);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..3 {
            for j in 0..3 {
                assert!((llt[(i, j)] - a[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigs 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_solve_matches_known_solution() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = solve_spd(&a, &[1.0, 2.0]).unwrap();
        // verify A x = b
        let b2 = a.matvec(&x);
        assert!((b2[0] - 1.0).abs() < 1e-12 && (b2[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn triangular_solves_invert_each_other() {
        let l = Mat::from_rows(&[vec![2.0, 0.0], vec![1.0, 3.0]]);
        let b = [4.0, 10.0];
        let y = solve_lower(&l, &b);
        assert!((l.matvec(&y)[0] - 4.0).abs() < 1e-12);
        let x = solve_lower_t(&l, &b);
        let lt = l.transpose();
        assert!((lt.matvec(&x)[1] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sqdist_and_dot() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }
}
