//! Mini property-based testing harness (proptest is not vendored in this
//! offline image, so we provide the same workflow in-tree).
//!
//! `check(name, cases, |rng| ...)` runs a property closure against many
//! seeded random cases. On failure it re-runs a *shrinking* pass: the
//! failing seed is reported so the case reproduces exactly, and numeric
//! helpers bias toward boundary values (min/max/0/1) the way proptest's
//! generators do, which is where most bugs live.

use super::rng::Rng;

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Integer in [lo, hi] biased toward the boundaries (25% of draws).
pub fn int_biased(rng: &mut Rng, lo: i64, hi: i64) -> i64 {
    if rng.bool(0.25) {
        *rng.choice(&[lo, hi, lo, hi, (lo + hi) / 2])
    } else {
        rng.range_i64(lo, hi)
    }
}

/// Float in [lo, hi] biased toward boundaries and zero.
pub fn f64_biased(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    if rng.bool(0.2) {
        let picks = [lo, hi, 0.0f64.clamp(lo, hi), (lo + hi) * 0.5];
        *rng.choice(&picks)
    } else {
        rng.range_f64(lo, hi)
    }
}

/// A random vector of floats in [lo, hi].
pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| f64_biased(rng, lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn biased_ints_hit_boundaries() {
        let mut rng = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..500 {
            match int_biased(&mut rng, 3, 9) {
                3 => lo_seen = true,
                9 => hi_seen = true,
                v => assert!((3..=9).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
