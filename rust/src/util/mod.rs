//! Dependency-free infrastructure: PRNG, JSON, statistics, dense linear
//! algebra, and the in-tree bench/property-test harnesses.
//!
//! This image builds fully offline with only the `xla` crate's closure
//! vendored, so the usual ecosystem crates (serde, rand, criterion,
//! proptest) are replaced by these focused implementations.

pub mod bench;
pub mod json;
pub mod linalg;
pub mod prop;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
