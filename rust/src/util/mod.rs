//! Dependency-free infrastructure: PRNG, JSON, statistics, dense linear
//! algebra, and the in-tree bench/property-test harnesses.
//!
//! This image builds fully offline with only the `xla` crate's closure
//! vendored, so the usual ecosystem crates (serde, rand, criterion,
//! proptest) are replaced by these focused implementations.

pub mod bench;
pub mod json;
pub mod linalg;
pub mod prop;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;

/// FNV-1a 64-bit — cheap, dependency-free stable hash. Used both as the
/// snapshot corruption check and as the search-space fingerprint carried
/// in the protocol-v4 `hello` (this guards against torn writes and
/// misconfigured tuners, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x1_0000_01b3);
    }
    hash
}
