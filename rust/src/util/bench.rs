//! Mini benchmark harness (criterion is not vendored in this offline
//! image). Provides warmup, adaptive iteration counts, and robust summary
//! statistics; used by every `benches/*.rs` target (all declared with
//! `harness = false`).

use std::time::{Duration, Instant};

use super::stats;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with criterion-like behaviour: warm up, pick an
/// iteration count that fits the measurement budget, take batched samples.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_samples: 60,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup_ms: u64, measure_ms: u64) -> Self {
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Measure `f`, printing a one-line summary. The closure should return
    /// something cheap (e.g. a checksum) to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup and per-call cost estimate.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls == 0 {
            std::hint::black_box(f());
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_nanos() as f64 / calls as f64;

        // Choose batch size so each sample takes ~measure/max_samples.
        let sample_budget_ns = self.measure.as_nanos() as f64 / self.max_samples as f64;
        let batch = ((sample_budget_ns / per_call.max(1.0)).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.max_samples);
        let run_start = Instant::now();
        while samples.len() < self.max_samples && run_start.elapsed() < self.measure {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            iters: batch * samples.len() as u64,
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            stddev_ns: stats::stddev(&samples),
            p95_ns: stats::quantile(&samples, 0.95),
        };
        println!(
            "bench {:<44} mean {:>10}  median {:>10}  p95 {:>10}  (n={})",
            result.name,
            fmt_ns(result.mean_ns),
            fmt_ns(result.median_ns),
            fmt_ns(result.p95_ns),
            result.iters,
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(5, 30);
        let r = b.bench("noop-ish", || std::hint::black_box(1 + 1));
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn ordering_of_costs() {
        // black_box each element so the sums cannot fold to closed forms.
        let mut b = Bencher::new(5, 40);
        let cheap = b.bench("cheap", || (0..8u64).map(std::hint::black_box).sum::<u64>());
        let costly =
            b.bench("costly", || (0..20_000u64).map(std::hint::black_box).sum::<u64>());
        assert!(costly.mean_ns > cheap.mean_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
