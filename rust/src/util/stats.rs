//! Small statistics helpers shared by the bench harness and figures.

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 if n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-quantile via linear interpolation on the sorted copy, p in [0,1].
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Max with a NaN-safe total order (NaN sorts lowest).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// argmax index; panics on empty input.
pub fn argmax(xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmax of empty slice");
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Running best-so-far transform (the Fig. 5 "tuning curve" view).
pub fn best_so_far(xs: &[f64]) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    xs.iter()
        .map(|&x| {
            best = best.max(x);
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn best_so_far_monotone() {
        let b = best_so_far(&[3.0, 1.0, 4.0, 1.0, 5.0]);
        assert_eq!(b, vec![3.0, 3.0, 4.0, 4.0, 5.0]);
    }

    #[test]
    fn empty_mean_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
