//! Parameter search space (paper §3, Table 1).
//!
//! Each tunable is an integer range `[min, max]` with a step size; the
//! space is their Cartesian product. The tuning algorithms all work on the
//! continuous unit cube `[0,1]^d` and snap to the grid at evaluation time
//! (exactly what the paper's framework does when it "converts and applies
//! the chosen parameters"), so this module owns every encode/decode:
//!
//!   grid value  <->  value index  <->  unit-cube coordinate
//!
//! plus grid iteration (for the Fig. 6 exhaustive sweep), Latin-hypercube
//! initialisation, and neighbourhood moves.

use crate::util::{Json, Rng};

/// One tunable parameter: an inclusive integer range with a step.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub min: i64,
    pub max: i64,
    pub step: i64,
}

impl ParamDef {
    pub fn new(name: &str, min: i64, max: i64, step: i64) -> ParamDef {
        assert!(step > 0, "param {name}: step must be positive");
        assert!(min <= max, "param {name}: min {min} > max {max}");
        ParamDef { name: name.to_string(), min, max, step }
    }

    /// Number of grid points.
    pub fn n_values(&self) -> usize {
        ((self.max - self.min) / self.step) as usize + 1
    }

    /// Grid value at index `i` (clamped to the last point).
    pub fn value_at(&self, i: usize) -> i64 {
        let i = i.min(self.n_values() - 1);
        self.min + self.step * i as i64
    }

    /// Snap an arbitrary integer to the nearest grid point.
    pub fn snap(&self, v: i64) -> i64 {
        let v = v.clamp(self.min, self.max);
        let k = ((v - self.min) as f64 / self.step as f64).round() as i64;
        (self.min + k * self.step).clamp(self.min, self.max)
    }

    /// Map a grid value to [0, 1] (0-size ranges map to 0.5).
    pub fn to_unit(&self, v: i64) -> f64 {
        if self.max == self.min {
            return 0.5;
        }
        (self.snap(v) - self.min) as f64 / (self.max - self.min) as f64
    }

    /// Map a unit-cube coordinate back to the nearest grid value.
    pub fn from_unit(&self, u: f64) -> i64 {
        let u = u.clamp(0.0, 1.0);
        let raw = self.min as f64 + u * (self.max - self.min) as f64;
        self.snap(raw.round() as i64)
    }

    /// Uniformly random grid value.
    pub fn random(&self, rng: &mut Rng) -> i64 {
        self.value_at(rng.index(self.n_values()))
    }
}

/// A concrete configuration: one value per parameter, in space order.
pub type Config = Vec<i64>;

/// The Cartesian-product search space.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    pub params: Vec<ParamDef>,
}

impl SearchSpace {
    pub fn new(params: Vec<ParamDef>) -> SearchSpace {
        assert!(!params.is_empty(), "empty search space");
        SearchSpace { params }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Total number of grid points (the paper quotes ~50 000 for its
    /// ResNet50 sweep at coarsened steps).
    pub fn size(&self) -> u128 {
        self.params.iter().map(|p| p.n_values() as u128).product()
    }

    /// Stable fingerprint of this space's *shape*: FNV-1a 64 over every
    /// parameter's name, range and step, in declaration order. Two
    /// processes built from the same parameter table — any build, any
    /// machine — produce the same value; any rename, reorder, re-range or
    /// re-step changes it. The protocol-v4 `hello` carries this so one
    /// surrogate daemon can key an independent factor per search space
    /// and reject tuners aimed at the wrong one (see `server/proto.rs`).
    pub fn fingerprint(&self) -> u64 {
        let mut canon = String::new();
        for p in &self.params {
            canon.push_str(&p.name);
            canon.push('\0');
            canon.push_str(&format!("{}\0{}\0{}\n", p.min, p.max, p.step));
        }
        crate::util::fnv1a64(canon.as_bytes())
    }

    pub fn param(&self, name: &str) -> Option<&ParamDef> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Snap every coordinate of an arbitrary integer vector onto the grid.
    pub fn snap(&self, cfg: &[i64]) -> Config {
        assert_eq!(cfg.len(), self.dim(), "config dim mismatch");
        self.params.iter().zip(cfg).map(|(p, &v)| p.snap(v)).collect()
    }

    /// True if `cfg` lies exactly on the grid.
    pub fn contains(&self, cfg: &[i64]) -> bool {
        cfg.len() == self.dim() && self.snap(cfg) == cfg
    }

    /// Configuration -> unit cube.
    pub fn to_unit(&self, cfg: &[i64]) -> Vec<f64> {
        assert_eq!(cfg.len(), self.dim(), "config dim mismatch");
        self.params.iter().zip(cfg).map(|(p, &v)| p.to_unit(v)).collect()
    }

    /// Unit cube -> nearest grid configuration.
    pub fn from_unit(&self, u: &[f64]) -> Config {
        assert_eq!(u.len(), self.dim(), "unit vector dim mismatch");
        self.params.iter().zip(u).map(|(p, &x)| p.from_unit(x)).collect()
    }

    /// Uniformly random configuration.
    pub fn random(&self, rng: &mut Rng) -> Config {
        self.params.iter().map(|p| p.random(rng)).collect()
    }

    /// Latin-hypercube sample of `n` configurations: each parameter's range
    /// is cut into n strata and each stratum used exactly once — the
    /// standard space-filling initial design for BO.
    pub fn latin_hypercube(&self, n: usize, rng: &mut Rng) -> Vec<Config> {
        assert!(n > 0);
        let mut columns: Vec<Vec<f64>> = Vec::with_capacity(self.dim());
        for _ in 0..self.dim() {
            let mut col: Vec<f64> =
                (0..n).map(|i| (i as f64 + rng.f64()) / n as f64).collect();
            rng.shuffle(&mut col);
            columns.push(col);
        }
        (0..n)
            .map(|i| {
                let u: Vec<f64> = columns.iter().map(|c| c[i]).collect();
                self.from_unit(&u)
            })
            .collect()
    }

    /// A random neighbour: perturb each coordinate by ±step with prob
    /// `move_prob`, always changing at least one coordinate.
    pub fn neighbour(&self, cfg: &[i64], move_prob: f64, rng: &mut Rng) -> Config {
        let mut out = self.snap(cfg);
        let mut moved = false;
        for (i, p) in self.params.iter().enumerate() {
            if rng.bool(move_prob) {
                let delta = if rng.bool(0.5) { p.step } else { -p.step };
                let v = p.snap(out[i] + delta);
                if v != out[i] {
                    out[i] = v;
                    moved = true;
                }
            }
        }
        if !moved {
            let i = rng.index(self.dim());
            let p = &self.params[i];
            let delta = if rng.bool(0.5) { p.step } else { -p.step };
            out[i] = p.snap(out[i] + delta);
        }
        out
    }

    /// Iterate the full grid in row-major order (Fig. 6 sweep).
    pub fn grid(&self) -> GridIter<'_> {
        GridIter { space: self, idx: vec![0; self.dim()], done: false }
    }

    /// JSON encoding of a configuration as {param: value}.
    pub fn config_to_json(&self, cfg: &[i64]) -> Json {
        Json::Obj(
            self.params
                .iter()
                .zip(cfg)
                .map(|(p, &v)| (p.name.clone(), Json::Num(v as f64)))
                .collect(),
        )
    }

    /// Decode {param: value} JSON into a snapped configuration.
    pub fn config_from_json(&self, j: &Json) -> Result<Config, String> {
        let mut cfg = Vec::with_capacity(self.dim());
        for p in &self.params {
            let v = j
                .get(&p.name)
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("missing/invalid param '{}'", p.name))?;
            cfg.push(p.snap(v));
        }
        Ok(cfg)
    }
}

/// Row-major grid iterator.
pub struct GridIter<'a> {
    space: &'a SearchSpace,
    idx: Vec<usize>,
    done: bool,
}

impl<'a> Iterator for GridIter<'a> {
    type Item = Config;

    fn next(&mut self) -> Option<Config> {
        if self.done {
            return None;
        }
        let cfg: Config = self
            .space
            .params
            .iter()
            .zip(&self.idx)
            .map(|(p, &i)| p.value_at(i))
            .collect();
        // Advance odometer (last param fastest).
        let mut k = self.space.dim();
        loop {
            if k == 0 {
                self.done = true;
                break;
            }
            k -= 1;
            self.idx[k] += 1;
            if self.idx[k] < self.space.params[k].n_values() {
                break;
            }
            self.idx[k] = 0;
        }
        Some(cfg)
    }
}

// ---------------------------------------------------------------------------
// The paper's concrete space (Table 1).
// ---------------------------------------------------------------------------

/// Canonical parameter order used throughout tftune.
pub const INTER_OP: usize = 0;
pub const INTRA_OP: usize = 1;
pub const BATCH: usize = 2;
pub const BLOCKTIME: usize = 3;
pub const OMP_THREADS: usize = 4;

/// Pairplot letters from the paper (Fig. 7 / Table 2):
/// X=intra_op, Y=OMP_NUM_THREADS, Z=batch_size, V=inter_op, W=KMP_BLOCKTIME.
pub fn paper_letter(param_index: usize) -> &'static str {
    match param_index {
        INTER_OP => "V",
        INTRA_OP => "X",
        BATCH => "Z",
        BLOCKTIME => "W",
        OMP_THREADS => "Y",
        _ => "?",
    }
}

/// TensorFlow threading-model space with a per-model batch range (Table 1).
pub fn threading_space(batch_min: i64, batch_max: i64, batch_step: i64) -> SearchSpace {
    SearchSpace::new(vec![
        ParamDef::new("inter_op_parallelism_threads", 1, 4, 1),
        ParamDef::new("intra_op_parallelism_threads", 1, 56, 1),
        ParamDef::new("batch_size", batch_min, batch_max, batch_step),
        ParamDef::new("KMP_BLOCKTIME", 0, 200, 10),
        ParamDef::new("OMP_NUM_THREADS", 1, 56, 1),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn space() -> SearchSpace {
        threading_space(64, 1024, 64)
    }

    #[test]
    fn table1_counts() {
        let s = space();
        assert_eq!(s.params[INTER_OP].n_values(), 4);
        assert_eq!(s.params[INTRA_OP].n_values(), 56);
        assert_eq!(s.params[BATCH].n_values(), 16);
        assert_eq!(s.params[BLOCKTIME].n_values(), 21);
        assert_eq!(s.params[OMP_THREADS].n_values(), 56);
        assert_eq!(s.size(), 4 * 56 * 16 * 21 * 56);
    }

    #[test]
    fn snap_rounds_to_grid() {
        let p = ParamDef::new("b", 64, 1024, 64);
        assert_eq!(p.snap(64), 64);
        assert_eq!(p.snap(90), 64);
        assert_eq!(p.snap(97), 128);
        assert_eq!(p.snap(5000), 1024);
        assert_eq!(p.snap(-3), 64);
    }

    #[test]
    fn unit_round_trip_endpoints() {
        let p = ParamDef::new("t", 1, 56, 1);
        assert_eq!(p.from_unit(0.0), 1);
        assert_eq!(p.from_unit(1.0), 56);
        assert!((p.to_unit(1) - 0.0).abs() < 1e-12);
        assert!((p.to_unit(56) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn grid_iterates_entire_space() {
        let s = SearchSpace::new(vec![
            ParamDef::new("a", 0, 2, 1),
            ParamDef::new("b", 10, 30, 10),
        ]);
        let all: Vec<Config> = s.grid().collect();
        assert_eq!(all.len(), 9);
        assert_eq!(all[0], vec![0, 10]);
        assert_eq!(all[8], vec![2, 30]);
        // all unique
        let mut uniq = all.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 9);
    }

    #[test]
    fn lhs_covers_strata() {
        let s = space();
        let mut rng = Rng::new(9);
        let n = 8;
        let d = s.latin_hypercube(n, &mut rng);
        assert_eq!(d.len(), n);
        for cfg in &d {
            assert!(s.contains(cfg));
        }
        // For the 56-value params, 8 LHS strata are >= 7 grid points wide,
        // so after snapping all sampled values must be pairwise distinct.
        for pi in [INTRA_OP, OMP_THREADS] {
            let mut vs: Vec<i64> = d.iter().map(|c| c[pi]).collect();
            vs.sort_unstable();
            let before = vs.len();
            vs.dedup();
            assert_eq!(vs.len(), before, "strata collide for param {pi}: {vs:?}");
        }
    }

    #[test]
    fn json_round_trip() {
        let s = space();
        let mut rng = Rng::new(4);
        let cfg = s.random(&mut rng);
        let j = s.config_to_json(&cfg);
        let back = s.config_from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn json_missing_param_errors() {
        let s = space();
        let j = crate::util::json::parse(r#"{"batch_size": 64}"#).unwrap();
        assert!(s.config_from_json(&j).is_err());
    }

    #[test]
    fn prop_snap_idempotent_and_in_bounds() {
        let s = space();
        prop::check("snap idempotent", 200, |rng| {
            let raw: Vec<i64> =
                s.params.iter().map(|_| prop::int_biased(rng, -2000, 3000)).collect();
            let snapped = s.snap(&raw);
            assert_eq!(s.snap(&snapped), snapped);
            assert!(s.contains(&snapped));
            for (p, &v) in s.params.iter().zip(&snapped) {
                assert!(v >= p.min && v <= p.max);
            }
        });
    }

    #[test]
    fn prop_unit_round_trip() {
        let s = space();
        prop::check("unit round trip", 200, |rng| {
            let cfg = s.random(rng);
            let u = s.to_unit(&cfg);
            assert_eq!(s.from_unit(&u), cfg);
            for x in &u {
                assert!((0.0..=1.0).contains(x));
            }
        });
    }

    #[test]
    fn prop_neighbour_on_grid_and_differs() {
        let s = space();
        prop::check("neighbour validity", 200, |rng| {
            let cfg = s.random(rng);
            let n = s.neighbour(&cfg, 0.3, rng);
            assert!(s.contains(&n));
        });
    }

    #[test]
    fn degenerate_single_point_range() {
        let p = ParamDef::new("x", 5, 5, 1);
        assert_eq!(p.n_values(), 1);
        assert_eq!(p.from_unit(0.7), 5);
        assert_eq!(p.to_unit(5), 0.5);
    }
}
