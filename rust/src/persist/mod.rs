//! The durable persistence plane: snapshot + write-ahead log with
//! bit-identical crash recovery for the shared surrogate.
//!
//! The paper's campaigns are long black-box searches where every trial
//! is an expensive real measurement — yet the authoritative packed
//! Cholesky factor, the observation store and the multi-objective
//! history all live in memory. This module makes them survive a crash:
//!
//! - [`snapshot`] — periodic checksummed captures of the full model
//!   (observation rows + extras, hypers, and the packed factor when it
//!   covers the store prefix), written atomically off the model lock.
//! - [`wal`] — a write-ahead log of every store mutation between
//!   snapshots, appended *under the model-state lock* by a journal hook
//!   inside [`SharedSurrogate`], fsync'd on a configurable cadence. WAL
//!   order is store-mutation order by construction, and the number of
//!   `tell` records always equals the store length.
//! - [`recover`](crate::persist::recover()) — newest valid snapshot +
//!   WAL-suffix replay through the existing `factor_suffix`/`import_row`
//!   and drain machinery, restoring the factor **bit-identically** to
//!   the pre-crash authority (same ≤-exact standard the replica-parity
//!   suite pins). Torn WAL tails are truncated; corrupt snapshots fall
//!   back to full-log replay.
//!
//! # Wiring
//!
//! `surrogate-serve --state-dir DIR` recovers on boot, attaches the
//! journal, and checkpoints in the background; `tune --state-dir DIR`
//! additionally streams each completed trial to `DIR/session.jsonl` so
//! `--resume` continues an interrupted budget. In-process, attach
//! durability to any [`SharedSurrogate`] directly:
//!
//! ```
//! use tftune::gp::{GpHyper, SharedSurrogate};
//! use tftune::persist::{self, PersistOptions};
//!
//! let dir = std::env::temp_dir().join("tftune_doc_persist");
//! # std::fs::remove_dir_all(&dir).ok();
//! let shared = SharedSurrogate::new(GpHyper::default());
//! let persistence =
//!     persist::attach(&shared, &dir, PersistOptions::default()).unwrap();
//! shared.tell(vec![0.25, 0.75], 1.5); // journaled on next drain
//! drop(shared.lock());
//! persistence.snapshot(&shared).unwrap();
//!
//! // …crash… then restore, bit-identically:
//! let restored = persist::recover(&dir, GpHyper::default()).unwrap();
//! assert_eq!(restored.surrogate.len(), 1);
//! # std::fs::remove_dir_all(&dir).ok();
//! ```
//!
//! Attach the journal to the **authoritative** handle only. A
//! [`RemoteSurrogate`](crate::gp::RemoteSurrogate) mirror replicates a
//! factor that is already journaled at its served authority; journaling
//! it again would record the same history twice.
//!
//! # The sharded tier
//!
//! A store running the sharded scaling tier
//! ([`SharedSurrogate::new_sharded`](crate::gp::SharedSurrogate::new_sharded))
//! exports **rows-only** deltas — its factor is an ensemble of per-shard
//! packed Choleskys, not one flat triangle — so its snapshots carry
//! `"factor": null` and [`recover`](crate::persist::recover()) seeds the
//! store through the drain path instead of a verbatim factor import. The
//! recovered store comes back on the flat exact engine; the daemon then
//! re-tiers it (`--surrogate sharded` at open, or `--surrogate auto` at
//! the row cap) by re-pushing the rows in observation order, and the KD
//! tree re-splits at the same capacities, deterministically. The journal
//! format is unchanged — rows + hypers are tier-agnostic.

pub mod recover;
pub mod snapshot;
pub mod wal;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::gp::shared::JournalEvent;
use crate::gp::SharedSurrogate;
use crate::obs::{Event, EventSource};

pub use recover::Recovered;
pub use snapshot::{list_snapshots, snapshot_path, write_snapshot, SNAPSHOTS_KEPT};
pub use wal::{read_wal, wal_path, WalRecord, WalWriter, WAL_FILE};

/// Tunables for [`attach`].
#[derive(Debug, Clone, Copy)]
pub struct PersistOptions {
    /// Fsync the WAL after every `n` appended records; `0` buffers until
    /// an explicit sync or snapshot. Default 1 — every measurement is
    /// paid for with real evaluation time, so losing even one to a crash
    /// costs more than an fsync (see ARCHITECTURE.md §Durability for the
    /// cadence trade-off).
    pub fsync_every: usize,
}

impl Default for PersistOptions {
    fn default() -> PersistOptions {
        PersistOptions { fsync_every: 1 }
    }
}

/// Handle to an attached journal: owns the WAL writer shared with the
/// surrogate's journal hook and knows the state directory, so callers
/// can snapshot and sync through one object.
pub struct Persistence {
    dir: PathBuf,
    writer: Arc<Mutex<WalWriter>>,
    /// Observability: `snapshot-written` / `wal-sync` events flow through
    /// this source once [`Persistence::set_event_source`] attaches one.
    events: OnceLock<EventSource>,
}

impl Persistence {
    /// The state directory this journal writes into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Attach an observability event source: every successful
    /// [`Persistence::snapshot`] emits `snapshot-written` (the snapshot
    /// seq) and every successful [`Persistence::sync`] emits `wal-sync`
    /// carrying the records-appended gauge ([`WalWriter::appended`]).
    /// Write-once: the first source wins.
    pub fn set_event_source(&self, src: EventSource) {
        let _ = self.events.set(src);
    }

    /// Capture and write one snapshot of `surrogate` (atomic, keeps the
    /// newest [`SNAPSHOTS_KEPT`]), then fsync the WAL so every row the
    /// snapshot contains is also durable in the log — full-log fallback
    /// stays valid even if this snapshot is later corrupted. Returns the
    /// snapshot's `seq`.
    pub fn snapshot(&self, surrogate: &SharedSurrogate) -> Result<usize> {
        let seq = write_snapshot(surrogate, &self.dir)?;
        self.sync()?;
        if let Some(src) = self.events.get() {
            src.emit(Event::SnapshotWritten { seq });
        }
        Ok(seq)
    }

    /// Flush and fsync the WAL now, regardless of cadence.
    pub fn sync(&self) -> Result<()> {
        let appended = {
            let mut w = self.writer.lock().unwrap();
            w.sync()?;
            w.appended()
        };
        if let Some(src) = self.events.get() {
            src.emit(Event::WalSync { records: appended as usize });
        }
        Ok(())
    }
}

/// Install the durability journal on `surrogate`: every store mutation
/// (stored row, hyper change) from this point on is appended to
/// `dir/wal.jsonl` in store order, honouring `opts.fsync_every`.
///
/// Safe on a warm surrogate: if the WAL holds fewer `tell` records than
/// the store (fresh directory, or rows told before attachment), the gap
/// is backfilled first so the log always describes the whole store.
/// Attach to the *authoritative* handle only (module docs); attach
/// *after* [`recover`](crate::persist::recover()) so replay is never
/// journaled twice.
pub fn attach(
    surrogate: &SharedSurrogate,
    dir: &Path,
    opts: PersistOptions,
) -> Result<Persistence> {
    // Drain pending tells so the store — and the backfill below — is
    // current before the journal starts observing mutations.
    drop(surrogate.lock());

    let mut writer = WalWriter::open(dir, opts.fsync_every)?;

    // Backfill: the WAL must be a prefix of the store's history.
    let on_disk = read_wal(&wal_path(dir))?.tell_count();
    let store_len = surrogate.len();
    if on_disk < store_len {
        let missing = surrogate
            .export_delta(on_disk)
            .expect("store length bounds the export");
        for (k, (x, y)) in missing.rows.iter().enumerate() {
            writer.append(&WalRecord::Tell {
                x: x.clone(),
                value: *y,
                objectives: missing.extras.get(k).cloned().unwrap_or_default(),
            });
        }
        writer.sync()?;
    }

    let writer = Arc::new(Mutex::new(writer));
    let hook_writer = Arc::clone(&writer);
    // The hook runs under the model-state lock; the writer mutex nests
    // strictly below it (nobody takes state while holding the writer).
    surrogate.set_journal(move |event| {
        let mut w = hook_writer.lock().unwrap();
        match event {
            JournalEvent::Row { x, y, extras } => w.append(&WalRecord::Tell {
                x: x.to_vec(),
                value: y,
                objectives: extras.to_vec(),
            }),
            JournalEvent::Hyper(h) => w.append(&WalRecord::SetHyper(h)),
        }
    });
    Ok(Persistence { dir: dir.to_path_buf(), writer, events: OnceLock::new() })
}

/// Rebuild a surrogate from `dir` — see [`recover::recover`].
pub fn recover(dir: &Path, default_hyper: crate::gp::GpHyper) -> Result<Recovered> {
    recover::recover(dir, default_hyper)
}

/// The state-dir namespace of one fleet space: `root/space-<16 hex>`.
/// The daemon's *default* space journals into `root` itself (the layout
/// every pre-fleet `--state-dir` produced), so old campaign directories
/// keep recovering unchanged; every other fingerprint gets its own
/// subdirectory with the same snapshot + WAL layout inside.
pub fn space_dir(root: &Path, fingerprint: u64) -> PathBuf {
    root.join(format!("space-{fingerprint:016x}"))
}

/// Enumerate the per-space namespaces under `root` (fleet boot
/// recovery): every `space-<16 hex>` subdirectory, as
/// `(fingerprint, path)` pairs in fingerprint order. A missing `root`
/// is an empty fleet, not an error; non-matching entries are ignored.
pub fn list_space_dirs(root: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(e).with_context(|| format!("listing state dir {}", root.display()))
        }
    };
    for entry in entries {
        let entry = entry.with_context(|| format!("listing state dir {}", root.display()))?;
        let name = entry.file_name();
        let Some(hex) = name.to_str().and_then(|n| n.strip_prefix("space-")) else {
            continue;
        };
        if hex.len() != 16 || !entry.path().is_dir() {
            continue;
        }
        if let Ok(fp) = u64::from_str_radix(hex, 16) {
            out.push((fp, entry.path()));
        }
    }
    out.sort_by_key(|(fp, _)| *fp);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{GpHyper, SurrogateHandle};
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tftune_persist_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn factor_bits(s: &SharedSurrogate) -> Vec<u64> {
        let delta = s.export_delta(0).unwrap();
        delta.factor.expect("factor present").iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn journal_records_drains_and_hyper_changes_in_order() {
        let dir = tmp_dir("order");
        let shared = SharedSurrogate::new(GpHyper::default());
        let p = attach(&shared, &dir, PersistOptions { fsync_every: 1 }).unwrap();
        shared.tell(vec![0.1, 0.2], 1.0);
        shared.tell_multi(vec![0.3, 0.4], vec![2.0, -0.5]);
        drop(shared.lock());
        let new = GpHyper { lengthscale: 0.5, ..GpHyper::default() };
        shared.set_hyper(new);
        shared.tell(vec![0.5, 0.6], 3.0);
        drop(shared.lock());
        p.sync().unwrap();

        let wal = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(wal.records.len(), 4);
        assert!(matches!(&wal.records[0], WalRecord::Tell { value, .. } if *value == 1.0));
        assert!(
            matches!(&wal.records[1], WalRecord::Tell { objectives, .. } if objectives == &vec![-0.5])
        );
        assert!(matches!(&wal.records[2], WalRecord::SetHyper(h) if *h == new));
        assert!(matches!(&wal.records[3], WalRecord::Tell { value, .. } if *value == 3.0));
        assert_eq!(wal.tell_count(), shared.len(), "WAL tells == store length invariant");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dropped_rows_are_never_journaled() {
        let dir = tmp_dir("dropped");
        let shared = SharedSurrogate::new(GpHyper::default());
        let _p = attach(&shared, &dir, PersistOptions::default()).unwrap();
        shared.tell(vec![0.1, 0.2], 1.0);
        shared.tell(vec![0.3], 2.0); // wrong dimension: dropped on drain
        shared.tell(vec![0.7, 0.8], 3.0);
        drop(shared.lock());
        let wal = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(wal.tell_count(), 2, "the dropped row must not reach the WAL");
        assert_eq!(wal.tell_count(), shared.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn attach_backfills_a_warm_surrogate() {
        let dir = tmp_dir("backfill");
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(23);
        for _ in 0..5 {
            shared.tell_multi(vec![rng.f64(), rng.f64()], vec![rng.f64(), 9.0]);
        }
        // Rows exist before any journal: attach must backfill them.
        let p = attach(&shared, &dir, PersistOptions::default()).unwrap();
        shared.tell(vec![0.5, 0.5], 7.0);
        drop(shared.lock());
        p.sync().unwrap();
        let wal = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(wal.tell_count(), 6);
        match &wal.records[0] {
            WalRecord::Tell { objectives, .. } => assert_eq!(objectives, &vec![9.0]),
            other => panic!("unexpected {other:?}"),
        }
        // The backfilled log replays to the same factor.
        let r = recover(&dir, GpHyper::default()).unwrap();
        assert_eq!(factor_bits(&shared), factor_bits(&r.surrogate));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_then_more_tells_then_recover() {
        let dir = tmp_dir("cycle");
        let shared = SharedSurrogate::new(GpHyper::default());
        let p = attach(&shared, &dir, PersistOptions::default()).unwrap();
        let mut rng = Rng::new(29);
        for _ in 0..6 {
            shared.tell(vec![rng.f64(), rng.f64()], rng.f64());
        }
        let seq = p.snapshot(&shared).unwrap();
        assert_eq!(seq, 6, "snapshot drains pending tells before capture");
        for _ in 0..4 {
            shared.tell(vec![rng.f64(), rng.f64()], rng.f64());
        }
        drop(shared.lock());
        p.sync().unwrap();

        let r = recover(&dir, GpHyper::default()).unwrap();
        assert_eq!(r.snapshot_seq, Some(6));
        assert_eq!(r.replayed, 4);
        assert_eq!(r.surrogate.len(), 10);
        assert_eq!(factor_bits(&shared), factor_bits(&r.surrogate));
        std::fs::remove_dir_all(&dir).ok();
    }
}
