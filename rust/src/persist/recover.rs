//! Crash recovery: rebuild the authoritative [`SharedSurrogate`] from a
//! state directory, bit-identically to the pre-crash factor.
//!
//! Sequence (ARCHITECTURE.md §Durability):
//!
//! 1. Read the WAL; truncate a torn tail to the last complete record.
//! 2. Scan snapshots newest-first; the first one that validates
//!    (checksum, version, counts) seeds the store — its packed factor
//!    rows are imported *verbatim* through the same
//!    `factor_suffix`/`import_row` machinery replica catch-up uses, so
//!    the restored factor is byte-for-byte the authority's.
//! 3. Replay the WAL suffix: skip records up to the snapshot's `seq`-th
//!    `tell`, then apply the rest in order through the ordinary
//!    `tell_multi`/`set_hyper` drain path — identical float ops over an
//!    identical store prefix, hence identical eager rank-1 appends.
//!    Re-applying a `set-hyper` the snapshot already reflects is a
//!    no-op (hyper equality check), so the snapshot boundary cannot
//!    double-apply anything.
//! 4. If every snapshot is corrupt (or none exists), fall back to
//!    full-log replay from `seq` 0.
//! 5. Heal: if the WAL holds fewer `tell` records than the recovered
//!    store (a snapshot outlived an unsynced or poisoned WAL tail),
//!    append the missing rows back so full-log fallback stays valid for
//!    the *next* crash.

use std::path::Path;

use anyhow::{Context, Result};

use crate::gp::{GpHyper, SharedSurrogate};

use super::snapshot::{list_snapshots, load_snapshot};
use super::wal::{read_wal, truncate_wal, wal_path, WalRecord, WalWriter};

/// The outcome of [`recover`]: the rebuilt surrogate plus what it took.
pub struct Recovered {
    /// The restored authoritative surrogate (journal *not* attached —
    /// callers attach one after recovery so replay is never re-journaled).
    pub surrogate: SharedSurrogate,
    /// `seq` of the snapshot that seeded the store; `None` for full-log
    /// replay (no snapshot, or every snapshot corrupt).
    pub snapshot_seq: Option<usize>,
    /// WAL records applied on top of the snapshot.
    pub replayed: usize,
    /// Bytes of torn WAL tail truncated away.
    pub truncated_bytes: u64,
    /// Store rows appended back into the WAL by the heal pass.
    pub healed: usize,
}

/// Rebuild the surrogate from `dir` (see module docs). An empty or
/// absent directory recovers to a fresh, empty surrogate conditioned
/// with `default_hyper` — so one code path serves cold start and
/// restart alike.
pub fn recover(dir: &Path, default_hyper: GpHyper) -> Result<Recovered> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating state dir {}", dir.display()))?;

    // 1. The WAL, torn tail removed.
    let path = wal_path(dir);
    let wal = read_wal(&path)?;
    let mut truncated_bytes = 0;
    if wal.torn {
        let total = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(wal.valid_len);
        truncated_bytes = total - wal.valid_len;
        eprintln!(
            "tftune: truncating {truncated_bytes} byte(s) of torn WAL tail in {}",
            dir.display()
        );
        truncate_wal(&path, wal.valid_len)?;
    }

    // 2. Newest valid snapshot seeds the store.
    let mut surrogate = None;
    let mut snapshot_seq = None;
    for (seq, snap_path) in list_snapshots(dir)? {
        match load_snapshot(&snap_path) {
            Ok(delta) => {
                let restored = SharedSurrogate::new(delta.hyper);
                // from_n = 0 against an empty store: always applies.
                // Factor rows (when present) import verbatim.
                assert!(restored.import_delta(&delta), "empty store accepts a full delta");
                surrogate = Some(restored);
                snapshot_seq = Some(seq);
                break;
            }
            Err(e) => {
                eprintln!(
                    "tftune: snapshot {} invalid ({e}); falling back to the previous one",
                    snap_path.display()
                );
            }
        }
    }
    let surrogate = match surrogate {
        Some(s) => s,
        None => SharedSurrogate::new(default_hyper), // full-log replay
    };
    let seq = snapshot_seq.unwrap_or(0);

    // 3./4. Replay the WAL suffix: skip through the seq-th tell (hyper
    // records in that prefix are already reflected by the snapshot's
    // hyper — state mutation precedes its journal write under one lock),
    // apply everything after in order.
    let mut tells_seen = 0usize;
    let mut replayed = 0usize;
    for record in &wal.records {
        if tells_seen < seq {
            if let WalRecord::Tell { .. } = record {
                tells_seen += 1;
            }
            continue;
        }
        match record {
            WalRecord::Tell { x, value, objectives } => {
                let mut ys = Vec::with_capacity(1 + objectives.len());
                ys.push(*value);
                ys.extend_from_slice(objectives);
                surrogate.tell_multi(x.clone(), ys);
            }
            // set_hyper drains queued tells first (its guard's lock), so
            // replay order is preserved; an equal hyper is a no-op.
            WalRecord::SetHyper(h) => surrogate.set_hyper(*h),
        }
        replayed += 1;
    }
    drop(surrogate.lock()); // drain the trailing tells into the factor

    // 5. Heal: a snapshot newer than the surviving WAL leaves the log
    // short; append the missing store rows so full-log fallback stays
    // valid. (Journaled rows always passed the store's dimension check,
    // so WAL tell k is store row k — indices align.)
    let wal_tells = wal.tell_count();
    let store_len = surrogate.len();
    let mut healed = 0usize;
    if wal_tells < store_len {
        let missing = surrogate
            .export_delta(wal_tells)
            .expect("store length bounds the export");
        match WalWriter::open(dir, 0) {
            Ok(mut w) => {
                for (k, (x, y)) in missing.rows.iter().enumerate() {
                    w.append(&WalRecord::Tell {
                        x: x.clone(),
                        value: *y,
                        objectives: missing.extras.get(k).cloned().unwrap_or_default(),
                    });
                }
                if w.sync().is_ok() && !w.is_failed() {
                    healed = missing.rows.len();
                }
            }
            Err(e) => {
                eprintln!("tftune: could not heal the WAL ({e}); continuing without it")
            }
        }
    }

    Ok(Recovered { surrogate, snapshot_seq, replayed, truncated_bytes, healed })
}

#[cfg(test)]
mod tests {
    use super::super::snapshot::{snapshot_path, write_snapshot};
    use super::*;
    use crate::gp::{ScoreWorkspace, SurrogateHandle};
    use crate::util::Rng;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tftune_recover_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn factor_bits(s: &SharedSurrogate) -> Vec<u64> {
        let delta = s.export_delta(0).unwrap();
        delta.factor.expect("factor covers the store prefix").iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn fresh_dir_recovers_empty() {
        let dir = tmp_dir("fresh");
        let r = recover(&dir, GpHyper::default()).unwrap();
        assert_eq!(r.surrogate.len(), 0);
        assert_eq!(r.snapshot_seq, None);
        assert_eq!(r.replayed, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_plus_wal_suffix_restores_bit_identically() {
        let dir = tmp_dir("bitwise");
        let hyper = GpHyper::default();
        let authority = SharedSurrogate::new(hyper);
        let mut w = WalWriter::open(&dir, 1).unwrap();
        let mut rng = Rng::new(11);
        let mut tell = |s: &SharedSurrogate, w: &mut WalWriter| {
            let x: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            let y = (5.0 * x[0]).cos() + x[1];
            s.tell(x.clone(), y);
            w.append(&WalRecord::Tell { x, value: y, objectives: Vec::new() });
        };
        for _ in 0..10 {
            tell(&authority, &mut w);
        }
        drop(authority.lock());
        write_snapshot(&authority, &dir).unwrap();
        for _ in 0..7 {
            tell(&authority, &mut w); // WAL suffix past the snapshot
        }
        drop(authority.lock());
        drop(w);

        let r = recover(&dir, hyper).unwrap();
        assert_eq!(r.snapshot_seq, Some(10));
        assert_eq!(r.replayed, 7);
        assert_eq!(r.surrogate.len(), 17);
        assert_eq!(
            factor_bits(&authority),
            factor_bits(&r.surrogate),
            "restored packed factor must be bit-identical"
        );

        // And the posterior it serves is bit-identical too.
        let cand: Vec<f64> = (0..6).map(|_| rng.f64()).collect();
        let (mut wa, mut wb) = (ScoreWorkspace::default(), ScoreWorkspace::default());
        for (h, ws) in [(&authority, &mut wa), (&r.surrogate, &mut wb)] {
            let mut g = h.lock();
            let idx = g.conditioning_set();
            assert!(g.sync(&idx));
            let y: Vec<f64> = idx.iter().map(|&i| g.y(i)).collect();
            g.set_targets(&y);
            g.score_into(&cand, 2, 1.5, 0.0, ws);
        }
        for j in 0..2 {
            assert_eq!(wa.mean[j].to_bits(), wb.mean[j].to_bits());
            assert_eq!(wa.std[j].to_bits(), wb.std[j].to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_snapshot_falls_back_to_full_log_replay() {
        let dir = tmp_dir("fallback");
        let hyper = GpHyper::default();
        let authority = SharedSurrogate::new(hyper);
        let mut w = WalWriter::open(&dir, 1).unwrap();
        let mut rng = Rng::new(13);
        for _ in 0..8 {
            let x: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
            let y = x[0] - x[1];
            authority.tell(x.clone(), y);
            w.append(&WalRecord::Tell { x, value: y, objectives: Vec::new() });
        }
        drop(authority.lock());
        let seq = write_snapshot(&authority, &dir).unwrap();
        drop(w);
        // Corrupt the only snapshot: recovery must replay the whole log.
        std::fs::write(snapshot_path(&dir, seq), b"{\"version\":1,garbage").unwrap();

        let r = recover(&dir, hyper).unwrap();
        assert_eq!(r.snapshot_seq, None, "corrupt snapshot must not seed the store");
        assert_eq!(r.replayed, 8);
        assert_eq!(factor_bits(&authority), factor_bits(&r.surrogate));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hyper_changes_replay_in_order() {
        let dir = tmp_dir("hyper");
        let hyper = GpHyper::default();
        let authority = SharedSurrogate::new(hyper);
        let mut w = WalWriter::open(&dir, 1).unwrap();
        let mut rng = Rng::new(17);
        for i in 0..9 {
            if i == 4 {
                let new = GpHyper { lengthscale: 0.5, ..hyper };
                authority.set_hyper(new);
                w.append(&WalRecord::SetHyper(new));
            }
            let x: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
            let y = (3.0 * x[0]).sin();
            authority.tell(x.clone(), y);
            w.append(&WalRecord::Tell { x, value: y, objectives: Vec::new() });
        }
        drop(authority.lock());
        drop(w);

        let r = recover(&dir, hyper).unwrap();
        assert_eq!(r.surrogate.hyper(), authority.hyper());
        assert_eq!(r.surrogate.len(), 9);
        assert_eq!(factor_bits(&authority), factor_bits(&r.surrogate));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_newer_than_wal_heals_the_log() {
        let dir = tmp_dir("heal");
        let hyper = GpHyper::default();
        let authority = SharedSurrogate::new(hyper);
        let mut rng = Rng::new(19);
        // Rows reach the snapshot but never the WAL (e.g. a poisoned
        // writer): recovery restores from the snapshot and heals.
        for _ in 0..6 {
            let x: Vec<f64> = (0..2).map(|_| rng.f64()).collect();
            authority.tell_multi(x, vec![rng.f64(), -1.5]);
        }
        drop(authority.lock());
        write_snapshot(&authority, &dir).unwrap();

        let r = recover(&dir, hyper).unwrap();
        assert_eq!(r.snapshot_seq, Some(6));
        assert_eq!(r.healed, 6);
        assert_eq!(factor_bits(&authority), factor_bits(&r.surrogate));
        let wal = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(wal.tell_count(), 6, "healed WAL covers the whole store");
        match &wal.records[0] {
            WalRecord::Tell { objectives, .. } => {
                assert_eq!(objectives, &vec![-1.5], "extras survive the heal")
            }
            other => panic!("unexpected {other:?}"),
        }

        // A second recovery now works even without the snapshot at all.
        for (_, p) in list_snapshots(&dir).unwrap() {
            std::fs::remove_file(p).unwrap();
        }
        let r2 = recover(&dir, hyper).unwrap();
        assert_eq!(r2.snapshot_seq, None);
        assert_eq!(factor_bits(&authority), factor_bits(&r2.surrogate));
        std::fs::remove_dir_all(&dir).ok();
    }
}
