//! Checksummed snapshots of the shared surrogate: the full canonical
//! observation store, the hypers, and — when the factor covers exactly
//! the store prefix (eager factoring's steady state) — the packed
//! Cholesky factor itself, byte-for-byte.
//!
//! On-disk format (`snapshot-<seq>.json`, one JSON object):
//!
//! ```text
//! {"checksum":"<fnv1a64 hex>",
//!  "factor":[<f64>...]|null,
//!  "hyper":{...},
//!  "rows":[{"x":[...],"y":<f64>[,"ys":[...]]},...],
//!  "seq":<n>,
//!  "version":1}
//! ```
//!
//! `seq` is the store length the snapshot captures — recovery skips that
//! many `tell` records of the WAL and replays the rest. The checksum is
//! FNV-1a 64 over the canonical serialization of the object *without*
//! the checksum field; the JSON codec is deterministic (sorted keys,
//! shortest-round-trip f64s), so verification is re-serialize + compare.
//! Writes are atomic: temp file, fsync, rename, directory fsync — a
//! crash mid-write leaves either the old snapshot set or the new one,
//! never a half-written file that passes validation.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::gp::{SharedSurrogate, SurrogateDelta};
use crate::server::proto::{
    f64_vec, hyper_from_json, hyper_to_json, rows_from_json, rows_to_json,
};
use crate::util::fnv1a64;
use crate::util::json::{parse, Json};

/// Snapshot format version this build writes (and the only one it reads).
pub const SNAPSHOT_VERSION: i64 = 1;

/// How many snapshots [`write_snapshot`] retains (newest first). Two, so
/// a corrupt newest snapshot still recovers from its predecessor plus a
/// longer WAL replay before falling all the way back to full-log replay.
pub const SNAPSHOTS_KEPT: usize = 2;

/// Path of the snapshot capturing `seq` store rows inside `dir`.
pub fn snapshot_path(dir: &Path, seq: usize) -> PathBuf {
    dir.join(format!("snapshot-{seq}.json"))
}

/// The checksummed payload fields, canonically serialized.
fn payload_json(delta: &SurrogateDelta) -> Json {
    Json::obj(vec![
        (
            "factor",
            match &delta.factor {
                Some(f) => Json::from_f64s(f),
                None => Json::Null,
            },
        ),
        ("hyper", hyper_to_json(&delta.hyper)),
        ("rows", rows_to_json(&delta.rows, &delta.extras)),
        ("seq", (delta.total_n as i64).into()),
        ("version", SNAPSHOT_VERSION.into()),
    ])
}

/// Capture and atomically write one snapshot of `surrogate` into `dir`,
/// pruning all but the newest [`SNAPSHOTS_KEPT`]. Returns the snapshot's
/// `seq` (the store length captured). The capture itself is one short
/// pass under the model lock ([`SharedSurrogate::export_delta`] — it
/// drains pending tells first); serialization and file I/O run off it.
pub fn write_snapshot(surrogate: &SharedSurrogate, dir: &Path) -> Result<usize> {
    let delta = surrogate
        .export_delta(0)
        .expect("export_delta(0) is always satisfiable");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating state dir {}", dir.display()))?;

    // Serialize off the model lock: checksum over the payload without the
    // checksum field, then splice the checksum in as another sorted key.
    let payload = payload_json(&delta);
    let checksum = fnv1a64(payload.to_string().as_bytes());
    let full = match payload {
        Json::Obj(mut map) => {
            map.insert("checksum".to_string(), format!("{checksum:016x}").as_str().into());
            Json::Obj(map)
        }
        _ => unreachable!("payload is an object"),
    };

    let seq = delta.total_n;
    let path = snapshot_path(dir, seq);
    let tmp = dir.join(format!("snapshot-{seq}.json.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(full.to_string().as_bytes()).context("writing snapshot")?;
        f.write_all(b"\n").context("writing snapshot")?;
        f.sync_all().context("fsyncing snapshot")?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing snapshot {}", path.display()))?;
    // Make the rename itself durable (directory metadata).
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }

    for (_, stale_path) in list_snapshots(dir)?.into_iter().skip(SNAPSHOTS_KEPT) {
        std::fs::remove_file(stale_path).ok();
    }
    Ok(seq)
}

/// Snapshots inside `dir`, newest (highest `seq`) first.
pub fn list_snapshots(dir: &Path) -> Result<Vec<(usize, PathBuf)>> {
    let mut found = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(found),
        Err(e) => {
            return Err(e).with_context(|| format!("listing state dir {}", dir.display()))
        }
    };
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(seq) = name
            .strip_prefix("snapshot-")
            .and_then(|rest| rest.strip_suffix(".json"))
            .and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        found.push((seq, path));
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(found)
}

/// Load and validate one snapshot file. Errors cover everything a crash
/// or bit rot can produce: unreadable file, unparsable JSON, checksum
/// mismatch, unknown version, or internally inconsistent counts.
pub fn load_snapshot(path: &Path) -> Result<SurrogateDelta, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let j = parse(text.trim()).map_err(|e| format!("{}: {e}", path.display()))?;

    let version = j
        .get("version")
        .and_then(Json::as_i64)
        .ok_or_else(|| "missing 'version'".to_string())?;
    if version != SNAPSHOT_VERSION {
        return Err(format!("unsupported snapshot version {version}"));
    }
    let stored_sum = j
        .get("checksum")
        .and_then(Json::as_str)
        .ok_or_else(|| "missing 'checksum'".to_string())?
        .to_string();

    let seq = j
        .get("seq")
        .and_then(Json::as_i64)
        .and_then(|n| usize::try_from(n).ok())
        .ok_or_else(|| "missing non-negative 'seq'".to_string())?;
    let hyper = hyper_from_json(j.req("hyper").map_err(|e| e.to_string())?)?;
    let (rows, extras) = rows_from_json(j.req("rows").map_err(|e| e.to_string())?)?;
    let factor = match j.get("factor") {
        None | Some(Json::Null) => None,
        Some(v) => Some(f64_vec(v)?),
    };

    // Verify before trusting the contents: re-serialize the payload
    // canonically (the decode above is bit-exact) and compare checksums.
    let delta = SurrogateDelta {
        from_n: 0,
        total_n: seq,
        hyper,
        rows,
        extras,
        factor,
        leases: Vec::new(),
    };
    let expect = fnv1a64(payload_json(&delta).to_string().as_bytes());
    if format!("{expect:016x}") != stored_sum {
        return Err(format!(
            "checksum mismatch in {} (stored {stored_sum}, computed {expect:016x})",
            path.display()
        ));
    }
    if delta.rows.len() != seq {
        return Err(format!("snapshot seq {seq} disagrees with {} rows", delta.rows.len()));
    }
    Ok(delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::GpHyper;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tftune_snap_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn filled(n: usize, seed: u64) -> SharedSurrogate {
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            let x: Vec<f64> = (0..3).map(|_| rng.f64()).collect();
            let y = (4.0 * x[0]).sin() + 0.2 * x[2];
            shared.tell_multi(x, vec![y, -y, f64::NAN]);
        }
        drop(shared.lock());
        shared
    }

    #[test]
    fn snapshot_round_trip_is_bitwise() {
        let dir = tmp_dir("rt");
        let shared = filled(12, 3);
        let seq = write_snapshot(&shared, &dir).unwrap();
        assert_eq!(seq, 12);
        let delta = load_snapshot(&snapshot_path(&dir, seq)).unwrap();
        assert_eq!(delta.total_n, 12);
        assert!(delta.factor.is_some(), "eagerly factored store exports its factor");

        let want = shared.export_delta(0).unwrap();
        assert_eq!(delta.rows.len(), want.rows.len());
        for ((x, y), (wx, wy)) in delta.rows.iter().zip(&want.rows) {
            assert_eq!(y.to_bits(), wy.to_bits());
            for (a, b) in x.iter().zip(wx) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        for (e, we) in delta.extras.iter().zip(&want.extras) {
            assert_eq!(e.len(), we.len());
            for (a, b) in e.iter().zip(we) {
                assert_eq!(a.to_bits(), b.to_bits(), "extras must round trip bitwise");
            }
        }
        for (a, b) in delta.factor.as_ref().unwrap().iter().zip(want.factor.as_ref().unwrap())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "packed factor must round trip bitwise");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pruning_keeps_the_newest_two() {
        let dir = tmp_dir("prune");
        let shared = SharedSurrogate::new(GpHyper::default());
        let mut rng = Rng::new(5);
        for k in 0..3 {
            for _ in 0..(k + 1) {
                shared.tell(vec![rng.f64(), rng.f64()], rng.f64());
            }
            write_snapshot(&shared, &dir).unwrap();
        }
        let kept = list_snapshots(&dir).unwrap();
        assert_eq!(kept.len(), SNAPSHOTS_KEPT);
        assert_eq!(kept[0].0, 6, "newest first");
        assert_eq!(kept[1].0, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_fails_validation() {
        let dir = tmp_dir("corrupt");
        let shared = filled(6, 9);
        let seq = write_snapshot(&shared, &dir).unwrap();
        let path = snapshot_path(&dir, seq);
        let good = std::fs::read_to_string(&path).unwrap();

        // Flip one digit inside the rows payload.
        let target = good.find("\"rows\"").unwrap();
        let mut bad = good.clone().into_bytes();
        let flip = bad[target..].iter().position(|b| b.is_ascii_digit()).unwrap() + target;
        bad[flip] = if bad[flip] == b'9' { b'8' } else { b'9' };
        std::fs::write(&path, &bad).unwrap();
        let err = load_snapshot(&path).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");

        // Truncated file (torn write that somehow skipped the tmp+rename
        // discipline) fails parse, not a panic.
        std::fs::write(&path, &good.as_bytes()[..good.len() / 2]).unwrap();
        assert!(load_snapshot(&path).is_err());

        // Unknown version is refused.
        std::fs::write(&path, good.replace("\"version\":1", "\"version\":9")).unwrap();
        let err = load_snapshot(&path).unwrap_err();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
