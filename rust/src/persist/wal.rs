//! The write-ahead log: an append-only JSONL file recording every store
//! mutation of the authoritative [`SharedSurrogate`] between snapshots.
//!
//! Record shapes reuse the `History`/`Evaluation` JSONL vocabulary
//! (`"value"` / `"objectives"`, NaN travelling as `null`) and the
//! surrogate wire codec for hypers, so every f64 — including packed
//! factor inputs — survives the file bit-exactly (shortest-round-trip
//! encode, correctly-rounded parse; pinned in `server::proto`):
//!
//! ```text
//! {"kind":"tell","x":[...],"value":<f64>[,"objectives":[<f64>|null,...]]}
//! {"kind":"set-hyper","hyper":{...}}
//! ```
//!
//! `x` is the observation in unit-cube coordinates, `value` the primary
//! objective, `objectives` the *secondary* columns (present only for
//! multi-objective rows — mirrors the optional `"ys"` of the wire's
//! `tell-obs`). The log is strictly ordered: the journal hook appends
//! under the model-state lock, so WAL record order *is* store mutation
//! order, and the number of `tell` records equals the store length.
//!
//! A reader tolerates a **torn tail** — a partial line from a crash
//! mid-write — by reporting the byte length of the valid prefix;
//! recovery truncates the file there. A writer that hits an I/O error
//! poisons itself (no further appends) rather than leaving a hole in
//! the middle of the log: a WAL must always be a *prefix* of the true
//! history, never a subsequence.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::gp::GpHyper;
use crate::server::proto::{
    f64_vec, hyper_from_json, hyper_to_json, ys_from_json, ys_to_json,
};
use crate::util::json::{parse, Json};

/// File name of the write-ahead log inside a state directory.
pub const WAL_FILE: &str = "wal.jsonl";

/// Path of the write-ahead log inside `dir`.
pub fn wal_path(dir: &Path) -> PathBuf {
    dir.join(WAL_FILE)
}

/// One durable store mutation (module docs for the wire shape).
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// An observation row appended to the canonical store. `objectives`
    /// holds the secondary columns only (empty = single-objective row;
    /// NaN = declared column the trial could not measure).
    Tell { x: Vec<f64>, value: f64, objectives: Vec<f64> },
    /// The model switched hyperparameters.
    SetHyper(GpHyper),
}

impl WalRecord {
    /// One JSONL line, no trailing newline.
    pub fn encode(&self) -> String {
        match self {
            WalRecord::Tell { x, value, objectives } => {
                let mut pairs = vec![
                    ("kind", "tell".into()),
                    ("x", Json::from_f64s(x)),
                    ("value", (*value).into()),
                ];
                if !objectives.is_empty() {
                    pairs.push(("objectives", ys_to_json(objectives)));
                }
                Json::obj(pairs).to_string()
            }
            WalRecord::SetHyper(h) => Json::obj(vec![
                ("kind", "set-hyper".into()),
                ("hyper", hyper_to_json(h)),
            ])
            .to_string(),
        }
    }

    pub fn decode(line: &str) -> Result<WalRecord, String> {
        let j = parse(line).map_err(|e| e.to_string())?;
        match j.get("kind").and_then(Json::as_str) {
            Some("tell") => Ok(WalRecord::Tell {
                x: f64_vec(j.req("x").map_err(|e| e.to_string())?)?,
                value: j
                    .get("value")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| "missing number 'value'".to_string())?,
                objectives: match j.get("objectives") {
                    Some(v) => ys_from_json(v)?,
                    None => Vec::new(),
                },
            }),
            Some("set-hyper") => Ok(WalRecord::SetHyper(
                hyper_from_json(j.req("hyper").map_err(|e| e.to_string())?)?,
            )),
            other => Err(format!("unknown WAL record kind {other:?}")),
        }
    }
}

/// The decoded contents of a write-ahead log.
pub struct WalContents {
    /// Every record in the valid prefix, in append (= store) order.
    pub records: Vec<WalRecord>,
    /// Byte length of the valid prefix (complete, decodable lines).
    pub valid_len: u64,
    /// Whether bytes past `valid_len` exist — a torn tail from a crash
    /// mid-append (or garbage). Recovery truncates the file there.
    pub torn: bool,
}

impl WalContents {
    /// Number of `tell` records — equals the store length the log
    /// describes (the journal appends exactly one per stored row).
    pub fn tell_count(&self) -> usize {
        self.records.iter().filter(|r| matches!(r, WalRecord::Tell { .. })).count()
    }
}

/// Read the WAL at `path`, stopping at the first incomplete or
/// undecodable line. A missing file reads as an empty, untorn log.
pub fn read_wal(path: &Path) -> Result<WalContents> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)
                .with_context(|| format!("reading WAL {}", path.display()))?;
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalContents { records: Vec::new(), valid_len: 0, torn: false });
        }
        Err(e) => {
            return Err(e).with_context(|| format!("opening WAL {}", path.display()))
        }
    }
    let mut records = Vec::new();
    let mut valid_len = 0u64;
    let mut offset = 0usize;
    while let Some(nl) = bytes[offset..].iter().position(|&b| b == b'\n') {
        let line = &bytes[offset..offset + nl];
        let decoded = std::str::from_utf8(line).ok().and_then(|s| {
            let s = s.trim();
            if s.is_empty() { None } else { WalRecord::decode(s).ok() }
        });
        match decoded {
            Some(rec) => {
                records.push(rec);
                offset += nl + 1;
                valid_len = offset as u64;
            }
            // An undecodable *complete* line means everything after it is
            // suspect too — treat it as the start of the torn tail.
            None => break,
        }
    }
    let torn = valid_len < bytes.len() as u64;
    Ok(WalContents { records, valid_len, torn })
}

/// Truncate the WAL at `path` to `valid_len` bytes (drop a torn tail).
pub fn truncate_wal(path: &Path, valid_len: u64) -> Result<()> {
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening WAL {} for truncation", path.display()))?;
    f.set_len(valid_len).context("truncating torn WAL tail")?;
    f.sync_all().context("syncing truncated WAL")?;
    Ok(())
}

/// Appender for the write-ahead log, with a configurable fsync cadence.
///
/// `fsync_every = n` flushes *and fsyncs* after every `n` appended
/// records (1 = maximum durability: every record is on disk before the
/// measurement that produced it can be acted on further); `0` buffers
/// until an explicit [`WalWriter::sync`] or drop — fastest, but a crash
/// loses the buffered tail (recovery still restores a consistent prefix).
pub struct WalWriter {
    out: BufWriter<File>,
    fsync_every: usize,
    unsynced: usize,
    appended: u64,
    failed: bool,
}

impl WalWriter {
    /// Open (append, create) the WAL inside `dir`.
    pub fn open(dir: &Path, fsync_every: usize) -> Result<WalWriter> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let path = wal_path(dir);
        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        Ok(WalWriter {
            out: BufWriter::new(file),
            fsync_every,
            unsynced: 0,
            appended: 0,
            failed: false,
        })
    }

    /// Records appended through this writer since it was opened (not the
    /// on-disk total — re-opening starts the count at zero). The
    /// observability plane reports this gauge in `wal-sync` events.
    pub fn appended(&self) -> u64 {
        self.appended
    }

    /// Append one record, honouring the fsync cadence. Best-effort: an
    /// I/O error *poisons* the writer (all further appends are dropped
    /// with one warning) so the log stays a prefix of the true history —
    /// a hole in the middle would replay to a silently different model.
    pub fn append(&mut self, record: &WalRecord) {
        if self.failed {
            return;
        }
        let result = writeln!(self.out, "{}", record.encode()).and_then(|()| {
            self.appended += 1;
            self.unsynced += 1;
            if self.fsync_every > 0 && self.unsynced >= self.fsync_every {
                self.unsynced = 0;
                self.out.flush()?;
                self.out.get_ref().sync_data()?;
            }
            Ok(())
        });
        if let Err(e) = result {
            self.failed = true;
            eprintln!(
                "tftune: write-ahead log failed ({e}); journaling disabled — durability \
                 degrades to snapshots only"
            );
        }
    }

    /// Whether an I/O error has poisoned this writer.
    pub fn is_failed(&self) -> bool {
        self.failed
    }

    /// Flush buffered records and fsync now (snapshot boundary, shutdown).
    pub fn sync(&mut self) -> Result<()> {
        if self.failed {
            anyhow::bail!("write-ahead log writer poisoned by an earlier I/O error");
        }
        self.unsynced = 0;
        self.out.flush().context("flushing WAL")?;
        self.out.get_ref().sync_data().context("fsyncing WAL")?;
        Ok(())
    }
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        if !self.failed {
            let _ = self.out.flush();
            let _ = self.out.get_ref().sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::UNBOUNDED_HISTORY;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tftune_wal_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn record_round_trip_bitwise() {
        let recs = [
            WalRecord::Tell { x: vec![0.25, 1e-300, -3.5], value: 0.1 + 0.2, objectives: Vec::new() },
            WalRecord::Tell { x: vec![0.5], value: -1.0, objectives: vec![f64::NAN, 2.5] },
            WalRecord::SetHyper(GpHyper { lengthscale: 0.35, ..GpHyper::default() }),
            WalRecord::SetHyper(GpHyper {
                max_history: UNBOUNDED_HISTORY,
                ..GpHyper::default()
            }),
        ];
        for rec in &recs {
            let line = rec.encode();
            let back = WalRecord::decode(&line).unwrap();
            match (rec, &back) {
                (
                    WalRecord::Tell { x, value, objectives },
                    WalRecord::Tell { x: x2, value: v2, objectives: o2 },
                ) => {
                    assert_eq!(value.to_bits(), v2.to_bits(), "line: {line}");
                    for (a, b) in x.iter().zip(x2) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                    assert_eq!(objectives.len(), o2.len());
                    for (a, b) in objectives.iter().zip(o2) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                _ => assert_eq!(rec, &back, "line: {line}"),
            }
        }
        assert!(WalRecord::decode("not json").is_err());
        assert!(WalRecord::decode(r#"{"kind":"nope"}"#).is_err());
    }

    #[test]
    fn writer_reader_round_trip_and_missing_file() {
        let dir = tmp_dir("rt");
        let empty = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(empty.records.len(), 0);
        assert!(!empty.torn);

        let mut w = WalWriter::open(&dir, 1).unwrap();
        w.append(&WalRecord::Tell { x: vec![0.1, 0.9], value: 2.0, objectives: Vec::new() });
        w.append(&WalRecord::SetHyper(GpHyper::default()));
        w.append(&WalRecord::Tell { x: vec![0.4, 0.2], value: 3.0, objectives: vec![1.5] });
        drop(w);
        let back = read_wal(&wal_path(&dir)).unwrap();
        assert_eq!(back.records.len(), 3);
        assert_eq!(back.tell_count(), 2);
        assert!(!back.torn);

        // Re-opening appends, never truncates.
        let mut w = WalWriter::open(&dir, 0).unwrap();
        w.append(&WalRecord::Tell { x: vec![0.7, 0.7], value: 4.0, objectives: Vec::new() });
        w.sync().unwrap();
        assert_eq!(read_wal(&wal_path(&dir)).unwrap().tell_count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_reported_and_truncated() {
        let dir = tmp_dir("torn");
        let mut w = WalWriter::open(&dir, 1).unwrap();
        w.append(&WalRecord::Tell { x: vec![0.1], value: 1.0, objectives: Vec::new() });
        w.append(&WalRecord::Tell { x: vec![0.2], value: 2.0, objectives: Vec::new() });
        drop(w);
        let path = wal_path(&dir);
        let good_len = std::fs::metadata(&path).unwrap().len();
        // Crash mid-append: a partial record with no newline.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(br#"{"kind":"tell","x":[0."#).unwrap();
        drop(f);

        let contents = read_wal(&path).unwrap();
        assert_eq!(contents.records.len(), 2);
        assert_eq!(contents.valid_len, good_len);
        assert!(contents.torn);
        truncate_wal(&path, contents.valid_len).unwrap();
        let clean = read_wal(&path).unwrap();
        assert_eq!(clean.records.len(), 2);
        assert!(!clean.torn);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn garbage_line_marks_the_tail_torn() {
        let dir = tmp_dir("garbage");
        let path = wal_path(&dir);
        let good = WalRecord::Tell { x: vec![0.3], value: 1.0, objectives: Vec::new() };
        std::fs::write(&path, format!("{}\nthis is not json\n{}\n", good.encode(), good.encode()))
            .unwrap();
        let contents = read_wal(&path).unwrap();
        // Everything after the first bad line is suspect, even if it
        // parses: the log is a prefix, never a subsequence.
        assert_eq!(contents.records.len(), 1);
        assert!(contents.torn);
        std::fs::remove_dir_all(&dir).ok();
    }
}
