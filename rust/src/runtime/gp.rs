//! The AOT GP surrogate: executes `artifacts/gp.hlo.txt` (L2 JAX graph
//! containing the L1 Pallas RBF kernel) via PJRT on every BO iteration.
//!
//! The artifact is monomorphic: N_PAD history slots, D_FEAT features,
//! C_CAND candidates (shape contract read from meta.json and asserted
//! here). This wrapper pads/masks the live history, marshals buffers, and
//! unpacks the (mu, sigma, gain) tuple.
//!
//! Hyperparameters are *runtime inputs* (the `hyper_v` vector below), not
//! compile-time constants — which is what lets
//! `BayesOpt::with_lengthscale_selection` (and the CLI's
//! `--tune-lengthscale`) drive the existing log-marginal-likelihood grid
//! search on this path with **zero recompilation**: the engine re-selects
//! the lengthscale as history grows and the same compiled graph scores
//! under the new value. Pinned native-vs-artifact in
//! `rust/tests/artifact_gp.rs`.

use anyhow::{Context, Result};

use super::{literal_f32, Runtime};
use crate::gp::{GpHyper, KernelKind, Scores, Surrogate};
use crate::util::Json;

pub struct GpSurrogate {
    exe: xla::PjRtLoadedExecutable,
    pub n_pad: usize,
    pub d_feat: usize,
    pub c_cand: usize,
}

/// One compiled capacity of the GP graph, as declared by meta.json.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GpVariant {
    pub n_pad: usize,
    pub file: String,
}

/// The capacities meta.json declares: the base artifact plus every entry
/// of the optional `variants` list (pre-variant meta.json files have
/// none), deduplicated and sorted ascending by `n_pad`.
fn declared_variants(gp_meta: &Json) -> Result<Vec<GpVariant>> {
    let base_n = gp_meta
        .req("n_pad")
        .map_err(anyhow::Error::msg)?
        .as_i64()
        .unwrap_or(0) as usize;
    let base_file = gp_meta
        .get("file")
        .and_then(Json::as_str)
        .unwrap_or("gp.hlo.txt")
        .to_string();
    let mut variants = vec![GpVariant { n_pad: base_n, file: base_file }];
    if let Some(list) = gp_meta.get("variants").and_then(Json::as_arr) {
        for v in list {
            let n_pad = v
                .get("n_pad")
                .and_then(Json::as_i64)
                .context("gp variant missing 'n_pad'")? as usize;
            let file = v
                .get("file")
                .and_then(Json::as_str)
                .context("gp variant missing 'file'")?
                .to_string();
            variants.push(GpVariant { n_pad, file });
        }
    }
    variants.sort_by_key(|v| v.n_pad);
    variants.dedup_by_key(|v| v.n_pad);
    Ok(variants)
}

/// Pick the smallest declared capacity covering `window` — compiling a
/// 256-slot graph to serve a 65-point window would pay 4x the matmul cost
/// of the 128-slot one for nothing.
fn select_variant(gp_meta: &Json, window: usize) -> Result<GpVariant> {
    let variants = declared_variants(gp_meta)?;
    let largest = variants.last().map(|v| v.n_pad).unwrap_or(0);
    let picked = variants.into_iter().find(|v| v.n_pad >= window);
    picked.with_context(|| {
        format!(
            "no GP artifact variant covers a {window}-point window (largest compiled \
             capacity is {largest}); add the capacity to GP_VARIANTS and rebuild artifacts"
        )
    })
}

impl GpSurrogate {
    /// Compile the GP artifact from a runtime.
    pub fn load(rt: &Runtime) -> Result<GpSurrogate> {
        let gp_meta = rt.meta().get("gp").context("meta.json missing 'gp'")?;
        let n_pad = gp_meta.req("n_pad").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let d_feat = gp_meta.req("d_feat").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let c_cand = gp_meta.req("c_cand").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let file = gp_meta
            .get("file")
            .and_then(crate::util::Json::as_str)
            .unwrap_or("gp.hlo.txt")
            .to_string();
        let exe = rt.compile(&file)?;
        Ok(GpSurrogate { exe, n_pad, d_feat, c_cand })
    }

    /// Convenience: open the default runtime and load.
    pub fn open_default() -> Result<GpSurrogate> {
        let rt = Runtime::open_default()?;
        GpSurrogate::load(&rt)
    }

    /// Compile the smallest artifact variant whose capacity covers a
    /// `window`-point conditioning window (`GpHyper::max_history`). With
    /// a pre-variant meta.json this degrades to [`GpSurrogate::load`]
    /// when the base capacity suffices, and errors otherwise.
    pub fn load_for_window(rt: &Runtime, window: usize) -> Result<GpSurrogate> {
        let gp_meta = rt.meta().get("gp").context("meta.json missing 'gp'")?;
        let variant = select_variant(gp_meta, window)?;
        let d_feat = gp_meta
            .req("d_feat")
            .map_err(anyhow::Error::msg)?
            .as_i64()
            .unwrap() as usize;
        let c_cand = gp_meta
            .req("c_cand")
            .map_err(anyhow::Error::msg)?
            .as_i64()
            .unwrap() as usize;
        let exe = rt.compile(&variant.file)?;
        Ok(GpSurrogate { exe, n_pad: variant.n_pad, d_feat, c_cand })
    }

    /// Execute the artifact on padded buffers. x rows must already be in
    /// [0,1]^d with d <= d_feat; y standardised.
    fn execute(
        &self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> Result<Scores> {
        // The artifact is monomorphic over the shared GpHyper contract:
        // its graph hard-codes the RBF kernel and N_PAD history slots, so
        // reject hypers the compiled graph cannot represent instead of
        // silently computing something else than the native stack would.
        anyhow::ensure!(
            hyper.kernel == KernelKind::Rbf,
            "AOT GP artifact implements only the RBF kernel, got {}",
            hyper.kernel.name()
        );
        anyhow::ensure!(
            hyper.max_history <= self.n_pad,
            "surrogate window {} exceeds artifact N_PAD {}; recompile the artifact or \
             narrow the window (GpHyper.max_history)",
            hyper.max_history,
            self.n_pad
        );
        let n = x.len();
        anyhow::ensure!(n > 0, "empty history");
        anyhow::ensure!(n <= self.n_pad, "history {n} exceeds artifact N_PAD {}", self.n_pad);
        anyhow::ensure!(
            cand.len() <= self.c_cand,
            "candidates {} exceed artifact C_CAND {}",
            cand.len(),
            self.c_cand
        );
        let d = x.first().map(|r| r.len()).unwrap_or(0);
        anyhow::ensure!(d <= self.d_feat, "feature dim {d} exceeds artifact D_FEAT");

        // Pad xtr / ytr / mask to N_PAD, candidates to C_CAND.
        let mut xtr = vec![0f32; self.n_pad * self.d_feat];
        for (i, row) in x.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                xtr[i * self.d_feat + j] = v as f32;
            }
        }
        let mut ytr = vec![0f32; self.n_pad];
        let mut mask = vec![0f32; self.n_pad];
        for (i, &v) in y.iter().enumerate() {
            ytr[i] = v as f32;
            mask[i] = 1.0;
        }
        let mut xc = vec![0f32; self.c_cand * self.d_feat];
        for (i, row) in cand.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                xc[i * self.d_feat + j] = v as f32;
            }
        }
        let hyper_v = [
            hyper.lengthscale as f32,
            hyper.signal_var as f32,
            hyper.noise_var as f32,
            acq_alpha as f32,
            y_best as f32,
        ];

        let args = [
            literal_f32(&xtr, &[self.n_pad as i64, self.d_feat as i64])?,
            literal_f32(&ytr, &[self.n_pad as i64])?,
            literal_f32(&mask, &[self.n_pad as i64])?,
            literal_f32(&xc, &[self.c_cand as i64, self.d_feat as i64])?,
            literal_f32(&hyper_v, &[5])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching GP result")?;
        let (mu_l, sigma_l, gain_l) = result.to_tuple3().context("unpacking GP tuple")?;
        let mu: Vec<f32> = mu_l.to_vec()?;
        let sigma: Vec<f32> = sigma_l.to_vec()?;
        let gain: Vec<f32> = gain_l.to_vec()?;

        let take = cand.len();
        Ok(Scores {
            mean: mu[..take].iter().map(|&v| v as f64).collect(),
            std: sigma[..take].iter().map(|&v| v as f64).collect(),
            gain: gain[..take].iter().map(|&v| v as f64).collect(),
        })
    }
}

impl Surrogate for GpSurrogate {
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> Result<Scores> {
        self.execute(x, y, cand, hyper, acq_alpha, y_best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn variant_meta() -> Json {
        // The 'gp' section aot.py writes for GP_VARIANTS = (64, 128, 256).
        parse(
            r#"{"n_pad":64,"d_feat":8,"c_cand":512,"file":"gp.hlo.txt",
                "variants":[
                  {"n_pad":64,"cg_iters":32,"file":"gp.hlo.txt"},
                  {"n_pad":128,"cg_iters":48,"file":"gp_n128.hlo.txt"},
                  {"n_pad":256,"cg_iters":64,"file":"gp_n256.hlo.txt"}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn selects_smallest_covering_variant() {
        let meta = variant_meta();
        let pick = |w| select_variant(&meta, w).unwrap();
        assert_eq!(pick(1), GpVariant { n_pad: 64, file: "gp.hlo.txt".into() });
        assert_eq!(pick(64), GpVariant { n_pad: 64, file: "gp.hlo.txt".into() });
        assert_eq!(pick(65), GpVariant { n_pad: 128, file: "gp_n128.hlo.txt".into() });
        assert_eq!(pick(128), GpVariant { n_pad: 128, file: "gp_n128.hlo.txt".into() });
        assert_eq!(pick(256), GpVariant { n_pad: 256, file: "gp_n256.hlo.txt".into() });
    }

    #[test]
    fn oversized_window_names_the_largest_capacity() {
        let err = select_variant(&variant_meta(), 257).unwrap_err().to_string();
        assert!(err.contains("257-point window"), "{err}");
        assert!(err.contains("largest compiled capacity is 256"), "{err}");
    }

    #[test]
    fn pre_variant_meta_degrades_to_the_base_artifact() {
        // An older meta.json: no 'variants' list, no explicit 'file'.
        let meta = parse(r#"{"n_pad":64,"d_feat":8,"c_cand":512}"#).unwrap();
        let v = select_variant(&meta, 40).unwrap();
        assert_eq!(v, GpVariant { n_pad: 64, file: "gp.hlo.txt".into() });
        assert!(select_variant(&meta, 65).is_err());
    }
}
