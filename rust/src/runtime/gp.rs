//! The AOT GP surrogate: executes `artifacts/gp.hlo.txt` (L2 JAX graph
//! containing the L1 Pallas RBF kernel) via PJRT on every BO iteration.
//!
//! The artifact is monomorphic: N_PAD history slots, D_FEAT features,
//! C_CAND candidates (shape contract read from meta.json and asserted
//! here). This wrapper pads/masks the live history, marshals buffers, and
//! unpacks the (mu, sigma, gain) tuple.
//!
//! Hyperparameters are *runtime inputs* (the `hyper_v` vector below), not
//! compile-time constants — which is what lets
//! `BayesOpt::with_lengthscale_selection` (and the CLI's
//! `--tune-lengthscale`) drive the existing log-marginal-likelihood grid
//! search on this path with **zero recompilation**: the engine re-selects
//! the lengthscale as history grows and the same compiled graph scores
//! under the new value. Pinned native-vs-artifact in
//! `rust/tests/artifact_gp.rs`.

use anyhow::{Context, Result};

use super::{literal_f32, Runtime};
use crate::gp::{GpHyper, KernelKind, Scores, Surrogate};

pub struct GpSurrogate {
    exe: xla::PjRtLoadedExecutable,
    pub n_pad: usize,
    pub d_feat: usize,
    pub c_cand: usize,
}

impl GpSurrogate {
    /// Compile the GP artifact from a runtime.
    pub fn load(rt: &Runtime) -> Result<GpSurrogate> {
        let gp_meta = rt.meta().get("gp").context("meta.json missing 'gp'")?;
        let n_pad = gp_meta.req("n_pad").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let d_feat = gp_meta.req("d_feat").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let c_cand = gp_meta.req("c_cand").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let file = gp_meta
            .get("file")
            .and_then(crate::util::Json::as_str)
            .unwrap_or("gp.hlo.txt")
            .to_string();
        let exe = rt.compile(&file)?;
        Ok(GpSurrogate { exe, n_pad, d_feat, c_cand })
    }

    /// Convenience: open the default runtime and load.
    pub fn open_default() -> Result<GpSurrogate> {
        let rt = Runtime::open_default()?;
        GpSurrogate::load(&rt)
    }

    /// Execute the artifact on padded buffers. x rows must already be in
    /// [0,1]^d with d <= d_feat; y standardised.
    fn execute(
        &self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> Result<Scores> {
        // The artifact is monomorphic over the shared GpHyper contract:
        // its graph hard-codes the RBF kernel and N_PAD history slots, so
        // reject hypers the compiled graph cannot represent instead of
        // silently computing something else than the native stack would.
        anyhow::ensure!(
            hyper.kernel == KernelKind::Rbf,
            "AOT GP artifact implements only the RBF kernel, got {}",
            hyper.kernel.name()
        );
        anyhow::ensure!(
            hyper.max_history <= self.n_pad,
            "surrogate window {} exceeds artifact N_PAD {}; recompile the artifact or \
             narrow the window (GpHyper.max_history)",
            hyper.max_history,
            self.n_pad
        );
        let n = x.len();
        anyhow::ensure!(n > 0, "empty history");
        anyhow::ensure!(n <= self.n_pad, "history {n} exceeds artifact N_PAD {}", self.n_pad);
        anyhow::ensure!(
            cand.len() <= self.c_cand,
            "candidates {} exceed artifact C_CAND {}",
            cand.len(),
            self.c_cand
        );
        let d = x.first().map(|r| r.len()).unwrap_or(0);
        anyhow::ensure!(d <= self.d_feat, "feature dim {d} exceeds artifact D_FEAT");

        // Pad xtr / ytr / mask to N_PAD, candidates to C_CAND.
        let mut xtr = vec![0f32; self.n_pad * self.d_feat];
        for (i, row) in x.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                xtr[i * self.d_feat + j] = v as f32;
            }
        }
        let mut ytr = vec![0f32; self.n_pad];
        let mut mask = vec![0f32; self.n_pad];
        for (i, &v) in y.iter().enumerate() {
            ytr[i] = v as f32;
            mask[i] = 1.0;
        }
        let mut xc = vec![0f32; self.c_cand * self.d_feat];
        for (i, row) in cand.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                xc[i * self.d_feat + j] = v as f32;
            }
        }
        let hyper_v = [
            hyper.lengthscale as f32,
            hyper.signal_var as f32,
            hyper.noise_var as f32,
            acq_alpha as f32,
            y_best as f32,
        ];

        let args = [
            literal_f32(&xtr, &[self.n_pad as i64, self.d_feat as i64])?,
            literal_f32(&ytr, &[self.n_pad as i64])?,
            literal_f32(&mask, &[self.n_pad as i64])?,
            literal_f32(&xc, &[self.c_cand as i64, self.d_feat as i64])?,
            literal_f32(&hyper_v, &[5])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching GP result")?;
        let (mu_l, sigma_l, gain_l) = result.to_tuple3().context("unpacking GP tuple")?;
        let mu: Vec<f32> = mu_l.to_vec()?;
        let sigma: Vec<f32> = sigma_l.to_vec()?;
        let gain: Vec<f32> = gain_l.to_vec()?;

        let take = cand.len();
        Ok(Scores {
            mean: mu[..take].iter().map(|&v| v as f64).collect(),
            std: sigma[..take].iter().map(|&v| v as f64).collect(),
            gain: gain[..take].iter().map(|&v| v as f64).collect(),
        })
    }
}

impl Surrogate for GpSurrogate {
    fn fit_score(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        cand: &[Vec<f64>],
        hyper: GpHyper,
        acq_alpha: f64,
        y_best: f64,
    ) -> Result<Scores> {
        self.execute(x, y, cand, hyper, acq_alpha, y_best)
    }
}
