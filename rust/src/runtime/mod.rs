//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! This is the bridge between L3 (this crate) and L2/L1 (the JAX + Pallas
//! graphs lowered by `python/compile/aot.py`). Artifacts are HLO *text* —
//! the only interchange format xla_extension 0.5.1 accepts from jax ≥ 0.5
//! protos (see /opt/xla-example/README.md). Each artifact compiles once at
//! load time into a `PjRtLoadedExecutable`; executions after that are
//! pure C++ with no Python anywhere.

pub mod gp;
pub mod workload;

pub use gp::GpSurrogate;
pub use workload::WorkloadRunner;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Shared PJRT CPU client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    meta: Json,
}

impl Runtime {
    /// Create a CPU PJRT client and read `meta.json` from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {} (run `make artifacts`)", meta_path.display()))?;
        let meta = parse(&text).map_err(|e| anyhow::anyhow!("parsing meta.json: {e}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), meta })
    }

    /// Open the default `artifacts/` directory, searching upward from the
    /// current directory (so tests and examples work from any cwd).
    pub fn open_default() -> Result<Runtime> {
        let dir = find_artifacts_dir()
            .context("artifacts/ not found; run `make artifacts` first")?;
        Runtime::new(&dir)
    }

    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// Search for `artifacts/meta.json` in cwd and up to 4 parent directories.
pub fn find_artifacts_dir() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    for _ in 0..5 {
        let cand = dir.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("meta.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            break;
        }
    }
    // also try the crate root at compile time (tests run from target dirs)
    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR);
    if crate_dir.join("meta.json").exists() {
        return Some(crate_dir);
    }
    None
}

/// Flatten an f32 slice into a Literal of the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_artifacts_dir_from_manifest() {
        // artifacts/ is built before cargo test in the Makefile.
        if let Some(dir) = find_artifacts_dir() {
            assert!(dir.join("meta.json").exists());
        }
    }

    #[test]
    fn literal_f32_shape_check() {
        assert!(literal_f32(&[1.0, 2.0], &[2, 2]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
