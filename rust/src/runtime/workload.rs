//! Real tunable workload: the AOT-compiled MLP inference graphs
//! (`artifacts/workload_b{B}.hlo.txt`), one executable per batch size.
//!
//! This is the *measurable* system-under-test for the end-to-end example:
//! the tuner varies batch size, the runner executes the actual PJRT
//! executable and reports measured examples/second — real numbers from a
//! real system, no simulator involved.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{Context, Result};

use super::{literal_f32, Runtime};
use crate::util::{Json, Rng};

pub struct WorkloadRunner {
    /// Compiled executable + prepared input literals per batch size.
    exes: BTreeMap<i64, (xla::PjRtLoadedExecutable, Vec<xla::Literal>)>,
    pub batches: Vec<i64>,
    pub d_in: usize,
    pub d_out: usize,
    pub flops_per_example: f64,
}

impl WorkloadRunner {
    pub fn load(rt: &Runtime) -> Result<WorkloadRunner> {
        let meta = rt.meta().get("workload").context("meta.json missing 'workload'")?;
        let batches: Vec<i64> = meta
            .req("batches")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("batches not an array")?
            .iter()
            .filter_map(Json::as_i64)
            .collect();
        let d_in = meta.req("d_in").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let d_hidden =
            meta.req("d_hidden").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let d_out = meta.req("d_out").map_err(anyhow::Error::msg)?.as_i64().unwrap() as usize;
        let flops_per_example = meta
            .req("flops_per_example")
            .map_err(anyhow::Error::msg)?
            .as_f64()
            .unwrap();

        // Deterministic random weights shared across batch variants.
        let mut rng = Rng::new(0xD00D);
        let mut gen = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let w1 = gen(d_in * d_hidden, 0.1);
        let b1 = gen(d_hidden, 0.01);
        let w2 = gen(d_hidden * d_hidden, 0.05);
        let b2 = gen(d_hidden, 0.01);
        let w3 = gen(d_hidden * d_out, 0.1);
        let b3 = gen(d_out, 0.01);

        let mut exes = BTreeMap::new();
        for &b in &batches {
            let file = format!("workload_b{b}.hlo.txt");
            let exe = rt.compile(&file)?;
            let x = gen(b as usize * d_in, 1.0);
            let args = vec![
                literal_f32(&x, &[b, d_in as i64])?,
                literal_f32(&w1, &[d_in as i64, d_hidden as i64])?,
                literal_f32(&b1, &[d_hidden as i64])?,
                literal_f32(&w2, &[d_hidden as i64, d_hidden as i64])?,
                literal_f32(&b2, &[d_hidden as i64])?,
                literal_f32(&w3, &[d_hidden as i64, d_out as i64])?,
                literal_f32(&b3, &[d_out as i64])?,
            ];
            exes.insert(b, (exe, args));
        }
        Ok(WorkloadRunner { exes, batches, d_in, d_out, flops_per_example })
    }

    pub fn open_default() -> Result<WorkloadRunner> {
        let rt = Runtime::open_default()?;
        WorkloadRunner::load(&rt)
    }

    /// Run one inference at the given batch size; returns the output
    /// probabilities (sanity: batch * d_out values, rows sum to 1).
    pub fn run_once(&self, batch: i64) -> Result<Vec<f32>> {
        let (exe, args) = self
            .exes
            .get(&batch)
            .with_context(|| format!("no compiled workload for batch {batch}"))?;
        let out = exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Measure throughput (examples/s) at a batch size: `reps` timed
    /// executions after one warmup.
    pub fn measure_throughput(&self, batch: i64, reps: usize) -> Result<f64> {
        let (exe, args) = self
            .exes
            .get(&batch)
            .with_context(|| format!("no compiled workload for batch {batch}"))?;
        // warmup
        let _ = exe.execute::<xla::Literal>(args)?;
        let t0 = Instant::now();
        for _ in 0..reps.max(1) {
            let bufs = exe.execute::<xla::Literal>(args)?;
            // Force completion by materialising the literal.
            let _ = bufs[0][0].to_literal_sync()?;
        }
        let dt = t0.elapsed().as_secs_f64();
        Ok(batch as f64 * reps.max(1) as f64 / dt)
    }
}
