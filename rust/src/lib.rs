//! tftune: gradient-free auto-tuning of a TensorFlow-style CPU backend.
//!
//! Reproduction of "Automatic Tuning of TensorFlow's CPU Backend using
//! Gradient-Free Optimization Algorithms" (Mebratu et al., MLHPCS/ISC 2021)
//! as a three-layer Rust + JAX + Pallas system. `ARCHITECTURE.md` at the
//! repo root is the guided tour (layer map, trial lifecycle, surrogate
//! contract); this page is the API-level summary.
//!
//! Layers:
//! - L3 (this crate): the tuning coordinator — search space, BO/GA/NMS
//!   engines, evaluation history, the host/target protocol, the
//!   system-under-test simulator substrate, and figure/table harnesses.
//! - L2 (python/compile/model.py): the Gaussian-process surrogate
//!   fit+predict+acquisition graph, AOT-lowered to HLO text at build time.
//! - L1 (python/compile/kernels/rbf.py): the Pallas RBF kernel-matrix
//!   kernel invoked from the L2 graph.
//!
//! Python is never on the tuning request path: the Rust BO engine executes
//! the AOT-compiled GP artifact via PJRT (`runtime`).
//!
//! # The ask/tell trial model
//!
//! The tuning core is an *ask/tell* conversation between an engine and a
//! driver. [`algorithms::Tuner::ask`]`(n)` yields up to `n` [`Trial`]s —
//! grid configurations tagged with engine-unique ids — and
//! [`algorithms::Tuner::tell`]`(id, &Measurement)` reports results back in
//! *any* order. [`Measurement`] replaces the old bare-`f64` objective: it
//! carries the value, what the value means, its wall-clock cost, and
//! optional metadata, and is recorded per trial in [`History`].
//!
//! [`TuningSession`] is the production driver: it owns an engine, a pool
//! of [`evaluator::Evaluator`]s (worker threads for in-process targets,
//! one TCP connection per remote daemon), and a [`Budget`] (evaluation
//! cap, wall-clock limit, plateau stop), keeping one trial in flight per
//! evaluator and streaming completions through a per-trial callback.
//! [`SessionGroup`] drives several sessions concurrently on one host.
//!
//! # The surrogate subsystem
//!
//! The GP surrogate is the numeric hot path of the whole system (the
//! paper's central result is that BO wins on most models), so it is its
//! own subsystem under [`gp`], with interchangeable roles driven by one
//! shared hyperparameter bundle ([`gp::GpHyper`]: kernel kind,
//! lengthscale, noise, conditioning window):
//!
//! - **Incremental engine model** ([`gp::IncrementalGp`]) — the
//!   persistent model conditioned across a run. `tell` folds an
//!   observation in as an O(n²) rank-1 Cholesky append; batched `ask`s
//!   condition on in-flight trials by extending the factor with
//!   constant-liar fantasies and retracting them after scoring; the
//!   candidate pool is scored by a blocked scoring engine — one
//!   cache-tiled cross-kernel panel + multi-RHS triangular solve over
//!   reused buffers ([`gp::ScoreWorkspace`]) that never grow once
//!   warmed, optionally partitioned across threads (bit-identical to
//!   serial for any count) with an opt-in f32 ranking tier
//!   ([`gp::ScoreTier`]).
//! - **Shared concurrent handle** ([`gp::SharedSurrogate`]) — `BayesOpt`
//!   *borrows* the model through the [`gp::SurrogateHandle`] contract
//!   instead of owning it, so an evaluator pool, remote daemons and whole
//!   concurrent sessions ([`SessionGroup`]) can condition **one** factor:
//!   tells enqueue without blocking a scoring pass; each ask drains the
//!   queue in observation order and scores under an exclusive guard.
//! - **Served factor replica** ([`gp::RemoteSurrogate`]) — the same
//!   handle contract against a factor hosted by a *surrogate service*
//!   (`server`, `surrogate-serve`): separate tuner processes or hosts
//!   tell into one model over TCP, catch up via packed-factor suffix
//!   deltas, and lease their in-flight trials to each other as
//!   constant-liar fantasies ([`SessionGroup::remote_shared_bo`] wires a
//!   whole group).
//! - **Exact oracle** ([`gp::NativeGp`]) — the from-scratch reference
//!   solve. The incremental model reproduces it bit-for-bit (pinned by
//!   `rust/tests/surrogate_incremental.rs`); the scratch-refit engine
//!   path survives as [`gp::ExactRefitSurrogate`].
//! - **AOT artifact** (`runtime::GpSurrogate`) — the compiled HLO graph
//!   (L2 JAX + L1 Pallas RBF) executed via PJRT; RBF-only and compiled
//!   for a fixed window, and it rejects hypers outside that contract so
//!   the native and artifact paths can never silently disagree. The
//!   conditioning window exists **only** for parity with this compiled
//!   shape; native-only runs may lift it
//!   (`BayesOpt::with_history_window(None)`).
//!
//! Kernels (RBF, Matérn-5/2) live behind [`gp::Kernel`] /
//! [`gp::KernelKind`] with log-marginal-likelihood lengthscale selection
//! in [`gp::select_lengthscale`]; the packed-Cholesky/trsm/gemm kernel
//! set backing it all is in [`util::linalg`].
//!
//! # Multi-objective tuning
//!
//! The knobs this system tunes trade throughput against tail latency, so
//! a run can declare an [`ObjectiveSet`] (primary `value` plus named
//! `Measurement::metadata` columns, `:min` to minimise — see
//! [`objectives`]) and hand it to the BO engine
//! (`BayesOpt::with_objectives`) and the session
//! ([`TuningSession::with_objectives`]). The GP factor depends only on
//! the inputs, so K objectives are **K target columns over one shared
//! factor** — one blocked panel pass per ask, not K refits — scored
//! under a weighted scalarisation or an SMSego-style hypervolume gain
//! over the non-dominated front ([`Scalarization`]). [`History`] records
//! each trial's objective vector and exposes
//! [`History::pareto_front`] / [`History::hypervolume`]. On the wire
//! (protocol v3) the columns ride `tell-obs` / `factor-delta` rows, and
//! v2 peers keep working single-objective.
//!
//! ## Migrating from propose/observe
//!
//! Pre-redesign code looked like `let cfg = tuner.propose(); ...;
//! tuner.observe(&cfg, value)`. The equivalent today:
//!
//! ```
//! use tftune::algorithms::{Algorithm, Tuner};
//! use tftune::evaluator::{Evaluator, SimEvaluator};
//! use tftune::sim::ModelId;
//!
//! let space = ModelId::NcfFp32.space();
//! let mut tuner = Algorithm::Bo.build(&space, 1);
//! let mut evaluator = SimEvaluator::new(ModelId::NcfFp32, 1);
//!
//! let trial = tuner.ask(1).pop().unwrap();
//! let m = evaluator.measure(&trial.config).unwrap(); // Measurement, not f64
//! tuner.tell(trial.id, &m);
//! ```
//!
//! or, end to end, `evaluator::tune(&mut *tuner, &mut eval, iters)` for
//! the serial loop and [`TuningSession`] for batched/parallel runs:
//!
//! ```
//! use tftune::algorithms::Algorithm;
//! use tftune::evaluator::{sim_pool, Objective};
//! use tftune::sim::ModelId;
//! use tftune::{Budget, TuningSession};
//!
//! let model = ModelId::NcfFp32;
//! let mut session = TuningSession::new(
//!     Algorithm::Bo.build(&model.space(), 1),
//!     sim_pool(model, 1, 0.0, Objective::Throughput, 4),
//!     Budget::evaluations(16).with_plateau(12, 0.01),
//! );
//! let history = session.run().unwrap();
//! assert!(history.len() <= 16);
//! ```
//!
//! # Durability
//!
//! Long campaigns survive crashes through the [`persist`] subsystem:
//! checksummed snapshots of the packed factor + observation store, a
//! write-ahead log of every store mutation between them, and recovery
//! that restores the factor **bit-identically** to the pre-crash
//! authority (`surrogate-serve --state-dir`, `tune --state-dir` /
//! `--resume`; ARCHITECTURE.md §Durability).
//!
//! See `examples/parallel_tuning.rs`, `examples/session_group.rs`,
//! `examples/durable_session.rs` and the example index in `README.md`.

pub mod algorithms;
pub mod config;
pub mod evaluator;
pub mod figures;
pub mod gp;
pub mod history;
pub mod objectives;
pub mod obs;
pub mod persist;
pub mod runtime;
pub mod server;
pub mod session;
pub mod sim;
pub mod space;
pub mod util;

pub use algorithms::{Trial, TrialId};
pub use config::TuneConfig;
pub use gp::SharedSurrogate;
pub use history::{Evaluation, History, Measurement};
pub use objectives::{ObjectiveSet, Scalarization};
pub use session::{Budget, SessionGroup, StopReason, TuningSession};
pub use space::{ParamDef, SearchSpace};
