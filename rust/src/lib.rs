//! tftune: gradient-free auto-tuning of a TensorFlow-style CPU backend.
//!
//! Reproduction of "Automatic Tuning of TensorFlow's CPU Backend using
//! Gradient-Free Optimization Algorithms" (Mebratu et al., MLHPCS/ISC 2021)
//! as a three-layer Rust + JAX + Pallas system. See DESIGN.md.
//!
//! Layers:
//! - L3 (this crate): the tuning coordinator — search space, BO/GA/NMS
//!   engines, evaluation history, the host/target protocol, the
//!   system-under-test simulator substrate, and figure/table harnesses.
//! - L2 (python/compile/model.py): the Gaussian-process surrogate
//!   fit+predict+acquisition graph, AOT-lowered to HLO text at build time.
//! - L1 (python/compile/kernels/rbf.py): the Pallas RBF kernel-matrix
//!   kernel invoked from the L2 graph.
//!
//! Python is never on the tuning request path: the Rust BO engine executes
//! the AOT-compiled GP artifact via PJRT (`runtime`).
//!
//! # The ask/tell trial model
//!
//! The tuning core is an *ask/tell* conversation between an engine and a
//! driver. [`algorithms::Tuner::ask`]`(n)` yields up to `n` [`Trial`]s —
//! grid configurations tagged with engine-unique ids — and
//! [`algorithms::Tuner::tell`]`(id, &Measurement)` reports results back in
//! *any* order. [`Measurement`] replaces the old bare-`f64` objective: it
//! carries the value, what the value means, its wall-clock cost, and
//! optional metadata, and is recorded per trial in [`History`].
//!
//! [`TuningSession`] is the production driver: it owns an engine, a pool
//! of [`evaluator::Evaluator`]s (worker threads for in-process targets,
//! one TCP connection per remote daemon), and a [`Budget`] (evaluation
//! cap, wall-clock limit, plateau stop), keeping one trial in flight per
//! evaluator and streaming completions through a per-trial callback.
//!
//! ## Migrating from propose/observe
//!
//! Pre-redesign code looked like `let cfg = tuner.propose(); ...;
//! tuner.observe(&cfg, value)`. The equivalent today:
//!
//! ```ignore
//! let trial = tuner.ask(1).pop().unwrap();
//! let m = evaluator.measure(&trial.config)?;   // Measurement, not f64
//! tuner.tell(trial.id, &m);
//! ```
//!
//! or, end to end, `evaluator::tune(&mut *tuner, &mut eval, iters)` for
//! the serial loop and [`TuningSession`] for batched/parallel runs. See
//! `examples/parallel_tuning.rs`.

pub mod algorithms;
pub mod config;
pub mod evaluator;
pub mod figures;
pub mod gp;
pub mod history;
pub mod runtime;
pub mod server;
pub mod session;
pub mod sim;
pub mod space;
pub mod util;

pub use algorithms::{Trial, TrialId};
pub use config::TuneConfig;
pub use history::{Evaluation, History, Measurement};
pub use session::{Budget, StopReason, TuningSession};
pub use space::{ParamDef, SearchSpace};
