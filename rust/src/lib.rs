//! tftune: gradient-free auto-tuning of a TensorFlow-style CPU backend.
//!
//! Reproduction of "Automatic Tuning of TensorFlow's CPU Backend using
//! Gradient-Free Optimization Algorithms" (Mebratu et al., MLHPCS/ISC 2021)
//! as a three-layer Rust + JAX + Pallas system. See DESIGN.md.
//!
//! Layers:
//! - L3 (this crate): the tuning coordinator — search space, BO/GA/NMS
//!   engines, evaluation history, the host/target protocol, the
//!   system-under-test simulator substrate, and figure/table harnesses.
//! - L2 (python/compile/model.py): the Gaussian-process surrogate
//!   fit+predict+acquisition graph, AOT-lowered to HLO text at build time.
//! - L1 (python/compile/kernels/rbf.py): the Pallas RBF kernel-matrix
//!   kernel invoked from the L2 graph.
//!
//! Python is never on the tuning request path: the Rust BO engine executes
//! the AOT-compiled GP artifact via PJRT (`runtime`).

pub mod algorithms;
pub mod config;
pub mod evaluator;
pub mod figures;
pub mod gp;
pub mod history;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod space;
pub mod util;

pub use config::TuneConfig;
pub use history::{Evaluation, History};
pub use space::{ParamDef, SearchSpace};
