//! Table 1 — the tuning parameters and their ranges, per model.

use crate::sim::ModelId;

use super::print_table;

/// Print Table 1 exactly as the paper structures it.
pub fn print_table1() {
    let mut rows = vec![
        vec![
            "inter_op_parallelism_threads".to_string(),
            "all models".to_string(),
            "[1, 4, 1]".to_string(),
        ],
        vec![
            "intra_op_parallelism_threads".to_string(),
            "all models".to_string(),
            "[1, 56, 1]".to_string(),
        ],
    ];
    for model in ModelId::all() {
        let (lo, hi, step) = model.batch_range();
        rows.push(vec![
            "batch_size".to_string(),
            model.name().to_string(),
            format!("[{lo}, {hi}, {step}]"),
        ]);
    }
    rows.push(vec![
        "KMP_BLOCKTIME".to_string(),
        "all models".to_string(),
        "[0, 200, 10]".to_string(),
    ]);
    rows.push(vec![
        "OMP_NUM_THREADS".to_string(),
        "all models".to_string(),
        "[1, 56, 1]".to_string(),
    ]);
    print_table(
        "Table 1 — tuning parameters and their ranges (min, max, step)",
        &["parameter", "model", "range"],
        &rows,
    );
}

/// Search-space sizes per model (the paper's §1 search-cost discussion).
pub fn print_space_sizes() {
    let rows: Vec<Vec<String>> = ModelId::all()
        .into_iter()
        .map(|m| {
            let size = m.space().size();
            vec![m.name().to_string(), size.to_string()]
        })
        .collect();
    print_table("Full Table-1 grid size per model", &["model", "grid points"], &rows);
}

#[cfg(test)]
mod tests {
    #[test]
    fn printers_do_not_panic() {
        super::print_table1();
        super::print_space_sizes();
    }
}
