//! Fig. 6 — exhaustive sweep of ResNet50-INT8 throughput across all five
//! parameters, plus the paper's §1 cost accounting ("the exhaustive search
//! ... took close to a month of CPU time; the search space consisted of
//! roughly 50000 points").
//!
//! The full Table 1 grid is 4×56×16×21×56 ≈ 4.2M points; the paper's ~50k
//! sweep necessarily coarsened steps. We default to the same order of
//! magnitude (≈52k points: inter 4 × intra 8 × batch 4 × blocktime 5 ×
//! omp 8 ≈ 5120... scaled up via finer omp/intra) and verify the paper's
//! qualitative observations on the result:
//!   1. KMP_BLOCKTIME = 0 column dominates,
//!   2. throughput rises with OMP_NUM_THREADS,
//!   3. intra_op has ~no effect,
//!   4. batch size is second-order.

use std::path::Path;

use anyhow::Result;

use crate::sim::{ModelId, SimWorkload};
use crate::space::{self, Config, ParamDef, SearchSpace};
use crate::util::stats;

use super::{print_table, Csv};

/// The coarsened sweep grid (≈ the paper's 50k points).
pub fn sweep_space(fine: bool) -> SearchSpace {
    if fine {
        ModelId::Resnet50Int8.space() // full Table 1 grid (4.2M points)
    } else {
        SearchSpace::new(vec![
            ParamDef::new("inter_op_parallelism_threads", 1, 4, 1), // 4
            ParamDef::new("intra_op_parallelism_threads", 1, 56, 5), // 12
            ParamDef::new("batch_size", 64, 1024, 192),             // 6
            ParamDef::new("KMP_BLOCKTIME", 0, 200, 40),             // 6
            ParamDef::new("OMP_NUM_THREADS", 1, 56, 2),             // 28
        ])
        // 4 * 12 * 6 * 6 * 28 = 48384 points ~ "roughly 50000"
    }
}

/// One sweep result row.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub config: Config,
    pub throughput: f64,
}

/// Run the sweep (noise-free ground truth, as an exhaustive search would
/// average away noise anyway). Returns all points.
pub fn run_sweep(model: ModelId, fine: bool) -> Vec<SweepPoint> {
    let workload = SimWorkload::noiseless(model);
    let space = sweep_space(fine);
    space
        .grid()
        .map(|config| {
            let throughput = workload.true_throughput(&config);
            SweepPoint { config, throughput }
        })
        .collect()
}

/// Write the full sweep CSV.
pub fn write_csv(points: &[SweepPoint], out_dir: &Path) -> Result<std::path::PathBuf> {
    let mut csv = Csv::create(
        out_dir,
        "fig6_resnet50_int8_sweep.csv",
        &["inter_op", "intra_op", "batch", "blocktime", "omp", "throughput"],
    )?;
    for p in points {
        csv.row(&[
            p.config[space::INTER_OP].to_string(),
            p.config[space::INTRA_OP].to_string(),
            p.config[space::BATCH].to_string(),
            p.config[space::BLOCKTIME].to_string(),
            p.config[space::OMP_THREADS].to_string(),
            format!("{:.2}", p.throughput),
        ])?;
    }
    Ok(csv.path)
}

/// Mean throughput grouped by one parameter's values (marginal curve).
pub fn marginal(points: &[SweepPoint], param: usize) -> Vec<(i64, f64)> {
    let mut groups: std::collections::BTreeMap<i64, Vec<f64>> = Default::default();
    for p in points {
        groups.entry(p.config[param]).or_default().push(p.throughput);
    }
    groups.into_iter().map(|(v, ts)| (v, stats::mean(&ts))).collect()
}

/// Relative influence of a parameter: (max-min)/min of its marginal curve.
pub fn influence(points: &[SweepPoint], param: usize) -> f64 {
    let marg = marginal(points, param);
    let vals: Vec<f64> = marg.iter().map(|(_, t)| *t).collect();
    (stats::max(&vals) - stats::min(&vals)) / stats::min(&vals)
}

/// The paper's four qualitative observations, checked on sweep data.
#[derive(Debug)]
pub struct SweepFindings {
    pub blocktime0_best: bool,
    pub omp_influence: f64,
    pub intra_influence: f64,
    pub batch_influence: f64,
    pub best: SweepPoint,
    pub grid_points: usize,
    /// Hypothetical wall time had each evaluation taken the paper's ~1
    /// minute of real benchmarking (the "month of CPU time" claim).
    pub paper_equiv_days: f64,
}

pub fn analyze(points: &[SweepPoint]) -> SweepFindings {
    let bt_marg = marginal(points, space::BLOCKTIME);
    let best_bt = bt_marg
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|&(v, _)| v)
        .unwrap();
    let best = points
        .iter()
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).unwrap())
        .unwrap()
        .clone();
    SweepFindings {
        blocktime0_best: best_bt == 0,
        omp_influence: influence(points, space::OMP_THREADS),
        intra_influence: influence(points, space::INTRA_OP),
        batch_influence: influence(points, space::BATCH),
        best,
        grid_points: points.len(),
        paper_equiv_days: points.len() as f64 * 60.0 / 86_400.0,
    }
}

pub fn print_findings(f: &SweepFindings) {
    let rows = vec![
        vec!["grid points".into(), f.grid_points.to_string()],
        vec![
            "paper-equivalent wall time (1 min/eval)".into(),
            format!("{:.1} days", f.paper_equiv_days),
        ],
        vec!["KMP_BLOCKTIME=0 is the best marginal".into(), f.blocktime0_best.to_string()],
        vec!["OMP_NUM_THREADS influence (max-min)/min".into(), format!("{:.2}", f.omp_influence)],
        vec!["intra_op influence".into(), format!("{:.3}", f.intra_influence)],
        vec!["batch_size influence".into(), format!("{:.3}", f.batch_influence)],
        vec![
            "best config [inter,intra,batch,bt,omp]".into(),
            format!("{:?} @ {:.1} ex/s", f.best.config, f.best.throughput),
        ],
    ];
    print_table("Fig. 6 exhaustive sweep findings (ResNet50-INT8)", &["metric", "value"], &rows);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_points() -> Vec<SweepPoint> {
        // a downsampled sweep for test speed
        let workload = SimWorkload::noiseless(ModelId::Resnet50Int8);
        let space = SearchSpace::new(vec![
            ParamDef::new("inter", 1, 4, 3),
            ParamDef::new("intra", 1, 56, 55),
            ParamDef::new("batch", 64, 1024, 480),
            ParamDef::new("bt", 0, 200, 100),
            ParamDef::new("omp", 1, 56, 11),
        ]);
        space
            .grid()
            .map(|config| SweepPoint { throughput: workload.true_throughput(&config), config })
            .collect()
    }

    #[test]
    fn coarse_grid_is_about_50k() {
        let n = sweep_space(false).size();
        assert!((30_000..80_000).contains(&(n as i64)), "grid {n}");
    }

    #[test]
    fn paper_observations_hold_on_small_sweep() {
        let pts = small_points();
        let f = analyze(&pts);
        assert!(f.blocktime0_best, "blocktime 0 must dominate: {f:?}");
        assert!(f.omp_influence > 5.0 * f.intra_influence, "omp must dwarf intra: {f:?}");
        assert!(f.omp_influence > 2.0 * f.batch_influence, "omp must dwarf batch: {f:?}");
    }

    #[test]
    fn marginal_groups_cover_values() {
        let pts = small_points();
        let m = marginal(&pts, space::INTER_OP);
        assert_eq!(m.len(), 2); // inter 1 and 4 with step 3
        assert!(m.iter().all(|&(_, t)| t > 0.0));
    }
}
