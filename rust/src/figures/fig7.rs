//! Fig. 7 + Table 2 — exploration/exploitation analysis.
//!
//! Fig. 7 plots, for ResNet50-INT8 and BERT-FP32, the configurations each
//! algorithm sampled during tuning as pairplots over the five parameters
//! (letters: X=intra_op, Y=OMP, Z=batch, V=inter_op, W=blocktime). Table 2
//! reports the per-parameter sampled (min,max) and the sampled-range /
//! tunable-range percentage. This module reruns the tuning and emits both.

use std::path::Path;

use anyhow::Result;

use crate::algorithms::Algorithm;
use crate::config::{SurrogateKind, TuneConfig};
use crate::history::History;
use crate::sim::ModelId;
use crate::space::paper_letter;

use super::{print_table, Csv};

/// The two models the paper analyses in Fig. 7 / Table 2.
pub fn models() -> [ModelId; 2] {
    [ModelId::Resnet50Int8, ModelId::BertFp32]
}

/// Sampled data for one model × algorithm run.
pub struct SampleSet {
    pub model: ModelId,
    pub algorithm: Algorithm,
    pub history: History,
}

/// Rerun tuning and collect the sampled configurations.
pub fn run_samples(
    iterations: usize,
    seed: u64,
    surrogate: SurrogateKind,
) -> Result<Vec<SampleSet>> {
    let mut out = Vec::new();
    for model in models() {
        for algorithm in Algorithm::all_paper() {
            let cfg = TuneConfig { model, algorithm, iterations, seed, surrogate, ..Default::default() };
            let history = cfg.run()?;
            out.push(SampleSet { model, algorithm, history });
        }
    }
    Ok(out)
}

/// Write the pairplot scatter data: one CSV per model with every sampled
/// configuration, its algorithm, and its throughput (plot colour).
pub fn write_csv(samples: &[SampleSet], out_dir: &Path) -> Result<()> {
    for model in models() {
        let mut csv = Csv::create(
            out_dir,
            &format!("fig7_{}_samples.csv", model.short_name()),
            &["algorithm", "iteration", "V_inter", "X_intra", "Z_batch", "W_blocktime", "Y_omp", "throughput"],
        )?;
        for s in samples.iter().filter(|s| s.model == model) {
            for e in s.history.iter() {
                csv.row(&[
                    s.algorithm.name().to_string(),
                    e.iteration.to_string(),
                    e.config[crate::space::INTER_OP].to_string(),
                    e.config[crate::space::INTRA_OP].to_string(),
                    e.config[crate::space::BATCH].to_string(),
                    e.config[crate::space::BLOCKTIME].to_string(),
                    e.config[crate::space::OMP_THREADS].to_string(),
                    format!("{:.2}", e.value),
                ])?;
            }
        }
    }
    Ok(())
}

/// Table 2: sampled (min,max) per parameter + percentage of tunable range.
pub fn print_table2(samples: &[SampleSet]) {
    for model in models() {
        let space = model.space();
        let mut rows = Vec::new();
        // header-order: X, Y, Z, V, W as in the paper's Table 2
        let order = [
            crate::space::INTRA_OP,
            crate::space::OMP_THREADS,
            crate::space::BATCH,
            crate::space::INTER_OP,
            crate::space::BLOCKTIME,
        ];
        {
            let mut row = vec!["tunable range".to_string()];
            for &pi in &order {
                let p = &space.params[pi];
                row.push(format!("[{},{}]", p.min, p.max));
            }
            rows.push(row);
        }
        for s in samples.iter().filter(|s| s.model == model) {
            let ranges = s.history.sampled_ranges(space.dim()).unwrap();
            let pct = s.history.sampled_range_pct(&space).unwrap();
            let mut row_rng = vec![format!("{} (min,max)", s.algorithm.name())];
            let mut row_pct = vec![format!("{} sampled range %", s.algorithm.name())];
            for &pi in &order {
                row_rng.push(format!("[{},{}]", ranges[pi].0, ranges[pi].1));
                row_pct.push(format!("{:.0}", pct[pi]));
            }
            rows.push(row_rng);
            rows.push(row_pct);
        }
        let header: Vec<String> = std::iter::once("".to_string())
            .chain(order.iter().map(|&pi| {
                format!("{}={}", paper_letter(pi), space.params[pi].name.clone())
            }))
            .collect();
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        print_table(
            &format!("Table 2 — sampled vs tunable ranges: {}", model.name()),
            &header_refs,
            &rows,
        );
    }
}

/// Coverage summary used by tests and EXPERIMENTS.md: average sampled
/// range percentage per algorithm for one model.
pub fn avg_coverage(samples: &[SampleSet], model: ModelId, alg: Algorithm) -> Option<f64> {
    let space = model.space();
    samples
        .iter()
        .find(|s| s.model == model && s.algorithm == alg)
        .and_then(|s| s.history.sampled_range_pct(&space))
        .map(|pct| pct.iter().sum::<f64>() / pct.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exploration_ordering_bo_vs_ga() {
        // The paper's headline Table 2 finding: BO covers (nearly) 100% of
        // every range; GA covers well under half; NMS sits between.
        let samples = run_samples(50, 11, SurrogateKind::Native).unwrap();
        for model in models() {
            let bo = avg_coverage(&samples, model, Algorithm::Bo).unwrap();
            let ga = avg_coverage(&samples, model, Algorithm::Ga).unwrap();
            assert!(bo > 90.0, "{}: BO coverage {bo}", model.name());
            assert!(ga < 65.0, "{}: GA coverage {ga}", model.name());
            assert!(bo > ga, "{}: BO {bo} vs GA {ga}", model.name());
        }
    }
}
