//! Fig. 5 — "Results of auto-tuning TensorFlow's threading model using
//! Bayesian optimization, genetic algorithm, and Nelder-Mead simplex":
//! per-iteration throughput for 6 models × 3 algorithms, 50 iterations.

use std::path::Path;

use anyhow::Result;

use crate::algorithms::Algorithm;
use crate::config::{SurrogateKind, TuneConfig};
use crate::history::History;
use crate::sim::ModelId;
use crate::util::stats;

use super::{print_table, Csv};

/// One tuning curve.
#[derive(Debug, Clone)]
pub struct Curve {
    pub model: ModelId,
    pub algorithm: Algorithm,
    pub seed: u64,
    /// Raw measured throughput per iteration (what Fig. 5 plots).
    pub values: Vec<f64>,
}

impl Curve {
    pub fn best(&self) -> f64 {
        stats::max(&self.values)
    }
    pub fn best_curve(&self) -> Vec<f64> {
        stats::best_so_far(&self.values)
    }
}

/// Run one model × algorithm tuning curve. Executes through a serial
/// `TuningSession` (`TuneConfig::run`), which reproduces the paper's
/// strictly sequential measurement loop bit for bit.
pub fn run_curve(
    model: ModelId,
    algorithm: Algorithm,
    seed: u64,
    iterations: usize,
    surrogate: SurrogateKind,
) -> Result<Curve> {
    let cfg = TuneConfig { model, algorithm, iterations, seed, surrogate, ..Default::default() };
    let history: History = cfg.run()?;
    Ok(Curve { model, algorithm, seed, values: history.values() })
}

/// The full figure: every model × {BO, GA, NMS} × `seeds`.
pub fn run_figure(
    iterations: usize,
    seeds: &[u64],
    surrogate: SurrogateKind,
    out_dir: &Path,
) -> Result<Vec<Curve>> {
    let mut curves = Vec::new();
    for model in ModelId::all() {
        let mut csv = Csv::create(
            out_dir,
            &format!("fig5_{}.csv", model.short_name()),
            &["algorithm", "seed", "iteration", "throughput", "best_so_far"],
        )?;
        for alg in Algorithm::all_paper() {
            for &seed in seeds {
                let curve = run_curve(model, alg, seed, iterations, surrogate)?;
                let best = curve.best_curve();
                for (i, (&v, &b)) in curve.values.iter().zip(&best).enumerate() {
                    csv.row(&[
                        alg.name().to_string(),
                        seed.to_string(),
                        i.to_string(),
                        format!("{v:.3}"),
                        format!("{b:.3}"),
                    ])?;
                }
                curves.push(curve);
            }
        }
    }
    Ok(curves)
}

/// Print the summary the paper discusses: best throughput per model ×
/// algorithm (median across seeds), with the per-model winner marked.
pub fn print_summary(curves: &[Curve]) {
    let mut rows = Vec::new();
    for model in ModelId::all() {
        let mut best_per_alg = Vec::new();
        for alg in Algorithm::all_paper() {
            let bests: Vec<f64> = curves
                .iter()
                .filter(|c| c.model == model && c.algorithm == alg)
                .map(Curve::best)
                .collect();
            best_per_alg.push(if bests.is_empty() { 0.0 } else { stats::median(&bests) });
        }
        let winner = stats::argmax(&best_per_alg);
        let mut row = vec![model.name().to_string()];
        for (i, v) in best_per_alg.iter().enumerate() {
            let mark = if i == winner { " *" } else { "" };
            row.push(format!("{v:.1}{mark}"));
        }
        rows.push(row);
    }
    print_table(
        "Fig. 5 summary: best throughput (examples/s, median over seeds; * = winner)",
        &["model", "BO", "GA", "NMS"],
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_runs_and_has_budget_length() {
        let c = run_curve(ModelId::NcfFp32, Algorithm::Ga, 1, 12, SurrogateKind::Native).unwrap();
        assert_eq!(c.values.len(), 12);
        assert!(c.best() > 0.0);
    }

    #[test]
    fn best_curve_monotone() {
        let c =
            run_curve(ModelId::BertFp32, Algorithm::Nms, 2, 15, SurrogateKind::Native).unwrap();
        let b = c.best_curve();
        for w in b.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
