//! Figure/table reproduction harnesses.
//!
//! One submodule per evaluation artifact in the paper:
//! - `fig5`  — tuning curves, 6 models × {BO, GA, NMS}
//! - `fig6`  — exhaustive 5-parameter sweep of ResNet50-INT8
//! - `fig7`  — pairplot sample data + Table 2 range coverage
//! - `tables` — Table 1 (search space) pretty-printer
//!
//! Each harness prints the paper's rows/series to stdout and writes CSVs
//! under `figures_out/` so the plots can be regenerated with any plotting
//! tool. Benches in `benches/` are thin wrappers over these.

pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod tables;

use std::io::Write;
use std::path::{Path, PathBuf};

/// Default output directory for CSV series.
pub const OUT_DIR: &str = "figures_out";

/// A simple CSV writer (no quoting needed: all our fields are numeric or
/// bare identifiers).
pub struct Csv {
    file: std::fs::File,
    pub path: PathBuf,
    cols: usize,
}

impl Csv {
    pub fn create(dir: &Path, name: &str, header: &[&str]) -> anyhow::Result<Csv> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(name);
        let mut file = std::fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Csv { file, path, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(fields.len() == self.cols, "csv row width mismatch");
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_display(&mut self, fields: &[&dyn std::fmt::Display]) -> anyhow::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }
}

/// Render a fixed-width console table (the "same rows the paper reports").
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let line = |ch: char| {
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("{}", ch.to_string().repeat(total));
    };
    println!("\n{title}");
    line('=');
    let mut head = String::from("|");
    for (h, w) in header.iter().zip(&widths) {
        head.push_str(&format!(" {h:<w$} |"));
    }
    println!("{head}");
    line('-');
    for row in rows {
        let mut s = String::from("|");
        for (cell, w) in row.iter().zip(&widths) {
            s.push_str(&format!(" {cell:<w$} |"));
        }
        println!("{s}");
    }
    line('=');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_and_validates() {
        let dir = std::env::temp_dir().join("tftune_csv_test");
        let mut csv = Csv::create(&dir, "t.csv", &["a", "b"]).unwrap();
        csv.row(&["1".into(), "2".into()]).unwrap();
        assert!(csv.row(&["only-one".into()]).is_err());
        let text = std::fs::read_to_string(&csv.path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn print_table_does_not_panic() {
        print_table("t", &["x", "yy"], &[vec!["1".into(), "2".into()]]);
    }
}
